//! # pallas-model — BitNet b1.58 model layer
//!
//! The transformer ([`model`]: BitLinear, sessions, sampling), GGUF-style
//! checkpoint IO ([`modelio`]), the byte-fallback BPE [`tokenizer`], the
//! perplexity/task [`eval`] harness, and the end-to-end half of the
//! auto-tuner ([`tuner_e2e`] — the part that has to build whole models,
//! split out of `pallas_kernels::kernels::tuner` so the kernel crate
//! never depends upward on this one).
//!
//! Sessions allocate KV pages from [`pallas_core::arena`] — the arena
//! sits *below* this crate, so the model layer never reaches up into
//! the serving coordinator.

#![warn(clippy::undocumented_unsafe_blocks)]

#[deny(unsafe_code)]
pub mod eval;
pub mod model;
#[deny(unsafe_code)]
pub mod modelio;
#[deny(unsafe_code)]
pub mod tokenizer;
#[deny(unsafe_code)]
pub mod tuner_e2e;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
