//! End-to-end half of the auto-tuner: everything that has to build a
//! whole [`crate::model::Transformer`] to measure — layer-composition e2e timing
//! (`tune --e2e`), the automatic per-layer override search
//! (`tune --search-overrides`) and the model-composed
//! [`tokens_per_second`] estimate. Split out of
//! [`pallas_kernels::kernels::tuner`] by the workspace crate split so
//! the kernel crate never depends upward on the model crate; the
//! `rust_pallas` facade grafts these back into `bitnet::kernels::tuner`
//! and `bitnet::perf::calibrate`, so pre-split call sites compile
//! unchanged.

use anyhow::bail;
use pallas_kernels::kernels::tuner::{Dispatch, E2eEntry, LayerOverride, Role, TuningProfile};
use pallas_kernels::kernels::{kernel_for, QuantType};
use pallas_kernels::perf::calibrate::KernelRate;

use crate::Result;

/// The unique ternary-projection shapes of a model config, as (m, k) —
/// exactly the shapes [`crate::model::Transformer`] dispatches
/// ([`crate::model::ModelConfig::gemv_shapes`], deduplicated).
pub fn shapes_for_model(cfg: &crate::model::ModelConfig) -> Vec<(usize, usize)> {
    let mut shapes = cfg.gemv_shapes();
    shapes.sort_unstable();
    shapes.dedup();
    shapes
}

/// Measure layer-composition effects end to end (`bitnet tune --e2e`):
/// build the preset model under `Auto(profile)` and under
/// `Fixed(profile.default)`, then time one prefill chunk of
/// `prefill_tokens` and `decode_tokens` decode steps at `decode_width`
/// concurrent sequences (1 = single-sequence decode; `tune --trace`
/// passes the trace's modal shapes so this section and the override
/// search measure at the same, workload-observed shapes).
/// Per-shape micro-benchmarks can mislead in composition (one layer's
/// LUT tables evict the next layer's weights); this is the check that
/// the tuned profile actually wins on the full stack. Alternates are
/// prepacked before timing so repack cost isn't billed to the first call.
///
/// Synthesizes the model in memory, so it is restricted to runnable
/// presets (tiny / 100M).
pub fn measure_e2e(
    profile: &TuningProfile,
    cfg: &crate::model::ModelConfig,
    threads: usize,
    prefill_tokens: usize,
    decode_tokens: usize,
    decode_width: usize,
) -> Result<Vec<E2eEntry>> {
    ensure_hostable(cfg)?;
    let ck = crate::model::weights::Checkpoint::synthetic(cfg, 0xE2E);
    let candidates = [
        ("auto".to_string(), Dispatch::Auto(profile.clone())),
        (format!("fixed({})", profile.default.name()), Dispatch::Fixed(profile.default)),
    ];
    let mut out = Vec::new();
    for (label, dispatch) in candidates {
        out.push(measure_checkpoint_e2e(
            &label,
            dispatch,
            &ck,
            threads,
            prefill_tokens,
            decode_tokens,
            decode_width,
        )?);
    }
    Ok(out)
}

/// Refuse presets too large to synthesize in memory for an e2e timing
/// run (shared guard of [`measure_e2e`], [`measure_dispatch_e2e`] and
/// [`search_overrides`]).
fn ensure_hostable(cfg: &crate::model::ModelConfig) -> Result<()> {
    if cfg.param_count() > 300_000_000 {
        bail!(
            "e2e measurement synthesizes the whole model in memory; preset {} is too large \
             (use --preset tiny or 100M)",
            cfg.name
        );
    }
    Ok(())
}

/// Time one dispatch policy end to end on a synthesized preset model:
/// one prefill chunk of `prefill_tokens`, then `decode_tokens` decode
/// steps over `decode_width` concurrent sequences (1 = single-sequence
/// `decode_step`; wider runs the engine's batched `decode_batch` path,
/// so trace-driven searches measure at the decode width the workload
/// actually serves). Reported as an [`E2eEntry`], decode throughput in
/// generated tokens/s across the batch. The shared measurement primitive
/// behind [`measure_e2e`] and [`search_overrides`].
///
/// Synthesizes the model in memory, so it is restricted to runnable
/// presets (tiny / 100M).
pub fn measure_dispatch_e2e(
    label: &str,
    dispatch: Dispatch,
    cfg: &crate::model::ModelConfig,
    threads: usize,
    prefill_tokens: usize,
    decode_tokens: usize,
    decode_width: usize,
) -> Result<E2eEntry> {
    ensure_hostable(cfg)?;
    let ck = crate::model::weights::Checkpoint::synthetic(cfg, 0xE2E);
    measure_checkpoint_e2e(label, dispatch, &ck, threads, prefill_tokens, decode_tokens, decode_width)
}

/// [`measure_dispatch_e2e`] over an already-synthesized checkpoint —
/// the loop bodies of [`measure_e2e`] and [`search_overrides`] share one
/// checkpoint across all their measurements instead of regenerating the
/// model's random weights per candidate.
fn measure_checkpoint_e2e(
    label: &str,
    dispatch: Dispatch,
    ck: &crate::model::weights::Checkpoint,
    threads: usize,
    prefill_tokens: usize,
    decode_tokens: usize,
    decode_width: usize,
) -> Result<E2eEntry> {
    let cfg = &ck.config;
    let width = decode_width.max(1);
    let prefill_tokens = clamp_prefill_tokens(cfg, prefill_tokens);
    // The decode loop advances the session past the prefill chunk; keep
    // the sum inside max_seq_len or Session::append would overflow.
    let decode_tokens = decode_tokens.min(cfg.max_seq_len.saturating_sub(prefill_tokens + 1));
    let prompt: Vec<u32> = (0..prefill_tokens)
        .map(|i| (3 + i % cfg.vocab_size.saturating_sub(3).max(1)) as u32)
        .collect();
    let model = crate::model::Transformer::from_checkpoint_dispatch(ck, dispatch, threads);
    // Alternates are prepacked before timing so repack cost isn't billed
    // to the first call.
    model.prepack(&[1, width, prompt.len()]);
    let mut sessions: Vec<crate::model::Session> = (0..width)
        .map(|_| model.new_session(prompt.len() + decode_tokens + 1))
        .collect();
    // Only the first prefill is timed; the extra sessions exist to give
    // the batched decode below same-length peers.
    let t0 = std::time::Instant::now();
    let _ = model.prefill(&mut sessions[0], &prompt);
    let prefill_s = t0.elapsed().as_secs_f64();
    for s in sessions.iter_mut().skip(1) {
        let _ = model.prefill(s, &prompt);
    }
    let tok = 3 % cfg.vocab_size as u32;
    let t1 = std::time::Instant::now();
    if width == 1 {
        for _ in 0..decode_tokens {
            let _ = model.decode_step(&mut sessions[0], tok);
        }
    } else {
        let tokens: Vec<u32> = vec![tok; width];
        for _ in 0..decode_tokens {
            let mut refs: Vec<&mut crate::model::Session> = sessions.iter_mut().collect();
            let _ = model.decode_batch(&mut refs, &tokens);
        }
    }
    let decode_s = t1.elapsed().as_secs_f64();
    Ok(E2eEntry {
        label: label.to_string(),
        prefill_tok_s: prompt.len() as f64 / prefill_s.max(1e-9),
        decode_tok_s: (decode_tokens * width) as f64 / decode_s.max(1e-9),
    })
}

/// How [`search_overrides`] runs and scores its end-to-end sweep.
#[derive(Clone, Debug)]
pub struct OverrideSearchConfig {
    /// Prefill chunk length each composition is timed at (`tune --trace`
    /// sets it to the trace's modal chunk so the sweep measures a shape
    /// the workload actually runs).
    pub prefill_tokens: usize,
    /// Decode steps each composition is timed over.
    pub decode_tokens: usize,
    /// Concurrent sequences each decode step runs
    /// ([`measure_dispatch_e2e`]'s batched path when > 1; `tune --trace`
    /// sets it to the trace's modal decode width).
    pub decode_width: usize,
    /// Phase blend for scoring: `score = pw·prefill_tok_s +
    /// (1-pw)·decode_tok_s`. Defaults to 0.5; `tune --trace` sets it to
    /// the trace's observed prefill token fraction so the winner reflects
    /// real traffic.
    pub prefill_weight: f64,
    /// Kernels to try pinning on the edge/middle layers. Empty = derived
    /// from the profile (its distinct per-shape winners plus its
    /// default).
    pub candidates: Vec<QuantType>,
    /// Relative improvement over the uniform score a composition must
    /// show to win (0.02 = 2%). Each composition is timed once, so a
    /// strict `>` would let single-sample jitter install override rows
    /// from compositions that are not actually faster; the margin is
    /// the noise gate. Set 0.0 for the raw strict comparison.
    pub min_gain: f64,
}

impl Default for OverrideSearchConfig {
    fn default() -> Self {
        OverrideSearchConfig {
            prefill_tokens: 32,
            decode_tokens: 64,
            decode_width: 1,
            prefill_weight: 0.5,
            candidates: Vec::new(),
            min_gain: 0.02,
        }
    }
}

/// What [`search_overrides`] decided.
#[derive(Clone, Debug)]
pub struct OverrideSearchOutcome {
    /// The winning override rows — empty when no composition beat the
    /// uniform assignment (install these as the profile's `overrides`).
    pub overrides: Vec<LayerOverride>,
    /// Label of the winning composition (`"uniform"` when none won).
    pub winner: String,
    /// Every composition's end-to-end measurement, uniform first (append
    /// to the profile's `e2e` section for inspection).
    pub measurements: Vec<E2eEntry>,
    /// The uniform assignment's blended score (tok/s).
    pub uniform_score: f64,
    /// The best composition's blended score (tok/s) — equals
    /// `uniform_score` when nothing beat it.
    pub best_score: f64,
}

/// The prefill chunk length [`measure_dispatch_e2e`] will actually run
/// for `cfg` (session capacity bounds the chunk to half the context) —
/// shared with `search_overrides`' no-op filter, whose correctness
/// depends on probing dispatch at exactly the measured widths.
fn clamp_prefill_tokens(cfg: &crate::model::ModelConfig, tokens: usize) -> usize {
    tokens.clamp(1, (cfg.max_seq_len / 2).max(1))
}

/// The (m, k) projection shapes a [`Role`] dispatches in `cfg` (qkv
/// covers wq plus the possibly-narrower wk/wv).
fn role_shapes(cfg: &crate::model::ModelConfig, role: Role) -> Vec<(usize, usize)> {
    let h = cfg.hidden;
    match role {
        Role::Qkv => vec![(h, h), (cfg.kv_dim(), h)],
        Role::O => vec![(h, h)],
        Role::Gate | Role::Up => vec![(cfg.ffn, h)],
        Role::Down => vec![(h, cfg.ffn)],
    }
}

/// The per-layer override rows that pin `layers` × every role whose K
/// dimension `qtype` can serve (misaligned roles are skipped rather than
/// emitted as construction-time degrades) at batch `n = 1` — which the
/// largest-tuned-n ≤ n rule extends to every batch width.
fn composition_overrides(
    cfg: &crate::model::ModelConfig,
    layers: &[usize],
    qtype: QuantType,
) -> Vec<LayerOverride> {
    let k_mult = kernel_for(qtype).info().k_multiple;
    let mut rows = Vec::new();
    for &layer in layers {
        for role in Role::ALL {
            // Reduction dim per role: every projection consumes the
            // hidden state except `down`, which consumes the FFN width.
            if role_shapes(cfg, role).iter().any(|&(_, k)| k % k_mult != 0) {
                continue;
            }
            rows.push(LayerOverride { layer, role, n: 1, qtype });
        }
    }
    rows
}

/// Automatic per-layer override search (`tune --search-overrides`): the
/// edge layers (first and last) see different activation statistics and
/// cache pressure than the middle of the stack, so the per-shape winner
/// is not always the per-*position* winner. This sweeps edge-vs-middle
/// kernel assignments end to end — for each candidate kernel, one
/// composition pinning the first and last layers and (when the stack has
/// a middle) one pinning everything in between — scores each against the
/// uniform (no-override) assignment via [`measure_dispatch_e2e`], and
/// returns the winning [`LayerOverride`] rows, or none when uniform wins.
///
/// The score blends the two phase throughputs by
/// [`OverrideSearchConfig::prefill_weight`]; `progress` receives one line
/// per measurement plus the final decision.
pub fn search_overrides(
    profile: &TuningProfile,
    cfg: &crate::model::ModelConfig,
    threads: usize,
    search: &OverrideSearchConfig,
    mut progress: Option<&mut dyn FnMut(&str)>,
) -> Result<OverrideSearchOutcome> {
    let pw = search.prefill_weight.clamp(0.0, 1.0);
    let score = |e: &E2eEntry| pw * e.prefill_tok_s + (1.0 - pw) * e.decode_tok_s;
    // A composition wins only when it clears the uniform score by the
    // noise margin — each composition is timed once, and a strict `>`
    // would let single-sample jitter promote a not-actually-faster one.
    let min_gain = search.min_gain.max(0.0);
    let mut say = |s: &str| {
        if let Some(p) = progress.as_mut() {
            p(s);
        }
    };

    ensure_hostable(cfg)?;
    // One synthesized checkpoint shared across every measurement in the
    // sweep (regenerating the random weights per candidate would
    // dominate the search's cost on the 100M preset).
    let ck = crate::model::weights::Checkpoint::synthetic(cfg, 0xE2E);

    // The baseline every composition must beat: the profile as-is but
    // with no per-layer overrides (the uniform per-shape assignment).
    let mut uniform_profile = profile.clone();
    uniform_profile.overrides.clear();

    let candidates: Vec<QuantType> = if search.candidates.is_empty() {
        let mut c: Vec<QuantType> = profile.entries.iter().map(|e| e.best).collect();
        c.push(profile.default);
        c.sort_by_key(|q| q.name());
        c.dedup();
        c
    } else {
        search.candidates.clone()
    };

    let uniform = measure_checkpoint_e2e(
        "uniform",
        Dispatch::Auto(uniform_profile.clone()),
        &ck,
        threads,
        search.prefill_tokens,
        search.decode_tokens,
        search.decode_width,
    )?;
    let uniform_score = score(&uniform);
    say(&format!(
        "override search: uniform prefill {:.1} decode {:.1} tok/s (score {:.1}, prefill weight {:.2})",
        uniform.prefill_tok_s, uniform.decode_tok_s, uniform_score, pw
    ));

    // Edge layers vs middle layers: the first/last-vs-middle split the
    // paper's composition effects concentrate on.
    let last = cfg.n_layers.saturating_sub(1);
    let edge_layers: Vec<usize> = if last == 0 { vec![0] } else { vec![0, last] };
    let middle_layers: Vec<usize> = (1..last).collect();

    // Batch widths the measurement actually exercises — the decode width
    // (n=1 decode_step when 1, batched decode_batch otherwise) and the
    // prefill chunk, clamped through the same helper the measurement
    // uses. An n=1 override row shadows dispatch at *every* width, so a
    // row counts as a no-op only when it matches uniform's selection at
    // each of these: differing only at an unmeasured width (e.g. n=1
    // when the traced decode runs at width 4) is invisible to the timing
    // and must not let noise promote the composition.
    let probe_widths: Vec<usize> = {
        let mut w = vec![
            search.decode_width.max(1),
            clamp_prefill_tokens(cfg, search.prefill_tokens),
        ];
        w.sort_unstable();
        w.dedup();
        w
    };

    let mut measurements = vec![uniform];
    let mut best: Option<(f64, String, Vec<LayerOverride>)> = None;
    for &qt in &candidates {
        let mut compositions: Vec<(String, Vec<usize>)> =
            vec![(format!("edges={}", qt.name()), edge_layers.clone())];
        if !middle_layers.is_empty() {
            compositions.push((format!("middle={}", qt.name()), middle_layers.clone()));
        }
        for (label, layers) in compositions {
            let all_rows = composition_overrides(cfg, &layers, qt);
            if all_rows.is_empty() {
                say(&format!("override search: {label}: no role fits this kernel's K alignment, skipped"));
                continue;
            }
            // Drop rows that pin exactly what the uniform assignment
            // already selects at every measured width — they change
            // nothing the measurement can see, and a composition whose
            // measured configuration is identical to uniform "beating"
            // it would be pure timing noise installed as fake rows.
            let rows: Vec<LayerOverride> = all_rows
                .into_iter()
                .filter(|o| {
                    role_shapes(cfg, o.role).iter().any(|&(m, k)| {
                        probe_widths.iter().any(|&n| {
                            uniform_profile.select_for(o.layer, o.role, m, k, n).0 != o.qtype
                        })
                    })
                })
                .collect();
            if rows.is_empty() {
                say(&format!(
                    "override search: {label}: matches the uniform assignment at every \
                     measured width, skipped"
                ));
                continue;
            }
            let mut candidate_profile = uniform_profile.clone();
            candidate_profile.overrides = rows.clone();
            let e = measure_checkpoint_e2e(
                &label,
                Dispatch::Auto(candidate_profile),
                &ck,
                threads,
                search.prefill_tokens,
                search.decode_tokens,
                search.decode_width,
            )?;
            let s = score(&e);
            let wins = s > uniform_score * (1.0 + min_gain);
            say(&format!(
                "override search: {label}: prefill {:.1} decode {:.1} tok/s (score {:.1}{})",
                e.prefill_tok_s,
                e.decode_tok_s,
                s,
                if wins {
                    ", beats uniform"
                } else if s > uniform_score {
                    ", within noise margin of uniform"
                } else {
                    ""
                }
            ));
            measurements.push(e);
            if wins && best.as_ref().map_or(true, |(bs, _, _)| s > *bs) {
                best = Some((s, label, rows));
            }
        }
    }

    let outcome = match best {
        Some((best_score, winner, overrides)) => {
            say(&format!(
                "override search: winner {winner} ({} override rows, {:+.1}% over uniform)",
                overrides.len(),
                (best_score / uniform_score.max(1e-9) - 1.0) * 100.0
            ));
            OverrideSearchOutcome { overrides, winner, measurements, uniform_score, best_score }
        }
        None => {
            say("override search: uniform assignment wins, no overrides emitted");
            OverrideSearchOutcome {
                overrides: Vec::new(),
                winner: "uniform".to_string(),
                measurements,
                uniform_score,
                best_score: uniform_score,
            }
        }
    };
    Ok(outcome)
}

/// Estimated decode tokens/s for a model config under a calibrated rate:
/// ternary projections at the measured kernel rate, LM head at the
/// measured F16 rate, plus a fixed per-token overhead for attention/norms.
pub fn tokens_per_second(
    cfg: &crate::model::ModelConfig,
    rate: &KernelRate,
    f16_rate: &KernelRate,
    overhead_s: f64,
) -> f64 {
    let ternary_bytes = cfg.ternary_param_count() as f64 * rate.bpw / 8.0;
    let head_bytes = (cfg.vocab_size * cfg.hidden) as f64 * 2.0;
    let t = ternary_bytes / rate.weight_bytes_per_s
        + head_bytes / f16_rate.weight_bytes_per_s
        + overhead_s;
    1.0 / t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn composition_overrides_skip_misaligned_roles() {
        // micro config: hidden=128 fits I2_S (K % 128) everywhere, but
        // ffn=384 means `down` (k=ffn) misaligns for TQ2_0 (K % 256).
        let cfg = crate::model::ModelConfig {
            name: "micro",
            hidden: 128,
            ffn: 384,
            n_layers: 3,
            n_heads: 2,
            n_kv_heads: 2,
            vocab_size: 64,
            max_seq_len: 32,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
        };
        let rows = composition_overrides(&cfg, &[0, 2], QuantType::I2S);
        assert_eq!(rows.len(), 2 * Role::ALL.len(), "I2_S fits every role");
        assert!(rows.iter().all(|o| o.n == 1));
        let rows = composition_overrides(&cfg, &[0], QuantType::Tq20);
        // 384 % 256 != 0 → down skipped; 128 % 256 != 0 → everything
        // whose k is `hidden` is skipped too.
        assert!(rows.is_empty(), "{rows:?}");
    }

    #[test]
    fn shapes_for_model_covers_all_projections() {
        let cfg = crate::model::ModelConfig::tiny();
        let shapes = shapes_for_model(&cfg);
        assert!(shapes.contains(&(cfg.hidden, cfg.hidden)));
        assert!(shapes.contains(&(cfg.kv_dim(), cfg.hidden)));
        assert!(shapes.contains(&(cfg.ffn, cfg.hidden)));
        assert!(shapes.contains(&(cfg.hidden, cfg.ffn)));
        // Deduped and sorted.
        let mut sorted = shapes.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(shapes, sorted);
    }

    #[test]
    fn tokens_per_second_ordering() {
        let cfg = crate::model::ModelConfig::b3_8();
        let fast = KernelRate { qtype: QuantType::Tl20, weight_bytes_per_s: 1e10, weights_per_s: 5e10, bpw: 1.67 };
        let slow = KernelRate { qtype: QuantType::F16, weight_bytes_per_s: 1e10, weights_per_s: 5e9, bpw: 16.0 };
        let f16 = slow;
        let a = tokens_per_second(&cfg, &fast, &f16, 0.0);
        let b = tokens_per_second(&cfg, &slow, &f16, 0.0);
        assert!(a > b * 5.0, "{a} vs {b}");
    }
}
