//! Quality-evaluation harness (paper Table 2 stand-in).
//!
//! We cannot download bitnet_b1_58-large or WikiText2 (see DESIGN.md
//! §Substitutions); the Table-2 *claim* is equality/closeness to the
//! full-precision path, which is checkable exactly on any corpus:
//!
//! * **perplexity** of the same synthetic model under each kernel over a
//!   deterministic token stream — lossless kernels must match the
//!   training-scheme reference to the last bit, `_0` kernels must be
//!   within noise;
//! * a **cloze accuracy** task (WinoGrande/HellaSwag stand-in): pick the
//!   higher-likelihood continuation out of candidate pairs, scoring
//!   agreement with the reference path.

use crate::model::{Session, Transformer};

/// Natural-log perplexity of `tokens` under `model` (teacher-forced).
/// `tokens.len()` must be ≥ 2.
pub fn perplexity(model: &Transformer, tokens: &[u32]) -> f64 {
    assert!(tokens.len() >= 2, "need at least two tokens");
    let mut session: Session = model.new_session(tokens.len());
    let mut nll = 0f64;
    let mut count = 0usize;
    // Feed token t, score token t+1.
    let mut logits = model.prefill(&mut session, &tokens[..1]);
    for w in tokens.windows(2) {
        let target = w[1] as usize;
        nll += -log_softmax_at(&logits, target);
        count += 1;
        logits = model.decode_step(&mut session, w[1]);
    }
    (nll / count as f64).exp()
}

/// log softmax(logits)[target], computed in f64 for stability.
pub fn log_softmax_at(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let logsum: f64 = (logits.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>()).ln() + max;
    logits[target] as f64 - logsum
}

/// One cloze item: a context and two candidate continuations, `correct`
/// indexing the "right" one (as judged by the reference model).
#[derive(Clone, Debug)]
pub struct ClozeItem {
    pub context: Vec<u32>,
    pub candidates: [Vec<u32>; 2],
}

/// Score a candidate continuation: mean log-likelihood under the model.
pub fn continuation_loglik(model: &Transformer, context: &[u32], cont: &[u32]) -> f64 {
    let mut session = model.new_session(context.len() + cont.len());
    let mut logits = model.prefill(&mut session, context);
    let mut ll = 0f64;
    for &t in cont {
        ll += log_softmax_at(&logits, t as usize);
        logits = model.decode_step(&mut session, t);
    }
    ll / cont.len().max(1) as f64
}

/// Pick the higher-likelihood candidate (0 or 1).
pub fn cloze_choice(model: &Transformer, item: &ClozeItem) -> usize {
    let a = continuation_loglik(model, &item.context, &item.candidates[0]);
    let b = continuation_loglik(model, &item.context, &item.candidates[1]);
    if a >= b {
        0
    } else {
        1
    }
}

/// Fraction of items where `model` agrees with `reference`.
pub fn cloze_agreement(model: &Transformer, reference: &Transformer, items: &[ClozeItem]) -> f64 {
    if items.is_empty() {
        return 1.0;
    }
    let agree = items
        .iter()
        .filter(|it| cloze_choice(model, it) == cloze_choice(reference, it))
        .count();
    agree as f64 / items.len() as f64
}

/// Deterministic synthetic cloze set over the model's vocab.
pub fn synthetic_cloze_set(vocab: usize, n_items: usize, seed: u64) -> Vec<ClozeItem> {
    let mut rng = pallas_core::util::Rng::new(seed);
    (0..n_items)
        .map(|_| {
            let ctx_len = 3 + rng.next_below(6);
            let cont_len = 2 + rng.next_below(3);
            let mut tok = || 3 + rng.next_below(vocab - 3) as u32;
            let context: Vec<u32> = (0..ctx_len).map(|_| tok()).collect();
            let a: Vec<u32> = (0..cont_len).map(|_| tok()).collect();
            let b: Vec<u32> = (0..cont_len).map(|_| tok()).collect();
            ClozeItem { context, candidates: [a, b] }
        })
        .collect()
}

/// Deterministic synthetic evaluation token stream (the WikiText2
/// stand-in), produced by tokenizing the Zipf-ish corpus.
pub fn eval_token_stream(vocab: usize, n_tokens: usize, seed: u64) -> Vec<u32> {
    use crate::tokenizer::{synthetic_corpus, Tokenizer};
    let tok = Tokenizer::train(&synthetic_corpus(4000, seed), vocab.min(2048));
    let mut ids = tok.encode(&synthetic_corpus(n_tokens, seed + 1));
    ids.truncate(n_tokens);
    // Clamp into vocab in case the tokenizer's vocab exceeds the model's.
    for id in ids.iter_mut() {
        if *id as usize >= vocab {
            *id = (*id as usize % (vocab - 3) + 3) as u32;
        }
    }
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_kernels::kernels::QuantType;
    use crate::model::ModelConfig;

    fn tiny(qt: QuantType) -> Transformer {
        Transformer::synthetic(&ModelConfig::tiny(), qt, 5)
    }

    #[test]
    fn log_softmax_is_normalized() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn perplexity_bounded_by_vocab() {
        let model = tiny(QuantType::I2S);
        let tokens = eval_token_stream(512, 40, 1);
        let ppl = perplexity(&model, &tokens);
        assert!(ppl > 1.0, "{ppl}");
        assert!(ppl < 512.0 * 4.0, "{ppl}"); // way below worst-case-ish
    }

    #[test]
    fn lossless_kernels_identical_perplexity() {
        let tokens = eval_token_stream(512, 30, 2);
        let p_ref = perplexity(&tiny(QuantType::I2S), &tokens);
        let p_tl1 = perplexity(&tiny(QuantType::Tl11), &tokens);
        let p_tl2 = perplexity(&tiny(QuantType::Tl21), &tokens);
        assert_eq!(p_ref, p_tl1, "TL1_1 must be bit-identical");
        assert_eq!(p_ref, p_tl2, "TL2_1 must be bit-identical");
    }

    #[test]
    fn fast_kernels_close_perplexity() {
        let tokens = eval_token_stream(512, 30, 3);
        let p_ref = perplexity(&tiny(QuantType::I2S), &tokens);
        for qt in [QuantType::Tl10, QuantType::Tl20, QuantType::Tq20] {
            let p = perplexity(&tiny(qt), &tokens);
            let rel = (p - p_ref).abs() / p_ref;
            assert!(rel < 0.05, "{qt:?}: ppl {p} vs ref {p_ref}");
        }
    }

    #[test]
    fn cloze_agreement_is_total_for_lossless() {
        let items = synthetic_cloze_set(512, 8, 4);
        let reference = tiny(QuantType::I2S);
        let model = tiny(QuantType::Tl21);
        assert_eq!(cloze_agreement(&model, &reference, &items), 1.0);
    }
}
