//! Non-matmul transformer ops: RMSNorm, RoPE, softmax, SiLU/SwiGLU.
//! These stay in f32 on every kernel path (BitNet b1.58 keeps them
//! high-precision), so the lossless-equality property of I2_S/TL*_1 is
//! decided entirely by the BitLinear projections.
//!
//! The arithmetic runs on the [`pallas_core::simd::ops`] primitives, so
//! each op dispatches on the process-wide `SimdLevel` and is
//! bit-identical across scalar/AVX2/NEON (the reductions share one
//! lane-blocked order; transcendentals stay scalar libm in every tier).

use pallas_core::simd::ops;

/// RMSNorm: `out[i] = x[i] / rms(x) * gain[i]`.
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    debug_assert_eq!(x.len(), out.len());
    let ss = ops::sum_squares(x) / x.len() as f32;
    let inv = 1.0 / (ss + eps).sqrt();
    ops::scale_gain(x, inv, gain, out);
}

/// In-place rotary position embedding over interleaved (even, odd) pairs
/// of each head's dimensions, LLaMA convention.
///
/// The per-pair `sin`/`cos` tables depend on position only, so they are
/// computed once per call into a stack block and reused across heads
/// (the old per-head recompute did `n_heads` times the libm work), then
/// each head rotates through the vectorized [`ops::rope_rotate`].
pub fn rope(x: &mut [f32], n_heads: usize, head_dim: usize, pos: usize, theta: f32) {
    debug_assert_eq!(x.len(), n_heads * head_dim);
    let half = head_dim / 2;
    const BLOCK: usize = 64;
    let mut sin = [0f32; BLOCK];
    let mut cos = [0f32; BLOCK];
    let mut p0 = 0usize;
    while p0 < half {
        let pn = BLOCK.min(half - p0);
        for j in 0..pn {
            let i = p0 + j;
            let freq = 1.0 / theta.powf(2.0 * i as f32 / head_dim as f32);
            let angle = pos as f32 * freq;
            let (s, c) = angle.sin_cos();
            sin[j] = s;
            cos[j] = c;
        }
        for h in 0..n_heads {
            let head = &mut x[h * head_dim + 2 * p0..h * head_dim + 2 * (p0 + pn)];
            ops::rope_rotate(head, &sin[..pn], &cos[..pn]);
        }
        p0 += pn;
    }
}

/// Numerically-stable in-place softmax. Lives in `pallas_core::util`
/// since the crate split (the KV arena's fused attend uses the same
/// implementation one layer below); re-exported here unchanged.
pub use pallas_core::util::softmax;

/// SiLU (swish) activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// SwiGLU combine: `out[i] = silu(gate[i]) * up[i]` (vectorized; `exp`
/// stays scalar libm so every tier produces the same bits).
pub fn swiglu(gate: &[f32], up: &[f32], out: &mut [f32]) {
    debug_assert_eq!(gate.len(), up.len());
    debug_assert_eq!(gate.len(), out.len());
    ops::silu_mul(gate, up, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_gain_normalizes() {
        let x = vec![3.0f32, 4.0, 0.0, 0.0];
        let gain = vec![1.0f32; 4];
        let mut out = vec![0f32; 4];
        rmsnorm(&x, &gain, 0.0, &mut out);
        let rms: f32 = (out.iter().map(|v| v * v).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-5);
        assert!((out[0] / out[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut x = vec![1.0f32, 2.0, 3.0];
        softmax(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut x = vec![1000.0f32, 1000.0];
        softmax(&mut x);
        assert!((x[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).sin()).collect();
        let before: f32 = x.iter().map(|v| v * v).sum();
        rope(&mut x, 2, 32, 17, 10000.0);
        let after: f32 = x.iter().map(|v| v * v).sum();
        assert!((before - after).abs() / before < 1e-5);
    }

    #[test]
    fn rope_pos_zero_is_identity() {
        let mut x: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let orig = x.clone();
        rope(&mut x, 1, 32, 0, 10000.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rope_is_relative() {
        // <RoPE(q, p), RoPE(k, p)> depends only on the content for equal
        // positions: rotating both by the same angle preserves dot product.
        let q: Vec<f32> = (0..8).map(|i| (i as f32).cos()).collect();
        let k: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).sin()).collect();
        let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        let mut q1 = q.clone();
        let mut k1 = k.clone();
        rope(&mut q1, 1, 8, 5, 10000.0);
        rope(&mut k1, 1, 8, 5, 10000.0);
        assert!((dot(&q1, &k1) - dot(&q, &k)).abs() < 1e-4);
    }

    #[test]
    fn silu_properties() {
        assert_eq!(silu(0.0), 0.0);
        assert!(silu(10.0) > 9.99);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
