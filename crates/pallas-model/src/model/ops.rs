//! Non-matmul transformer ops: RMSNorm, RoPE, softmax, SiLU/SwiGLU.
//! These stay in f32 on every kernel path (BitNet b1.58 keeps them
//! high-precision), so the lossless-equality property of I2_S/TL*_1 is
//! decided entirely by the BitLinear projections.

/// RMSNorm: `out[i] = x[i] / rms(x) * gain[i]`.
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    debug_assert_eq!(x.len(), out.len());
    let ss: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ss + eps).sqrt();
    for ((o, &xv), &g) in out.iter_mut().zip(x.iter()).zip(gain.iter()) {
        *o = xv * inv * g;
    }
}

/// In-place rotary position embedding over interleaved (even, odd) pairs
/// of each head's dimensions, LLaMA convention.
pub fn rope(x: &mut [f32], n_heads: usize, head_dim: usize, pos: usize, theta: f32) {
    debug_assert_eq!(x.len(), n_heads * head_dim);
    for h in 0..n_heads {
        let head = &mut x[h * head_dim..(h + 1) * head_dim];
        for i in 0..head_dim / 2 {
            let freq = 1.0 / theta.powf(2.0 * i as f32 / head_dim as f32);
            let angle = pos as f32 * freq;
            let (sin, cos) = angle.sin_cos();
            let (a, b) = (head[2 * i], head[2 * i + 1]);
            head[2 * i] = a * cos - b * sin;
            head[2 * i + 1] = a * sin + b * cos;
        }
    }
}

/// Numerically-stable in-place softmax. Lives in `pallas_core::util`
/// since the crate split (the KV arena's fused attend uses the same
/// implementation one layer below); re-exported here unchanged.
pub use pallas_core::util::softmax;

/// SiLU (swish) activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// SwiGLU combine: `out[i] = silu(gate[i]) * up[i]`.
pub fn swiglu(gate: &[f32], up: &[f32], out: &mut [f32]) {
    for ((o, &g), &u) in out.iter_mut().zip(gate.iter()).zip(up.iter()) {
        *o = silu(g) * u;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_gain_normalizes() {
        let x = vec![3.0f32, 4.0, 0.0, 0.0];
        let gain = vec![1.0f32; 4];
        let mut out = vec![0f32; 4];
        rmsnorm(&x, &gain, 0.0, &mut out);
        let rms: f32 = (out.iter().map(|v| v * v).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-5);
        assert!((out[0] / out[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut x = vec![1.0f32, 2.0, 3.0];
        softmax(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut x = vec![1000.0f32, 1000.0];
        softmax(&mut x);
        assert!((x[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).sin()).collect();
        let before: f32 = x.iter().map(|v| v * v).sum();
        rope(&mut x, 2, 32, 17, 10000.0);
        let after: f32 = x.iter().map(|v| v * v).sum();
        assert!((before - after).abs() / before < 1e-5);
    }

    #[test]
    fn rope_pos_zero_is_identity() {
        let mut x: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let orig = x.clone();
        rope(&mut x, 1, 32, 0, 10000.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rope_is_relative() {
        // <RoPE(q, p), RoPE(k, p)> depends only on the content for equal
        // positions: rotating both by the same angle preserves dot product.
        let q: Vec<f32> = (0..8).map(|i| (i as f32).cos()).collect();
        let k: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).sin()).collect();
        let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        let mut q1 = q.clone();
        let mut k1 = k.clone();
        rope(&mut q1, 1, 8, 5, 10000.0);
        rope(&mut k1, 1, 8, 5, 10000.0);
        assert!((dot(&q1, &k1) - dot(&q, &k)).abs() < 1e-4);
    }

    #[test]
    fn silu_properties() {
        assert_eq!(silu(0.0), 0.0);
        assert!(silu(10.0) > 9.99);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
