//! Token sampling: greedy / temperature / top-k / top-p, deterministic via
//! the crate PRNG so serving runs are reproducible.

use super::ops::softmax;
use pallas_core::util::Rng;

/// Sampling configuration for one request.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// 0.0 → greedy argmax.
    pub temperature: f32,
    /// 0 → disabled.
    pub top_k: usize,
    /// 1.0 → disabled.
    pub top_p: f32,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0 }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self::default()
    }
    pub fn with_temperature(t: f32) -> Self {
        SamplingParams { temperature: t, ..Self::default() }
    }
}

/// Sample a token id from raw logits.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // Collect (logit, id), apply temperature.
    let mut items: Vec<(f32, u32)> =
        logits.iter().enumerate().map(|(i, &l)| (l / params.temperature, i as u32)).collect();
    // Top-k filter.
    if params.top_k > 0 && params.top_k < items.len() {
        items.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        items.truncate(params.top_k);
    } else {
        items.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    }
    let mut probs: Vec<f32> = items.iter().map(|it| it.0).collect();
    softmax(&mut probs);
    // Top-p (nucleus) filter over the sorted distribution.
    if params.top_p < 1.0 {
        let mut cum = 0f32;
        let mut cut = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= params.top_p {
                cut = i + 1;
                break;
            }
        }
        probs.truncate(cut);
        items.truncate(cut);
        let norm: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= norm;
        }
    }
    // Inverse-CDF draw.
    let r = rng.next_f32();
    let mut cum = 0f32;
    for (p, it) in probs.iter().zip(items.iter()) {
        cum += p;
        if r < cum {
            return it.1;
        }
    }
    items.last().unwrap().1
}

/// Greedy argmax (ties broken toward the lower id).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 5.0, -2.0, 4.9];
        let mut rng = Rng::new(1);
        assert_eq!(sample(&logits, &SamplingParams::greedy(), &mut rng), 1);
    }

    #[test]
    fn temperature_zero_is_greedy() {
        let logits = vec![1.0, 3.0, 2.0];
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            assert_eq!(sample(&logits, &SamplingParams::default(), &mut rng), 1);
        }
    }

    #[test]
    fn top_k_one_is_greedy() {
        let logits = vec![1.0, 3.0, 2.0];
        let p = SamplingParams { temperature: 1.0, top_k: 1, top_p: 1.0 };
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            assert_eq!(sample(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn sampling_respects_distribution() {
        // Token 0 has ~88% probability at T=1 (logit gap 2.0).
        let logits = vec![2.0, 0.0];
        let p = SamplingParams { temperature: 1.0, top_k: 0, top_p: 1.0 };
        let mut rng = Rng::new(4);
        let n = 5000;
        let zeros = (0..n).filter(|_| sample(&logits, &p, &mut rng) == 0).count();
        let frac = zeros as f64 / n as f64;
        assert!((frac - 0.8808).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn top_p_cuts_tail() {
        // Three tokens with probs ~ .665/.245/.090; top_p=0.7 keeps ≤ 2.
        let logits = vec![2.0, 1.0, 0.0];
        let p = SamplingParams { temperature: 1.0, top_k: 0, top_p: 0.7 };
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let t = sample(&logits, &p, &mut rng);
            assert!(t != 2, "tail token must be filtered");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let logits: Vec<f32> = (0..100).map(|i| ((i * 37) % 13) as f32 * 0.3).collect();
        let p = SamplingParams { temperature: 0.8, top_k: 40, top_p: 0.95 };
        let a: Vec<u32> = {
            let mut rng = Rng::new(9);
            (0..50).map(|_| sample(&logits, &p, &mut rng)).collect()
        };
        let b: Vec<u32> = {
            let mut rng = Rng::new(9);
            (0..50).map(|_| sample(&logits, &p, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }
}
