//! Model architecture configs. Shapes follow the BitNet b1.58 family used
//! in the paper's Table 7 (sizes per Wang et al. 2024b, "1-bit AI Infra"),
//! i.e. LLaMA-shaped transformers with ternary BitLinear projections.
//!
//! Sizes 700M…100B are used *shape-only* by the layer-composition bench
//! (no host here fits a dense 100B); the runnable presets (`tiny`,
//! `m100`) are small enough to train/infer end-to-end in CI.

/// Transformer hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub hidden: usize,
    /// FFN inner dimension (SwiGLU: three hidden×ffn matrices).
    pub ffn: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Grouped-query attention KV heads.
    pub n_kv_heads: usize,
    pub vocab_size: usize,
    pub max_seq_len: usize,
    pub rope_theta: f32,
    pub rms_eps: f32,
}

impl ModelConfig {
    /// Head dimension.
    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    /// KV projection output dimension.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Total parameter count (embeddings + blocks + head).
    pub fn param_count(&self) -> u64 {
        let h = self.hidden as u64;
        let f = self.ffn as u64;
        let v = self.vocab_size as u64;
        let kv = self.kv_dim() as u64;
        let per_layer = h * h          // wq
            + h * kv * 2               // wk, wv
            + h * h                    // wo
            + h * f * 3                // w_gate, w_up, w_down
            + h * 2; // two RMSNorm gains
        v * h          // tok embedding
            + self.n_layers as u64 * per_layer
            + h            // final norm
            + v * h // lm head (untied)
    }

    /// Ternary (BitLinear) parameter count — the weights the mpGEMM
    /// kernels see. Embeddings/norms stay high-precision (BitNet b1.58).
    pub fn ternary_param_count(&self) -> u64 {
        let h = self.hidden as u64;
        let f = self.ffn as u64;
        let kv = self.kv_dim() as u64;
        self.n_layers as u64 * (h * h * 2 + h * kv * 2 + h * f * 3)
    }

    /// The per-token weight-byte traffic for a given kernel bpw — the
    /// quantity that bounds decode tokens/s on a memory-bound CPU.
    pub fn decode_weight_bytes(&self, bpw: f64, embed_bpw: f64) -> f64 {
        let ternary = self.ternary_param_count() as f64 * bpw / 8.0;
        let head = (self.vocab_size * self.hidden) as f64 * embed_bpw / 8.0;
        ternary + head
    }

    /// All matmul shapes (m, k) of one decode step — the workload the
    /// kernel-level benches sweep (one GEMV per projection per layer +
    /// the LM head).
    pub fn gemv_shapes(&self) -> Vec<(usize, usize)> {
        vec![
            (self.hidden, self.hidden),  // wq
            (self.kv_dim(), self.hidden), // wk
            (self.kv_dim(), self.hidden), // wv
            (self.hidden, self.hidden),  // wo
            (self.ffn, self.hidden),     // w_gate
            (self.ffn, self.hidden),     // w_up
            (self.hidden, self.ffn),     // w_down
        ]
    }

    // ---- Runnable presets -------------------------------------------------

    /// ~1M params: unit/integration tests.
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny",
            hidden: 256,
            ffn: 768,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            vocab_size: 512,
            max_seq_len: 256,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
        }
    }

    /// ~100M params: the end-to-end serving example (examples/serve_e2e.rs).
    pub fn m100() -> ModelConfig {
        ModelConfig {
            name: "100M",
            hidden: 768,
            ffn: 2048,
            n_layers: 12,
            n_heads: 12,
            n_kv_heads: 12,
            vocab_size: 32000,
            max_seq_len: 2048,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
        }
    }

    // ---- Paper Table 7 shape presets --------------------------------------

    pub fn b700m() -> ModelConfig {
        ModelConfig { name: "700M", hidden: 1536, ffn: 4096, n_layers: 24, n_heads: 16, n_kv_heads: 16, vocab_size: 32000, max_seq_len: 2048, rope_theta: 10000.0, rms_eps: 1e-5 }
    }
    pub fn b1_5() -> ModelConfig {
        ModelConfig { name: "1.5B", hidden: 2048, ffn: 5632, n_layers: 24, n_heads: 32, n_kv_heads: 32, vocab_size: 32000, max_seq_len: 2048, rope_theta: 10000.0, rms_eps: 1e-5 }
    }
    pub fn b3_8() -> ModelConfig {
        // Paper's 3.8B uses hidden 3200; we round K dims up to the next
        // multiple of 256 so every kernel (TQ*/Q2_K need K % 256 == 0)
        // runs on the same shape — see DESIGN.md §Substitutions.
        ModelConfig { name: "3.8B", hidden: 3328, ffn: 8704, n_layers: 26, n_heads: 26, n_kv_heads: 26, vocab_size: 32000, max_seq_len: 2048, rope_theta: 10000.0, rms_eps: 1e-5 }
    }
    pub fn b7() -> ModelConfig {
        ModelConfig { name: "7B", hidden: 4096, ffn: 11008, n_layers: 32, n_heads: 32, n_kv_heads: 32, vocab_size: 32000, max_seq_len: 2048, rope_theta: 10000.0, rms_eps: 1e-5 }
    }
    pub fn b13() -> ModelConfig {
        ModelConfig { name: "13B", hidden: 5120, ffn: 13824, n_layers: 40, n_heads: 40, n_kv_heads: 40, vocab_size: 32000, max_seq_len: 2048, rope_theta: 10000.0, rms_eps: 1e-5 }
    }
    pub fn b30() -> ModelConfig {
        ModelConfig { name: "30B", hidden: 6656, ffn: 17920, n_layers: 60, n_heads: 52, n_kv_heads: 52, vocab_size: 32000, max_seq_len: 2048, rope_theta: 10000.0, rms_eps: 1e-5 }
    }
    pub fn b70() -> ModelConfig {
        ModelConfig { name: "70B", hidden: 8192, ffn: 28672, n_layers: 80, n_heads: 64, n_kv_heads: 8, vocab_size: 32000, max_seq_len: 2048, rope_theta: 10000.0, rms_eps: 1e-5 }
    }
    pub fn b100() -> ModelConfig {
        ModelConfig { name: "100B", hidden: 9216, ffn: 32768, n_layers: 88, n_heads: 72, n_kv_heads: 8, vocab_size: 32000, max_seq_len: 2048, rope_theta: 10000.0, rms_eps: 1e-5 }
    }

    /// The paper's Table 7 size ladder (shape presets).
    pub fn table7_sizes() -> Vec<ModelConfig> {
        vec![
            Self::b700m(),
            Self::b1_5(),
            Self::b3_8(),
            Self::b7(),
            Self::b13(),
            Self::b30(),
            Self::b70(),
            Self::b100(),
        ]
    }

    /// Look up any preset by name.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        let mut all = Self::table7_sizes();
        all.push(Self::tiny());
        all.push(Self::m100());
        all.into_iter().find(|c| c.name.eq_ignore_ascii_case(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_land_near_nominal_sizes() {
        let cases = [
            (ModelConfig::b700m(), 0.7e9),
            (ModelConfig::b1_5(), 1.5e9),
            (ModelConfig::b3_8(), 3.8e9),
            (ModelConfig::b7(), 7e9),
            (ModelConfig::b13(), 13e9),
            (ModelConfig::b30(), 30e9),
            (ModelConfig::b70(), 70e9),
            (ModelConfig::b100(), 100e9),
        ];
        for (cfg, want) in cases {
            let got = cfg.param_count() as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.35, "{}: {got:.3e} vs nominal {want:.1e} (rel {rel:.2})", cfg.name);
        }
    }

    #[test]
    fn m100_is_about_100m() {
        let got = ModelConfig::m100().param_count() as f64;
        assert!((0.8e8..1.6e8).contains(&got), "{got:.3e}");
    }

    #[test]
    fn head_dims_divide() {
        for cfg in ModelConfig::table7_sizes() {
            assert_eq!(cfg.hidden % cfg.n_heads, 0, "{}", cfg.name);
            assert_eq!(cfg.n_heads % cfg.n_kv_heads, 0, "{}", cfg.name);
            // All GEMV K dims must satisfy the strictest kernel (K % 256).
            for (_, k) in cfg.gemv_shapes() {
                assert_eq!(k % 256, 0, "{} k={k}", cfg.name);
            }
        }
    }

    #[test]
    fn ternary_fraction_dominates() {
        let cfg = ModelConfig::b7();
        let frac = cfg.ternary_param_count() as f64 / cfg.param_count() as f64;
        assert!(frac > 0.9, "ternary fraction {frac}");
    }

    #[test]
    fn preset_lookup() {
        assert_eq!(ModelConfig::preset("3.8B").unwrap().hidden, 3328);
        assert_eq!(ModelConfig::preset("tiny").unwrap().n_layers, 2);
        assert!(ModelConfig::preset("404B").is_none());
    }
}
