//! Synthetic BitNet b1.58 checkpoint generation.
//!
//! We do not have the proprietary 700M…100B checkpoints (see DESIGN.md
//! §Substitutions); tokens/s depends on shapes and storage format, not
//! trained values, so benchmarks and serving examples run on
//! deterministic pseudo-random ternary weights. Scales are chosen to keep
//! activations O(1) through depth (`scale = 1/√(0.5·K)` matches the ~50%
//! non-zero density of `Rng::next_ternary`).

use super::config::ModelConfig;
use pallas_kernels::kernels::quant::TernaryWeights;
use pallas_core::util::Rng;

/// Unpacked weights for one transformer layer (ternary projections +
/// f32 norm gains).
pub struct LayerWeights {
    pub wq: TernaryWeights,
    pub wk: TernaryWeights,
    pub wv: TernaryWeights,
    pub wo: TernaryWeights,
    pub w_gate: TernaryWeights,
    pub w_up: TernaryWeights,
    pub w_down: TernaryWeights,
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
}

/// A full unpacked checkpoint (interchange form between the synthetic
/// generator / BTNZ container and the packed `Transformer`).
pub struct Checkpoint {
    pub config: ModelConfig,
    /// vocab × hidden token embedding (f32, high-precision per BitNet).
    pub tok_embed: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    /// vocab × hidden LM head, kept in f16-representable f32.
    pub lm_head: Vec<f32>,
}

/// Deterministic ternary matrix with BitLinear-friendly scale.
pub fn synth_ternary(rng: &mut Rng, m: usize, k: usize) -> TernaryWeights {
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let scale = 1.0 / (0.5 * k as f32).sqrt();
    TernaryWeights::from_ternary(q, m, k, scale)
}

impl Checkpoint {
    /// Generate a synthetic checkpoint for `cfg`, fully determined by
    /// `seed`.
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let h = cfg.hidden;
        let kv = cfg.kv_dim();
        let mut tok_embed = vec![0f32; cfg.vocab_size * h];
        rng.fill_gaussian(&mut tok_embed, 1.0);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                wq: synth_ternary(&mut rng, h, h),
                wk: synth_ternary(&mut rng, kv, h),
                wv: synth_ternary(&mut rng, kv, h),
                wo: synth_ternary(&mut rng, h, h),
                w_gate: synth_ternary(&mut rng, cfg.ffn, h),
                w_up: synth_ternary(&mut rng, cfg.ffn, h),
                w_down: synth_ternary(&mut rng, h, cfg.ffn),
                attn_norm: vec![1.0; h],
                ffn_norm: vec![1.0; h],
            })
            .collect();
        let mut lm_head = vec![0f32; cfg.vocab_size * h];
        // Small head scale keeps logits in a sane softmax range.
        rng.fill_gaussian(&mut lm_head, 1.0 / (h as f32).sqrt());
        Checkpoint {
            config: cfg.clone(),
            tok_embed,
            layers,
            final_norm: vec![1.0; h],
            lm_head,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = ModelConfig::tiny();
        let a = Checkpoint::synthetic(&cfg, 42);
        let b = Checkpoint::synthetic(&cfg, 42);
        assert_eq!(a.layers[0].wq.q, b.layers[0].wq.q);
        assert_eq!(a.tok_embed, b.tok_embed);
        let c = Checkpoint::synthetic(&cfg, 43);
        assert_ne!(a.layers[0].wq.q, c.layers[0].wq.q);
    }

    #[test]
    fn shapes_match_config() {
        let cfg = ModelConfig::tiny();
        let ck = Checkpoint::synthetic(&cfg, 1);
        assert_eq!(ck.layers.len(), cfg.n_layers);
        let l = &ck.layers[0];
        assert_eq!(l.wq.m, cfg.hidden);
        assert_eq!(l.wk.m, cfg.kv_dim());
        assert_eq!(l.w_gate.m, cfg.ffn);
        assert_eq!(l.w_down.k, cfg.ffn);
        assert_eq!(ck.tok_embed.len(), cfg.vocab_size * cfg.hidden);
    }

    #[test]
    fn weight_scale_preserves_variance() {
        let mut rng = Rng::new(7);
        let (m, k) = (256, 256);
        let w = synth_ternary(&mut rng, m, k);
        let wd = w.dequantize();
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f32> = (0..m)
            .map(|r| (0..k).map(|i| wd[r * k + i] * x[i]).sum())
            .collect();
        let var = y.iter().map(|v| v * v).sum::<f32>() / m as f32;
        assert!((0.5..2.0).contains(&var), "output variance {var}");
    }
}
