//! The BitNet b1.58 transformer forward pass, with a chunked (GEMM)
//! prefill path and a batched decode path — the compute engine behind the
//! serving coordinator.
//!
//! Key properties:
//! * every projection goes through [`BitLinear`] → pluggable mpGEMM kernel;
//! * decode over a continuous batch runs each projection as one GEMM over
//!   the batch rows (weights streamed once per batch, the memory-bound win
//!   of dynamic batching);
//! * prefill processes the whole prompt as one chunk (compute-bound GEMM),
//!   matching the paper's decode/prefill distinction (§Limitations).

use super::bitlinear::BitLinear;
use super::config::ModelConfig;
use super::ops::{rmsnorm, rope, swiglu};
use super::weights::Checkpoint;
use pallas_core::arena::{AttnWorkspace, KvArena, KvDtype};
use pallas_kernels::kernels::baselines::f16_mad::dot_f16;
use pallas_kernels::kernels::tuner::{DispatchPlan, Role};
use pallas_kernels::kernels::{kernel_for, Dispatch, PrepareStats, PreparedActivations, QuantType};
use pallas_core::threadpool::{shared_pool, ThreadPool};
use pallas_core::util::f32_to_f16;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// High-precision (f16-stored) dense layer for the LM head.
pub struct DenseF16 {
    data: Vec<u8>,
    pub m: usize,
    pub k: usize,
}

impl DenseF16 {
    pub fn new(w: &[f32], m: usize, k: usize) -> DenseF16 {
        assert_eq!(w.len(), m * k);
        let mut data = vec![0u8; m * k * 2];
        for (chunk, &v) in data.chunks_exact_mut(2).zip(w.iter()) {
            chunk.copy_from_slice(&f32_to_f16(v).to_le_bytes());
        }
        DenseF16 { data, m, k }
    }

    pub fn forward(&self, x: &[f32], out: &mut [f32], pool: &ThreadPool) {
        assert_eq!(x.len(), self.k);
        assert_eq!(out.len(), self.m);
        let row_bytes = self.k * 2;
        let chunks = (pool.size() * 4).min(self.m);
        let rows_per = pallas_core::util::ceil_div(self.m, chunks);
        let out_ptr = SendPtr(out.as_mut_ptr());
        pool.parallel_for(chunks, |c| {
            let out_ptr = &out_ptr;
            let lo = c * rows_per;
            if lo >= self.m {
                return;
            }
            let hi = ((c + 1) * rows_per).min(self.m);
            // SAFETY: chunks cover disjoint [lo, hi) row ranges of `out`,
            // so each parallel task writes a non-overlapping slice.
            let slice = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo), hi - lo) };
            for (o, r) in slice.iter_mut().zip(lo..hi) {
                *o = dot_f16(&self.data[r * row_bytes..(r + 1) * row_bytes], x);
            }
        });
    }

    pub fn weight_bytes(&self) -> usize {
        self.data.len()
    }
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: the pointer targets a buffer that outlives the parallel_for
// call, and tasks write disjoint ranges of it.
unsafe impl Send for SendPtr {}
// SAFETY: as above.
unsafe impl Sync for SendPtr {}

/// Packed weights for one layer.
pub struct Layer {
    pub wq: BitLinear,
    pub wk: BitLinear,
    pub wv: BitLinear,
    pub wo: BitLinear,
    pub w_gate: BitLinear,
    pub w_up: BitLinear,
    pub w_down: BitLinear,
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
}

/// Per-sequence inference state: a **page-table view** into a
/// [`KvArena`] — position plus a sequence id whose pages live in the
/// arena. The session owns no KV buffers itself: standalone sessions
/// ([`Session::new`]) carry a private arena sized for their capacity,
/// serving sessions ([`Session::shared`]) all point at the engine's one
/// shared arena, where the scheduler reserves their pages.
pub struct Session {
    pub pos: usize,
    pub capacity: usize,
    seq: u64,
    arena: Arc<Mutex<KvArena>>,
    /// Persistent attention workspace (score buffer), reused across every
    /// attend so steady-state decode attention allocates nothing. Behind
    /// its own mutex because `attend` takes `&self` (the arena lock
    /// protects KV pages, not per-session scratch).
    attn_ws: Mutex<AttnWorkspace>,
}

impl Session {
    /// Standalone session backed by a private f32 arena sized for
    /// `capacity` tokens (the non-serving paths: `run`, eval, tests).
    pub fn new(n_layers: usize, kv_dim: usize, capacity: usize) -> Session {
        Self::with_dtype(n_layers, kv_dim, capacity, KvDtype::F32)
    }

    /// Standalone session with an explicit KV element type
    /// (`--kv-dtype f16` halves resident KV bytes).
    pub fn with_dtype(
        n_layers: usize,
        kv_dim: usize,
        capacity: usize,
        dtype: KvDtype,
    ) -> Session {
        let arena = KvArena::new(n_layers, kv_dim, capacity, dtype);
        Session {
            pos: 0,
            capacity,
            seq: 0,
            arena: Arc::new(Mutex::new(arena)),
            attn_ws: Mutex::new(AttnWorkspace::new()),
        }
    }

    /// A view into a shared arena: pages for `seq` are reserved there by
    /// the serving scheduler (or lazily on append when standalone code
    /// drives a shared arena directly).
    pub fn shared(arena: Arc<Mutex<KvArena>>, seq: u64, capacity: usize) -> Session {
        Session { pos: 0, capacity, seq, arena, attn_ws: Mutex::new(AttnWorkspace::new()) }
    }

    fn append(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.capacity, "KV cache overflow at pos {pos}");
        let mut arena = self.arena.lock().unwrap();
        // Idempotent for already-reserved pages (the serving scheduler
        // reserves ahead of every step); mints lazily for standalone
        // sessions growing into their private arena.
        assert!(arena.reserve(self.seq, pos + 1), "KV arena exhausted at pos {pos}");
        arena.append(self.seq, layer, pos, k, v);
    }

    /// Attention for one query row over this session's cached context
    /// (positions `0..ctx_len`) in `layer`, through the session's
    /// persistent workspace and (optionally) head-parallel on `pool`;
    /// see [`KvArena::attend_with`].
    #[allow(clippy::too_many_arguments)]
    fn attend(
        &self,
        layer: usize,
        q: &[f32],
        ctx_len: usize,
        n_heads: usize,
        n_kv_heads: usize,
        head_dim: usize,
        scale: f32,
        out: &mut [f32],
        pool: Option<&ThreadPool>,
    ) {
        let mut ws = self.attn_ws.lock().unwrap();
        self.arena.lock().unwrap().attend_with(
            &mut ws, self.seq, layer, q, ctx_len, n_heads, n_kv_heads, head_dim, scale, out, pool,
        );
    }

    /// Attention-workspace counters `(allocs, reuses)` — the observable
    /// behind the "steady-state decode attention allocates nothing"
    /// guarantee (allocs flatline once the context stops growing past
    /// its previous peak; see `rust/tests/prepare.rs` style asserts).
    pub fn attn_workspace_stats(&self) -> (u64, u64) {
        let ws = self.attn_ws.lock().unwrap();
        (ws.allocs(), ws.reuses())
    }

    /// Bytes of KV storage actually resident for this sequence (held
    /// pages × page bytes × dtype width) — not the worst-case capacity,
    /// which the pre-paged layout eagerly allocated and reported.
    pub fn kv_bytes(&self) -> usize {
        self.arena.lock().unwrap().held_bytes(self.seq)
    }

    /// Pages this sequence currently holds in its arena.
    pub fn held_pages(&self) -> usize {
        self.arena.lock().unwrap().held_pages(self.seq)
    }

    /// Reset the position for reuse (appends overwrite from 0). Page
    /// ownership is untouched: in serving, the scheduler releases pages
    /// at preemption/finish — and may have *re-reserved* them for a
    /// same-step re-admission by the time the engine resets the session,
    /// so releasing here would drop a live reservation. Standalone
    /// sessions simply keep their pages and overwrite them.
    pub fn clear(&mut self) {
        self.pos = 0;
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // Return pages to a shared arena when the engine retires the
        // session without an explicit release; harmless double-release
        // otherwise (release of an unknown seq is a no-op).
        if let Ok(mut arena) = self.arena.lock() {
            arena.release(self.seq);
        }
    }
}

/// Cumulative wall-clock split of the forward pass by phase: attention
/// (the paged-KV fused attend), mpGEMM (every BitLinear projection and
/// the f16 LM head, including their prepare-once preprocessing), and
/// other ops (norms, RoPE, SwiGLU, residual/activation plumbing).
/// Atomic so concurrent forward passes accumulate without a lock; the
/// serving engine mirrors these into its metrics once per step.
#[derive(Default)]
pub struct PhaseStats {
    attn_ns: AtomicU64,
    gemm_ns: AtomicU64,
    other_ns: AtomicU64,
}

impl PhaseStats {
    /// `(attention, mpGEMM, other-ops)` microseconds accumulated so far.
    pub fn snapshot_us(&self) -> (u64, u64, u64) {
        (
            self.attn_ns.load(Ordering::Relaxed) / 1_000,
            self.gemm_ns.load(Ordering::Relaxed) / 1_000,
            self.other_ns.load(Ordering::Relaxed) / 1_000,
        )
    }
}

/// The packed model.
pub struct Transformer {
    pub cfg: ModelConfig,
    /// Representative kernel: the fixed kernel, or (under `Auto`
    /// dispatch) the profile's pick for the h×h attention projections.
    pub qtype: QuantType,
    /// The per-call kernel resolver every ternary projection routes
    /// through — packing picked the n=1 primary; `forward_batch`
    /// re-resolves per call with the real (layer, role, batch) context.
    pub plan: DispatchPlan,
    pub tok_embed: Vec<f32>,
    pub layers: Vec<Layer>,
    pub final_norm: Vec<f32>,
    pub lm_head: DenseF16,
    /// The compute pool. A handle to the process-wide
    /// [`shared_pool`] by default ([`Transformer::from_checkpoint_plan`]),
    /// so the engine, the tuner and every model instance fork onto one
    /// worker set instead of layering competing pools; tests inject a
    /// private pool via [`Transformer::from_checkpoint_plan_pool`].
    pub pool: Arc<ThreadPool>,
    /// Persistent prepare-once workspace: per-input activation batches
    /// shared across the projections consuming each layer input (wq/wk/wv
    /// share one, gate/up share one), with buffers recycled across calls
    /// so steady-state decode allocates nothing in the prepare path.
    prepare_ws: Mutex<PreparedActivations>,
    /// Per-phase time accounting for every forward pass (see
    /// [`PhaseStats`]); read via [`Transformer::phase_us`].
    pub phase: PhaseStats,
}

impl Transformer {
    /// Pack a checkpoint for the given kernel, with `n_threads` compute
    /// threads.
    pub fn from_checkpoint(ck: &Checkpoint, qtype: QuantType, n_threads: usize) -> Transformer {
        Self::from_checkpoint_dispatch(ck, Dispatch::Fixed(qtype), n_threads)
    }

    /// Pack a checkpoint routing every projection through a [`Dispatch`]
    /// policy — with `Dispatch::Auto` each (m, k) projection shape packs
    /// with the kernel its tuning profile measured fastest.
    pub fn from_checkpoint_dispatch(
        ck: &Checkpoint,
        dispatch: Dispatch,
        n_threads: usize,
    ) -> Transformer {
        Self::from_checkpoint_plan(ck, DispatchPlan::new(dispatch), n_threads)
    }

    /// Pack a checkpoint under a full [`DispatchPlan`]. Each projection's
    /// *primary* packing is the plan's pick for its (layer, role, m, k)
    /// at n=1 (the decode regime); other regimes pack alternates lazily
    /// on first routed call (or eagerly via [`Transformer::prepack`]).
    pub fn from_checkpoint_plan(
        ck: &Checkpoint,
        plan: DispatchPlan,
        n_threads: usize,
    ) -> Transformer {
        Self::from_checkpoint_plan_pool(ck, plan, shared_pool(n_threads.max(1)))
    }

    /// [`Transformer::from_checkpoint_plan`] with an explicit compute
    /// pool. The NUMA-placement tests need a pool over a mock topology —
    /// the process-wide [`shared_pool`] is sized and placed once, so a
    /// test cannot re-seat it — and embedders may want an isolated pool.
    /// On a multi-node pool, every primary packed tensor is
    /// NUMA-localized so each node's row share lives in its memory.
    pub fn from_checkpoint_plan_pool(
        ck: &Checkpoint,
        plan: DispatchPlan,
        pool: Arc<ThreadPool>,
    ) -> Transformer {
        let cfg = ck.config.clone();
        let primary = |li: usize, role: Role, w: &pallas_kernels::kernels::quant::TernaryWeights| {
            let want = plan.select(li, role, w.m, w.k, 1);
            let qtype = if w.k % kernel_for(want).info().k_multiple == 0 {
                want
            } else if let Dispatch::Auto(p) = plan.dispatch() {
                // A hand-written profile entry/override can name a kernel
                // whose K alignment doesn't fit this projection; degrade
                // to the profile default (like the lazy-alternate path)
                // instead of panicking mid-construction.
                eprintln!(
                    "dispatch: layer {li} {} {}x{}: {} needs K % {} == 0; using default {}",
                    role.name(),
                    w.m,
                    w.k,
                    want.name(),
                    kernel_for(want).info().k_multiple,
                    p.default.name()
                );
                p.default
            } else {
                // Fixed dispatch keeps the explicit, loud misconfiguration
                // panic (BitLinear::new asserts).
                want
            };
            BitLinear::new(w, qtype)
        };
        let mut layers: Vec<Layer> = ck
            .layers
            .iter()
            .enumerate()
            .map(|(li, l)| Layer {
                wq: primary(li, Role::Qkv, &l.wq),
                wk: primary(li, Role::Qkv, &l.wk),
                wv: primary(li, Role::Qkv, &l.wv),
                wo: primary(li, Role::O, &l.wo),
                w_gate: primary(li, Role::Gate, &l.w_gate),
                w_up: primary(li, Role::Up, &l.w_up),
                w_down: primary(li, Role::Down, &l.w_down),
                attn_norm: l.attn_norm.clone(),
                ffn_norm: l.ffn_norm.clone(),
            })
            .collect();
        if pool.n_nodes() > 1 {
            // First-touch each primary tensor's row shares from their
            // owning nodes so the decode-path weight stream reads local
            // memory (alternates pack lazily and keep default placement).
            for layer in layers.iter_mut() {
                for lin in [
                    &mut layer.wq,
                    &mut layer.wk,
                    &mut layer.wv,
                    &mut layer.wo,
                    &mut layer.w_gate,
                    &mut layer.w_up,
                    &mut layer.w_down,
                ] {
                    lin.qtensor.numa_localize(&pool);
                }
            }
        }
        Transformer {
            lm_head: DenseF16::new(&ck.lm_head, cfg.vocab_size, cfg.hidden),
            tok_embed: ck.tok_embed.clone(),
            final_norm: ck.final_norm.clone(),
            layers,
            qtype: plan.dispatch().representative(cfg.hidden, cfg.hidden),
            plan,
            cfg,
            pool,
            prepare_ws: Mutex::new(PreparedActivations::new()),
            phase: PhaseStats::default(),
        }
    }

    /// Cumulative `(attention, mpGEMM, other-ops)` forward-pass
    /// microseconds — the paper-style decode profile (`--verbose` and
    /// the engine's phase metrics render this split).
    pub fn phase_us(&self) -> (u64, u64, u64) {
        self.phase.snapshot_us()
    }

    /// Prepare-cache counter snapshot (hits/misses/buffer reuse) — the
    /// observability behind the "prepare runs once per role-group" and
    /// "steady-state decode is allocation-free" guarantees.
    pub fn prepare_stats(&self) -> PrepareStats {
        self.prepare_ws.lock().unwrap().stats()
    }

    /// Synthetic model shortcut (tests, examples, benches).
    pub fn synthetic(cfg: &ModelConfig, qtype: QuantType, seed: u64) -> Transformer {
        Self::from_checkpoint(&Checkpoint::synthetic(cfg, seed), qtype, 1)
    }

    /// The distinct (m, k, primary kernel) combinations across **all**
    /// layers — what `--verbose` prints so an operator can audit
    /// auto-dispatch decisions. Per-layer overrides make layers diverge,
    /// so a shape can legitimately appear once per kernel it runs under.
    pub fn kernel_summary(&self) -> Vec<(usize, usize, QuantType)> {
        let mut out: Vec<(usize, usize, QuantType)> = Vec::new();
        for layer in &self.layers {
            for (_, lin) in Self::role_layers(layer) {
                let item = (lin.m, lin.k, lin.qtype());
                if !out.contains(&item) {
                    out.push(item);
                }
            }
        }
        out.sort_unstable_by_key(|&(m, k, _)| (m, k));
        out
    }

    pub fn new_session(&self, capacity: usize) -> Session {
        self.new_session_dtype(capacity, KvDtype::F32)
    }

    /// Standalone session with an explicit KV element type.
    pub fn new_session_dtype(&self, capacity: usize, dtype: KvDtype) -> Session {
        Session::with_dtype(
            self.cfg.n_layers,
            self.cfg.kv_dim(),
            capacity.min(self.cfg.max_seq_len),
            dtype,
        )
    }

    /// Serving session: a page-table view into the engine's shared
    /// arena, which must have been built for this model's layer count
    /// and KV dim (see `coordinator::engine`).
    pub fn new_session_shared(
        &self,
        arena: &Arc<Mutex<KvArena>>,
        seq: u64,
        capacity: usize,
    ) -> Session {
        Session::shared(Arc::clone(arena), seq, capacity.min(self.cfg.max_seq_len))
    }

    /// One layer's projections with the [`Role`] each plays — the order
    /// and grouping the dispatch plan keys on.
    fn role_layers(layer: &Layer) -> [(Role, &BitLinear); 7] {
        [
            (Role::Qkv, &layer.wq),
            (Role::Qkv, &layer.wk),
            (Role::Qkv, &layer.wv),
            (Role::O, &layer.wo),
            (Role::Gate, &layer.w_gate),
            (Role::Up, &layer.w_up),
            (Role::Down, &layer.w_down),
        ]
    }

    /// Eagerly materialize every packing the plan can select at the
    /// given batch widths (e.g. `[1, max_batch]` before serving), so the
    /// first routed request doesn't pay the repack latency.
    pub fn prepack(&self, batches: &[usize]) {
        for (li, layer) in self.layers.iter().enumerate() {
            for (role, lin) in Self::role_layers(layer) {
                for &n in batches {
                    let n = n.max(1);
                    let want = self.plan.select(li, role, lin.m, lin.k, n);
                    let got = lin.prepack(want);
                    if got != want {
                        self.plan.note_degraded(lin.m, lin.k, n, want, got);
                    }
                }
            }
        }
    }

    /// Per-layer, per-phase kernel winners under the plan: one line per
    /// run of layers with identical picks, showing each role's decode
    /// (n=1) vs prefill (n=`prefill_n`) kernel as `role=dec/pre`
    /// (collapsed to `role=k` when the phases agree). What `--verbose`
    /// prints so an operator can audit phase-aware dispatch.
    pub fn plan_summary(&self, prefill_n: usize) -> Vec<String> {
        let sig = |li: usize| -> String {
            Self::role_layers(&self.layers[li])
                .iter()
                .map(|&(role, lin)| {
                    let (d, _) = self.plan.dispatch().select_for(li, role, lin.m, lin.k, 1);
                    let (p, _) =
                        self.plan.dispatch().select_for(li, role, lin.m, lin.k, prefill_n.max(2));
                    if d == p {
                        format!("{}={}", role.name(), d.name())
                    } else {
                        format!("{}={}/{}", role.name(), d.name(), p.name())
                    }
                })
                .collect::<Vec<_>>()
                .join(" ")
        };
        let mut out = Vec::new();
        if self.layers.is_empty() {
            return out;
        }
        let mut start = 0usize;
        let mut cur = sig(0);
        for li in 1..=self.layers.len() {
            let next = if li < self.layers.len() { sig(li) } else { String::new() };
            if li == self.layers.len() || next != cur {
                if start == li - 1 {
                    out.push(format!("layer {}: {}", start, cur));
                } else {
                    out.push(format!("layers {}-{}: {}", start, li - 1, cur));
                }
                start = li;
                cur = next;
            }
        }
        // Pack-time sparsity: measured weight-level zero fraction
        // (weighted by parameter count) and how many projections' primary
        // packing carries the block-skip layout.
        let mut weights = 0f64;
        let mut zeros = 0f64;
        let mut sparse_ct = 0usize;
        let mut total = 0usize;
        for layer in &self.layers {
            for (_, lin) in Self::role_layers(layer) {
                let params = (lin.m * lin.k) as f64;
                weights += params;
                zeros += params * lin.zero_fraction;
                total += 1;
                if lin.sparse_layout() {
                    sparse_ct += 1;
                }
            }
        }
        if weights > 0.0 {
            out.push(format!(
                "sparsity: {:.1}% zero weights; block-skip layout on {sparse_ct}/{total} projections",
                100.0 * zeros / weights
            ));
        }
        out
    }

    /// Packed weight bytes streamed per decoded token (primary packings
    /// only — what one n=1 decode step reads).
    pub fn weight_bytes_per_token(&self) -> usize {
        let layers: usize = self
            .layers
            .iter()
            .map(|l| {
                Self::role_layers(l).iter().map(|(_, lin)| lin.primary_weight_bytes()).sum::<usize>()
            })
            .sum();
        layers + self.lm_head.weight_bytes()
    }

    /// Total resident packed weight bytes, including every materialized
    /// alternate — the bounded memory cost of multi-packed dispatch.
    pub fn resident_weight_bytes(&self) -> usize {
        let layers: usize = self
            .layers
            .iter()
            .map(|l| Self::role_layers(l).iter().map(|(_, lin)| lin.weight_bytes()).sum::<usize>())
            .sum();
        layers + self.lm_head.weight_bytes()
    }

    /// Prefill `tokens` into `session` as one chunk; returns the logits of
    /// the final position.
    pub fn prefill(&self, session: &mut Session, tokens: &[u32]) -> Vec<f32> {
        assert!(!tokens.is_empty());
        let n = tokens.len();
        let h = self.cfg.hidden;
        let base_pos = session.pos;
        // Embed the chunk.
        let mut xs = vec![0f32; n * h];
        for (i, &t) in tokens.iter().enumerate() {
            xs[i * h..(i + 1) * h]
                .copy_from_slice(&self.tok_embed[t as usize * h..(t as usize + 1) * h]);
        }
        let positions: Vec<usize> = (0..n).map(|i| base_pos + i).collect();
        {
            let mut refs = [&mut *session];
            for (li, layer) in self.layers.iter().enumerate() {
                self.block_chunk(layer, li, &mut xs, n, &positions, &mut refs, true);
            }
        }
        session.pos = base_pos + n;
        self.logits_for(&xs[(n - 1) * h..])
    }

    /// One decode step for a single sequence.
    pub fn decode_step(&self, session: &mut Session, token: u32) -> Vec<f32> {
        let mut sessions = [session];
        let mut out = self.decode_batch(&mut sessions, &[token]);
        out.pop().unwrap()
    }

    /// One decode step for a continuous batch: `tokens[i]` is appended to
    /// `sessions[i]`. Each projection runs as a single GEMM over the batch.
    /// Returns one logits vector per sequence.
    pub fn decode_batch(&self, sessions: &mut [&mut Session], tokens: &[u32]) -> Vec<Vec<f32>> {
        assert_eq!(sessions.len(), tokens.len());
        let n = tokens.len();
        let h = self.cfg.hidden;
        let mut xs = vec![0f32; n * h];
        for (i, &t) in tokens.iter().enumerate() {
            xs[i * h..(i + 1) * h]
                .copy_from_slice(&self.tok_embed[t as usize * h..(t as usize + 1) * h]);
        }
        let positions: Vec<usize> = sessions.iter().map(|s| s.pos).collect();
        for (li, layer) in self.layers.iter().enumerate() {
            self.block_chunk(layer, li, &mut xs, n, &positions, sessions, false);
        }
        for s in sessions.iter_mut() {
            s.pos += 1;
        }
        (0..n).map(|i| self.logits_for(&xs[i * h..(i + 1) * h])).collect()
    }

    /// One transformer block over a chunk of `n` rows.
    ///
    /// `prefill` mode: all rows belong to `sessions[0]` at ascending
    /// positions (causal attention inside the chunk). Batch mode: row `i`
    /// belongs to `sessions[i]` at `positions[i]`.
    #[allow(clippy::too_many_arguments)]
    fn block_chunk(
        &self,
        layer: &Layer,
        li: usize,
        xs: &mut [f32],
        n: usize,
        positions: &[usize],
        sessions: &mut [&mut Session],
        prefill: bool,
    ) {
        let cfg = &self.cfg;
        let h = cfg.hidden;
        let hd = cfg.head_dim();
        let kvd = cfg.kv_dim();

        // Phase accounting: attention and mpGEMM segments are timed
        // directly (the GEMM timers bracket the workspace lock, so
        // prepare preprocessing and any lock wait count as projection
        // cost); "other" is the block remainder (norms, RoPE, SwiGLU,
        // residuals, KV appends).
        let t_block = Instant::now();
        let mut attn_ns = 0u64;
        let mut gemm_ns = 0u64;

        // ---- Attention ----
        let mut normed = vec![0f32; n * h];
        for i in 0..n {
            rmsnorm(&xs[i * h..(i + 1) * h], &layer.attn_norm, cfg.rms_eps, &mut normed[i * h..(i + 1) * h]);
        }
        let mut q = vec![0f32; n * h];
        let mut k = vec![0f32; n * kvd];
        let mut v = vec![0f32; n * kvd];
        // Phase-aware dispatch: every projection re-resolves its kernel
        // per call with the effective batch width (prefill chunk length
        // or decode batch), so one layer can run different kernels across
        // phases (paper §3: TL1/TL2 for compute-bound prefill, I2_S for
        // memory-bound decode). Projections sharing an input also share
        // its preprocessing through the prepare-once workspace: wq/wk/wv
        // consume one prepared batch, gate/up another (Algorithms 1–2
        // preprocessing runs once per role-group, not per projection).
        // The workspace lock is scoped to each projection group so the
        // attention/FFN compute between them never sits inside the
        // critical section (concurrent forward passes stay parallel).
        let t = Instant::now();
        {
            let mut acts = self.prepare_ws.lock().unwrap();
            acts.begin_input();
            layer.wq.forward_batch_cached(&self.plan, li, Role::Qkv, &normed, n, &mut q, &self.pool, &mut acts);
            layer.wk.forward_batch_cached(&self.plan, li, Role::Qkv, &normed, n, &mut k, &self.pool, &mut acts);
            layer.wv.forward_batch_cached(&self.plan, li, Role::Qkv, &normed, n, &mut v, &self.pool, &mut acts);
        }
        gemm_ns += t.elapsed().as_nanos() as u64;
        for i in 0..n {
            rope(&mut q[i * h..(i + 1) * h], cfg.n_heads, hd, positions[i], cfg.rope_theta);
            rope(&mut k[i * kvd..(i + 1) * kvd], cfg.n_kv_heads, hd, positions[i], cfg.rope_theta);
            let s = if prefill { &mut *sessions[0] } else { &mut *sessions[i] };
            s.append(li, positions[i], &k[i * kvd..(i + 1) * kvd], &v[i * kvd..(i + 1) * kvd]);
        }
        // Scaled dot-product attention per row against its session's
        // cache, read through the page table with the f16→f32 decode
        // fused into the SIMD dot/axpy loops, head-parallel on the
        // compute pool (see KvArena::attend_with).
        let mut attn_out = vec![0f32; n * h];
        let scale = 1.0 / (hd as f32).sqrt();
        let t = Instant::now();
        for i in 0..n {
            let s: &Session = if prefill { &*sessions[0] } else { &*sessions[i] };
            let ctx_len = positions[i] + 1; // causal: everything ≤ this position
            s.attend(
                li,
                &q[i * h..(i + 1) * h],
                ctx_len,
                cfg.n_heads,
                cfg.n_kv_heads,
                hd,
                scale,
                &mut attn_out[i * h..(i + 1) * h],
                Some(&self.pool),
            );
        }
        attn_ns += t.elapsed().as_nanos() as u64;
        let mut proj = vec![0f32; n * h];
        let t = Instant::now();
        {
            let mut acts = self.prepare_ws.lock().unwrap();
            acts.begin_input();
            layer.wo.forward_batch_cached(&self.plan, li, Role::O, &attn_out, n, &mut proj, &self.pool, &mut acts);
        }
        gemm_ns += t.elapsed().as_nanos() as u64;
        for (x, p) in xs.iter_mut().zip(proj.iter()) {
            *x += p;
        }

        // ---- FFN (SwiGLU) ----
        for i in 0..n {
            rmsnorm(&xs[i * h..(i + 1) * h], &layer.ffn_norm, cfg.rms_eps, &mut normed[i * h..(i + 1) * h]);
        }
        let f = cfg.ffn;
        let mut gate = vec![0f32; n * f];
        let mut up = vec![0f32; n * f];
        let t = Instant::now();
        {
            let mut acts = self.prepare_ws.lock().unwrap();
            acts.begin_input();
            layer.w_gate.forward_batch_cached(&self.plan, li, Role::Gate, &normed, n, &mut gate, &self.pool, &mut acts);
            layer.w_up.forward_batch_cached(&self.plan, li, Role::Up, &normed, n, &mut up, &self.pool, &mut acts);
        }
        gemm_ns += t.elapsed().as_nanos() as u64;
        let mut act = vec![0f32; n * f];
        swiglu(&gate, &up, &mut act);
        let mut down = vec![0f32; n * h];
        let t = Instant::now();
        {
            let mut acts = self.prepare_ws.lock().unwrap();
            acts.begin_input();
            layer.w_down.forward_batch_cached(&self.plan, li, Role::Down, &act, n, &mut down, &self.pool, &mut acts);
        }
        gemm_ns += t.elapsed().as_nanos() as u64;
        for (x, d) in xs.iter_mut().zip(down.iter()) {
            *x += d;
        }

        let total_ns = t_block.elapsed().as_nanos() as u64;
        self.phase.attn_ns.fetch_add(attn_ns, Ordering::Relaxed);
        self.phase.gemm_ns.fetch_add(gemm_ns, Ordering::Relaxed);
        self.phase.other_ns.fetch_add(total_ns.saturating_sub(attn_ns + gemm_ns), Ordering::Relaxed);
    }

    fn logits_for(&self, x: &[f32]) -> Vec<f32> {
        let h = self.cfg.hidden;
        let mut normed = vec![0f32; h];
        rmsnorm(&x[..h], &self.final_norm, self.cfg.rms_eps, &mut normed);
        let mut logits = vec![0f32; self.cfg.vocab_size];
        let t = Instant::now();
        self.lm_head.forward(&normed, &mut logits, &self.pool);
        self.phase.gemm_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model(qtype: QuantType) -> Transformer {
        Transformer::synthetic(&ModelConfig::tiny(), qtype, 7)
    }

    #[test]
    fn prefill_then_decode_matches_token_by_token() {
        let model = tiny_model(QuantType::I2S);
        let tokens = [5u32, 10, 400, 3, 77];
        // Path A: chunked prefill.
        let mut s1 = model.new_session(64);
        let logits_a = model.prefill(&mut s1, &tokens);
        // Path B: token-by-token prefill (chunks of one).
        let mut s2 = model.new_session(64);
        let mut logits_b = Vec::new();
        for &t in &tokens {
            logits_b = model.prefill(&mut s2, &[t]);
        }
        assert_eq!(s1.pos, s2.pos);
        for (a, b) in logits_a.iter().zip(logits_b.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_batch_matches_individual_decode() {
        let model = tiny_model(QuantType::Tl21);
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[9, 8], &[100, 200, 300, 400]];
        // Individual path.
        let mut singles = Vec::new();
        for p in prompts {
            let mut s = model.new_session(64);
            model.prefill(&mut s, p);
            let l = model.decode_step(&mut s, 42);
            singles.push(l);
        }
        // Batched path.
        let mut sessions: Vec<Session> = prompts
            .iter()
            .map(|p| {
                let mut s = model.new_session(64);
                model.prefill(&mut s, p);
                s
            })
            .collect();
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        let batched = model.decode_batch(&mut refs, &[42, 42, 42]);
        for (i, (a, b)) in singles.iter().zip(batched.iter()).enumerate() {
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 1e-4, "seq {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn logits_are_finite_and_varied() {
        let model = tiny_model(QuantType::Tl20);
        let mut s = model.new_session(32);
        let logits = model.prefill(&mut s, &[1, 2, 3]);
        assert!(logits.iter().all(|v| v.is_finite()));
        let min = logits.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(max > min, "degenerate logits");
    }

    #[test]
    fn lossless_kernels_agree_bitwise_on_logits() {
        // The paper's Figure 2 property at model level: I2_S, TL1_1 and
        // TL2_1 produce identical logits (same integer math everywhere).
        let tokens = [3u32, 1, 4, 1, 5, 9, 2, 6];
        let mut outs = Vec::new();
        for qt in [QuantType::I2S, QuantType::Tl11, QuantType::Tl21] {
            let model = tiny_model(qt);
            let mut s = model.new_session(32);
            let l = model.prefill(&mut s, &tokens);
            outs.push(l);
        }
        assert_eq!(outs[0], outs[1], "I2_S vs TL1_1");
        assert_eq!(outs[0], outs[2], "I2_S vs TL2_1");
    }

    #[test]
    fn phase_stats_and_attn_workspace_accumulate() {
        let model = tiny_model(QuantType::I2S);
        let mut s = model.new_session(64);
        model.prefill(&mut s, &[1, 2, 3]);
        for t in 0..5u32 {
            model.decode_step(&mut s, 10 + t);
        }
        let (attn, gemm, other) = model.phase_us();
        assert!(attn + gemm + other > 0, "no phase time recorded");
        // The session workspace allocates O(log ctx) times (power-of-two
        // growth) and reuses everywhere else: 2 layers × 8 steps of
        // attends share one score buffer.
        let (allocs, reuses) = s.attn_workspace_stats();
        assert!(allocs >= 1, "first attend must size the workspace");
        assert!(
            reuses > allocs,
            "steady-state attends must reuse capacity: {allocs} allocs / {reuses} reuses"
        );
    }

    #[test]
    fn kv_overflow_panics() {
        let model = tiny_model(QuantType::I2S);
        let mut s = model.new_session(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.prefill(&mut s, &[1, 2, 3, 4, 5, 6]);
        }));
        assert!(result.is_err());
    }
}
