//! **BitLinear**: the ternary linear layer of BitNet b1.58, dispatching
//! its mpGEMM through any kernel in the library. Holds the packed weight
//! tensor; activation quantization happens inside the kernel's `prepare`
//! so each kernel applies its own scheme (per-tensor for the lossless
//! kernels, per-block for the llama.cpp baselines — exactly the
//! distinction Figure 2 of the paper illustrates).
//!
//! Since PR 2 the layer is a **multi-packed container**: one *primary*
//! packing (chosen at construction for the n=1 decode regime) plus up to
//! [`MAX_ALTERNATES`] alternate packings, materialized lazily the first
//! time a [`pallas_kernels::kernels::DispatchPlan`] routes a call to a different
//! kernel — e.g. TL2 for compute-bound prefill chunks while I2_S serves
//! memory-bound decode. Alternates are repacked from the primary tensor
//! (exact for ternary-native kernels, which round-trip `dequantize`), so
//! the unpacked weights are never retained. The resident memory cost is
//! reported by [`BitLinear::weight_bytes`].

use pallas_kernels::kernels::quant::TernaryWeights;
use pallas_kernels::kernels::tuner::{DispatchPlan, Role};
use pallas_kernels::kernels::{
    kernel_for, matmul, matmul_prepared, Dispatch, Kernel, PreparedActivations, QTensor, QuantType,
};
use pallas_core::threadpool::ThreadPool;
use std::sync::{Arc, RwLock};

/// Cap on alternate packings held per projection — the "repack
/// threshold" bounding multi-packing memory: primary + at most this many
/// alternates (2 covers the decode / prefill / wide-batch regimes).
/// Selections that would exceed the cap run on the primary instead and
/// are *not* an error (speed degrades gracefully, memory stays bounded).
pub const MAX_ALTERNATES: usize = 2;

pub struct BitLinear {
    /// The primary packing (decode-regime kernel).
    pub qtensor: QTensor,
    kernel: &'static dyn Kernel,
    /// Lazily materialized alternate packings, at most [`MAX_ALTERNATES`].
    alternates: RwLock<Vec<(QuantType, Arc<QTensor>)>>,
    /// The absmean weight scale of the source tensor, kept so alternates
    /// repack with exactly the scale the primary was packed with.
    weight_scale: f32,
    /// Zero-weight fraction of the source ternary tensor, measured once
    /// at pack time (the sparsity observability hook — ternary BitNet
    /// weights are ~1/3 exact zeros, but only *block-structured* zeros
    /// let the kernels elide work).
    pub zero_fraction: f64,
    /// Output features (rows).
    pub m: usize,
    /// Input features (cols).
    pub k: usize,
}

impl BitLinear {
    /// Pack ternary weights for the given kernel.
    pub fn new(w: &TernaryWeights, qtype: QuantType) -> BitLinear {
        let kernel = kernel_for(qtype);
        let info = kernel.info();
        assert_eq!(
            w.k % info.k_multiple,
            0,
            "{}: K={} not a multiple of {}",
            info.name,
            w.k,
            info.k_multiple
        );
        BitLinear {
            qtensor: kernel.quantize(w),
            kernel,
            alternates: RwLock::new(Vec::new()),
            weight_scale: w.scale,
            zero_fraction: pallas_kernels::kernels::sparse::zero_fraction(&w.q),
            m: w.m,
            k: w.k,
        }
    }

    /// Whether the primary packing carries the block-skip sparse layout
    /// (pack-time decision: [`pallas_kernels::kernels::sparse::SparseMode`] and,
    /// under `Auto`, the measured zero-*block* fraction against
    /// [`pallas_kernels::kernels::sparse::SPARSE_THRESHOLD`]).
    pub fn sparse_layout(&self) -> bool {
        self.qtensor.sparse.is_some()
    }

    /// The zero-block fraction the primary packing's sparse index
    /// measured, `None` when it packed dense.
    pub fn zero_block_fraction(&self) -> Option<f64> {
        self.qtensor.sparse.as_ref().map(|s| s.zero_block_fraction())
    }

    /// Pack ternary weights with the kernel a [`Dispatch`] policy selects
    /// for this layer's (m, k) shape — `Fixed` pins one kernel, `Auto`
    /// consults a measured [`pallas_kernels::kernels::TuningProfile`] (decode-path
    /// batch of 1 is the selection key; see `docs/tuning.md`).
    pub fn from_dispatch(w: &TernaryWeights, dispatch: &Dispatch) -> BitLinear {
        Self::new(w, dispatch.select(w.m, w.k, 1))
    }

    /// The primary kernel (what n=1 decode runs unless overridden).
    pub fn qtype(&self) -> QuantType {
        self.kernel.info().qtype
    }

    /// Every kernel with a materialized packing: the primary first, then
    /// the alternates in the order they were first used.
    pub fn packed_kernels(&self) -> Vec<QuantType> {
        let mut out = vec![self.qtype()];
        for (q, _) in self.alternates.read().unwrap().iter() {
            out.push(*q);
        }
        out
    }

    /// Reconstruct the unpacked ternary weights from the primary packing.
    /// Exact for ternary-native kernels (`dequantize` returns q·scale
    /// bit-for-bit); `None` when the primary cannot represent arbitrary
    /// ternary weights exactly (general llama.cpp formats).
    fn reconstruct(&self) -> Option<TernaryWeights> {
        if !self.kernel.info().ternary_native {
            return None;
        }
        let deq = self.kernel.dequantize(&self.qtensor);
        let s = self.weight_scale;
        let q: Vec<i8> = if s == 0.0 {
            vec![0i8; self.m * self.k]
        } else {
            deq.iter().map(|&v| (v / s).round().clamp(-1.0, 1.0) as i8).collect()
        };
        Some(TernaryWeights::from_ternary(q, self.m, self.k, s))
    }

    /// The alternate tensor for `qtype`, packing it on first use. `None`
    /// means "run the primary": `qtype` *is* the primary, the kernel's K
    /// alignment doesn't fit, the primary can't be reconstructed, or the
    /// [`MAX_ALTERNATES`] budget is exhausted.
    fn alternate_for(&self, qtype: QuantType) -> Option<Arc<QTensor>> {
        if qtype == self.qtype() {
            return None;
        }
        {
            let alts = self.alternates.read().unwrap();
            if let Some((_, t)) = alts.iter().find(|(q, _)| *q == qtype) {
                return Some(Arc::clone(t));
            }
            if alts.len() >= MAX_ALTERNATES {
                return None;
            }
        }
        if self.k % kernel_for(qtype).info().k_multiple != 0 {
            return None;
        }
        let w = self.reconstruct()?;
        let packed = Arc::new(kernel_for(qtype).quantize(&w));
        let mut alts = self.alternates.write().unwrap();
        // Re-check under the write lock: another thread may have packed
        // (or filled the budget) while we quantized.
        if let Some((_, t)) = alts.iter().find(|(q, _)| *q == qtype) {
            return Some(Arc::clone(t));
        }
        if alts.len() >= MAX_ALTERNATES {
            return None;
        }
        alts.push((qtype, Arc::clone(&packed)));
        Some(packed)
    }

    /// Eagerly materialize the packing for `qtype` (no-op when it is the
    /// primary or cannot be packed); returns the kernel that will
    /// actually serve calls asking for `qtype`.
    pub fn prepack(&self, qtype: QuantType) -> QuantType {
        match self.alternate_for(qtype) {
            Some(t) => t.qtype,
            None => self.qtype(),
        }
    }

    /// Single-row forward: `out = W · x` (always the primary packing).
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.k);
        debug_assert_eq!(out.len(), self.m);
        let p = self.kernel.prepare(x, self.k);
        self.kernel.gemv(&self.qtensor, &p, out);
    }

    /// Batched forward over `n` activation rows, parallelized on `pool`
    /// (always the primary packing).
    pub fn forward_batch(&self, x: &[f32], n: usize, out: &mut [f32], pool: &ThreadPool) {
        matmul(self.kernel, &self.qtensor, x, n, out, pool);
    }

    /// Batched forward routed through `qtype`, packing it on first use
    /// and falling back to the primary when it cannot be packed. Returns
    /// the kernel that actually ran.
    pub fn forward_batch_with(
        &self,
        qtype: QuantType,
        x: &[f32],
        n: usize,
        out: &mut [f32],
        pool: &ThreadPool,
    ) -> QuantType {
        match self.alternate_for(qtype) {
            Some(t) => {
                matmul(kernel_for(t.qtype), &t, x, n, out, pool);
                t.qtype
            }
            None => {
                matmul(self.kernel, &self.qtensor, x, n, out, pool);
                self.qtype()
            }
        }
    }

    /// Plan-routed batched forward: resolve (layer, role, m, k, n)
    /// through the [`DispatchPlan`] — the per-call decision that routes
    /// prefill chunks and batched decode to their measured winners.
    /// Returns the kernel that actually ran.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_planned(
        &self,
        plan: &DispatchPlan,
        layer: usize,
        role: Role,
        x: &[f32],
        n: usize,
        out: &mut [f32],
        pool: &ThreadPool,
    ) -> QuantType {
        let want = plan.select(layer, role, self.m, self.k, n);
        let ran = self.forward_batch_with(want, x, n, out, pool);
        if ran != want {
            plan.note_degraded(self.m, self.k, n, want, ran);
        }
        ran
    }

    /// Plan-routed batched forward through a shared [`PreparedActivations`]
    /// cache — the prepare-once hot path. The first projection consuming a
    /// given layer input prepares it for its resolved kernel; subsequent
    /// projections sharing the input (wq/wk/wv, gate/up) reuse the batch
    /// and pay only accumulation. Returns the kernel that actually ran.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_cached(
        &self,
        plan: &DispatchPlan,
        layer: usize,
        role: Role,
        x: &[f32],
        n: usize,
        out: &mut [f32],
        pool: &ThreadPool,
        acts: &mut PreparedActivations,
    ) -> QuantType {
        debug_assert_eq!(x.len(), n * self.k);
        debug_assert_eq!(out.len(), n * self.m);
        let want = plan.select(layer, role, self.m, self.k, n);
        let alt = self.alternate_for(want);
        let (kernel, tensor): (&'static dyn Kernel, &QTensor) = match alt.as_deref() {
            Some(t) => (kernel_for(t.qtype), t),
            None => (self.kernel, &self.qtensor),
        };
        let ran = tensor.qtype;
        if ran != want {
            plan.note_degraded(self.m, self.k, n, want, ran);
        }
        let batch = acts.get_or_prepare(kernel, x, self.k, n, pool);
        matmul_prepared(kernel, tensor, batch, x, n, out, pool);
        ran
    }

    /// Resident packed weight bytes: the primary plus every materialized
    /// alternate — the bounded memory cost of multi-packing.
    pub fn weight_bytes(&self) -> usize {
        let alts: usize =
            self.alternates.read().unwrap().iter().map(|(_, t)| t.weight_bytes()).sum();
        self.qtensor.weight_bytes() + alts
    }

    /// Packed bytes of the primary tensor alone — what one n=1 decode
    /// GEMV streams (the memory-bound decode cost).
    pub fn primary_weight_bytes(&self) -> usize {
        self.qtensor.weight_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 1.0 / (0.5 * k as f32).sqrt())
    }

    #[test]
    fn forward_matches_dense() {
        let (m, k) = (32, 256);
        let w = random_ternary(m, k, 1);
        let layer = BitLinear::new(&w, QuantType::I2S);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let mut out = vec![0f32; m];
        layer.forward(&x, &mut out);
        let wd = w.dequantize();
        for r in 0..m {
            let want: f32 = (0..k).map(|i| wd[r * k + i] * x[i]).sum();
            assert!((out[r] - want).abs() < 0.05 * want.abs().max(1.0), "row {r}");
        }
    }

    #[test]
    fn batch_forward_consistent_with_single() {
        let (m, k, n) = (16, 256, 4);
        let w = random_ternary(m, k, 3);
        let layer = BitLinear::new(&w, QuantType::Tl21);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(2);
        let mut out_b = vec![0f32; n * m];
        layer.forward_batch(&x, n, &mut out_b, &pool);
        for i in 0..n {
            let mut out_s = vec![0f32; m];
            layer.forward(&x[i * k..(i + 1) * k], &mut out_s);
            assert_eq!(&out_b[i * m..(i + 1) * m], &out_s[..], "row {i}");
        }
    }

    #[test]
    fn dispatch_packing_matches_fixed() {
        use pallas_kernels::kernels::TuningProfile;
        let (m, k) = (16, 256);
        let w = random_ternary(m, k, 6);
        let mut profile = TuningProfile::empty(QuantType::I2S, 1);
        profile.entries.push(pallas_kernels::kernels::tuner::TuningEntry {
            m,
            k,
            n: 1,
            weight: 1.0,
            best: QuantType::Tl21,
            best_simd: pallas_kernels::kernels::SimdLevel::Scalar,
            best_sparse: false,
            measurements: Vec::new(),
        });
        let auto = BitLinear::from_dispatch(&w, &Dispatch::Auto(profile));
        assert_eq!(auto.qtype(), QuantType::Tl21);
        let fixed = BitLinear::from_dispatch(&w, &Dispatch::Fixed(QuantType::Tl21));
        assert_eq!(fixed.qtype(), QuantType::Tl21);
        assert_eq!(auto.qtensor.data, fixed.qtensor.data, "identical packing");
    }

    #[test]
    fn alternate_repack_is_bit_identical_to_direct_packing() {
        // Repacking from the primary must equal packing from the source
        // weights — the property that keeps lossless multi-pack lossless.
        let (m, k) = (16, 256);
        let w = random_ternary(m, k, 8);
        let layer = BitLinear::new(&w, QuantType::I2S);
        let pool = ThreadPool::new(1);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let mut out_alt = vec![0f32; m];
        let ran = layer.forward_batch_with(QuantType::Tl21, &x, 1, &mut out_alt, &pool);
        assert_eq!(ran, QuantType::Tl21);
        assert_eq!(layer.packed_kernels(), vec![QuantType::I2S, QuantType::Tl21]);
        let direct = BitLinear::new(&w, QuantType::Tl21);
        let mut out_direct = vec![0f32; m];
        direct.forward(&x, &mut out_direct);
        assert_eq!(out_alt, out_direct);
        // Resident bytes now include both packings, and the primary
        // stream cost is unchanged.
        assert_eq!(
            layer.weight_bytes(),
            layer.primary_weight_bytes() + direct.primary_weight_bytes()
        );
    }

    #[test]
    fn alternate_budget_is_bounded() {
        let (m, k) = (8, 256);
        let w = random_ternary(m, k, 11);
        let layer = BitLinear::new(&w, QuantType::I2S);
        // Two alternates fit …
        assert_eq!(layer.prepack(QuantType::Tl21), QuantType::Tl21);
        assert_eq!(layer.prepack(QuantType::Tl11), QuantType::Tl11);
        // … the third exceeds MAX_ALTERNATES and degrades to the primary.
        assert_eq!(layer.prepack(QuantType::Tl20), QuantType::I2S);
        // Cached alternates and the primary itself still resolve.
        assert_eq!(layer.prepack(QuantType::Tl21), QuantType::Tl21);
        assert_eq!(layer.prepack(QuantType::I2S), QuantType::I2S);
        assert_eq!(layer.packed_kernels().len(), 1 + MAX_ALTERNATES);
    }

    #[test]
    fn incompatible_alternate_degrades_to_primary() {
        // K=128 fits I2_S but not TQ2_0 (K % 256); the routed call must
        // run on the primary instead of panicking.
        let (m, k) = (8, 128);
        let w = random_ternary(m, k, 12);
        let layer = BitLinear::new(&w, QuantType::I2S);
        let pool = ThreadPool::new(1);
        let x = vec![0.5f32; k];
        let mut out = vec![0f32; m];
        let ran = layer.forward_batch_with(QuantType::Tq20, &x, 1, &mut out, &pool);
        assert_eq!(ran, QuantType::I2S);
        assert_eq!(layer.packed_kernels(), vec![QuantType::I2S]);
    }

    #[test]
    fn sparsity_is_measured_and_iid_stays_dense() {
        use pallas_kernels::kernels::sparse::{self, SparseMode};
        let (m, k) = (8, 256);
        let w = random_ternary(m, k, 30);
        sparse::with_mode(SparseMode::Auto, || {
            let layer = BitLinear::new(&w, QuantType::I2S);
            // iid ternary is ~1/3 zeros by weight…
            assert!(
                layer.zero_fraction > 0.1 && layer.zero_fraction < 0.6,
                "{}",
                layer.zero_fraction
            );
            // …but essentially never forms a whole zero block, so the
            // pack-time decision keeps the dense layout automatically.
            assert!(!layer.sparse_layout());
            assert_eq!(layer.zero_block_fraction(), None);
        });
        sparse::with_mode(SparseMode::On, || {
            let forced = BitLinear::new(&w, QuantType::I2S);
            assert!(forced.sparse_layout());
            assert_eq!(forced.zero_block_fraction(), Some(0.0));
        });
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn rejects_misaligned_k() {
        let w = random_ternary(4, 100, 5);
        BitLinear::new(&w, QuantType::I2S);
    }

    #[test]
    fn cached_forward_matches_planned_forward() {
        let (m, k, n) = (16, 256, 3);
        let w = random_ternary(m, k, 20);
        let layer = BitLinear::new(&w, QuantType::Tl21);
        let plan = DispatchPlan::new(Dispatch::Fixed(QuantType::Tl21));
        let pool = ThreadPool::new(2);
        let mut rng = Rng::new(21);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let mut out_plan = vec![0f32; n * m];
        layer.forward_batch_planned(&plan, 0, Role::Qkv, &x, n, &mut out_plan, &pool);
        let mut acts = PreparedActivations::new();
        acts.begin_input();
        let mut out_cached = vec![0f32; n * m];
        let ran = layer
            .forward_batch_cached(&plan, 0, Role::Qkv, &x, n, &mut out_cached, &pool, &mut acts);
        assert_eq!(ran, QuantType::Tl21);
        assert_eq!(out_plan, out_cached);
        // A second projection consuming the same input hits the cache and
        // produces identical output.
        let mut out2 = vec![0f32; n * m];
        layer.forward_batch_cached(&plan, 0, Role::Qkv, &x, n, &mut out2, &pool, &mut acts);
        assert_eq!((acts.stats().misses, acts.stats().hits), (1, 1));
        assert_eq!(out2, out_cached);
    }
}
