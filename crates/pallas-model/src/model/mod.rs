//! BitNet b1.58 transformer — the model the mpGEMM library serves.
//!
//! LLaMA-shaped architecture with **BitLinear** projections (ternary
//! weights + per-tensor int8 activations) in every attention/FFN matmul;
//! embeddings, norms and the LM head stay high-precision, matching the
//! BitNet b1.58 recipe. All seven projections per layer dispatch through
//! the pluggable [`pallas_kernels::kernels::Kernel`] interface, so one model runs
//! under any of the paper's kernels — the basis of the speed (Table 7)
//! and quality (Table 2) comparisons.

pub mod bitlinear;
pub mod config;
pub mod ops;
pub mod sampling;
pub mod transformer;
pub mod weights;

pub use bitlinear::BitLinear;
pub use config::ModelConfig;
pub use sampling::{sample, SamplingParams};
pub use transformer::{PhaseStats, Session, Transformer};
