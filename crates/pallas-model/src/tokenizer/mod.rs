//! Tokenizer substrate: a byte-fallback tokenizer with a greedy
//! longest-match merge vocabulary (BPE-like), built deterministically from
//! a seed corpus. Real deployments would load a SentencePiece model; the
//! serving path only needs *a* reversible token stream with a realistic
//! vocab-id distribution.
//!
//! Token id layout: 0 = BOS, 1 = EOS, 2 = PAD, 3..259 = raw bytes,
//! 259.. = learned merges.

use std::collections::HashMap;

pub const BOS: u32 = 0;
pub const EOS: u32 = 1;
pub const PAD: u32 = 2;
const BYTE_BASE: u32 = 3;

pub struct Tokenizer {
    /// merge string → id.
    merges: HashMap<Vec<u8>, u32>,
    /// id → bytes (for decode).
    pieces: Vec<Vec<u8>>,
    /// Longest merge length (bounds the greedy scan).
    max_piece: usize,
    vocab_size: usize,
}

impl Tokenizer {
    /// Build a tokenizer whose learned pieces are the most frequent
    /// substrings (length 2..=8) of `corpus`, capped to `vocab_size`.
    pub fn train(corpus: &str, vocab_size: usize) -> Tokenizer {
        assert!(vocab_size > (BYTE_BASE as usize + 256), "vocab too small for byte fallback");
        let bytes = corpus.as_bytes();
        let mut freq: HashMap<&[u8], u64> = HashMap::new();
        for len in 2..=8usize {
            if bytes.len() < len {
                break;
            }
            for w in bytes.windows(len) {
                *freq.entry(w).or_insert(0) += 1;
            }
        }
        // Score by frequency × length (prefer long, common pieces);
        // deterministic tie-break on the bytes themselves.
        let mut scored: Vec<(&[u8], u64)> = freq.into_iter().filter(|(_, c)| *c >= 2).collect();
        scored.sort_by(|a, b| {
            let sa = a.1 * a.0.len() as u64;
            let sb = b.1 * b.0.len() as u64;
            sb.cmp(&sa).then_with(|| a.0.cmp(b.0))
        });

        let budget = vocab_size - BYTE_BASE as usize - 256;
        let mut merges = HashMap::new();
        let mut pieces: Vec<Vec<u8>> = Vec::new();
        // ids 0..259 reserved.
        for (piece, _) in scored.into_iter().take(budget) {
            let id = (BYTE_BASE as usize + 256 + pieces.len()) as u32;
            merges.insert(piece.to_vec(), id);
            pieces.push(piece.to_vec());
        }
        let max_piece = pieces.iter().map(|p| p.len()).max().unwrap_or(1);
        Tokenizer { merges, pieces, max_piece, vocab_size }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    /// Greedy longest-match encode with byte fallback; prepends BOS.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let bytes = text.as_bytes();
        let mut out = vec![BOS];
        let mut i = 0usize;
        while i < bytes.len() {
            let max_len = self.max_piece.min(bytes.len() - i);
            let mut matched = false;
            for len in (2..=max_len).rev() {
                if let Some(&id) = self.merges.get(&bytes[i..i + len]) {
                    out.push(id);
                    i += len;
                    matched = true;
                    break;
                }
            }
            if !matched {
                out.push(BYTE_BASE + bytes[i] as u32);
                i += 1;
            }
        }
        out
    }

    /// Decode ids back to text (lossy only on invalid UTF-8 boundaries).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if id < BYTE_BASE {
                continue; // specials
            }
            if id < BYTE_BASE + 256 {
                bytes.push((id - BYTE_BASE) as u8);
            } else {
                let pi = (id - BYTE_BASE - 256) as usize;
                if let Some(p) = self.pieces.get(pi) {
                    bytes.extend_from_slice(p);
                }
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Deterministic synthetic corpus for tokenizer training and eval —
/// a Zipf-ish word soup so token frequencies look text-like.
pub fn synthetic_corpus(words: usize, seed: u64) -> String {
    use pallas_core::util::Rng;
    const VOCAB: [&str; 48] = [
        "the", "of", "and", "to", "in", "a", "is", "that", "for", "it", "model", "weight",
        "ternary", "kernel", "lookup", "table", "edge", "inference", "quantization", "bit",
        "matrix", "vector", "memory", "bandwidth", "compute", "thread", "token", "speed",
        "lossless", "scale", "activation", "layer", "attention", "head", "cache", "batch",
        "decode", "prefill", "latency", "throughput", "device", "cpu", "register", "simd",
        "shuffle", "accumulate", "sign", "index",
    ];
    let mut rng = Rng::new(seed);
    let mut out = String::new();
    for i in 0..words {
        if i > 0 {
            out.push(' ');
        }
        // Zipf-ish: square the uniform draw to skew toward low indices.
        let u = rng.next_f32();
        let idx = ((u * u) * VOCAB.len() as f32) as usize;
        out.push_str(VOCAB[idx.min(VOCAB.len() - 1)]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trained() -> Tokenizer {
        Tokenizer::train(&synthetic_corpus(5000, 1), 512)
    }

    #[test]
    fn encode_decode_round_trip() {
        let tok = trained();
        for text in ["the ternary model", "lookup table kernel", "xyz unseen €", ""] {
            let ids = tok.encode(text);
            assert_eq!(tok.decode(&ids), text, "{text:?}");
        }
    }

    #[test]
    fn bos_is_prepended() {
        let tok = trained();
        assert_eq!(tok.encode("abc")[0], BOS);
    }

    #[test]
    fn common_words_compress() {
        let tok = trained();
        let ids = tok.encode("the the the the");
        // 15 bytes of text must compress below byte-level length + BOS.
        assert!(ids.len() < 16, "got {} tokens", ids.len());
    }

    #[test]
    fn ids_stay_in_vocab() {
        let tok = trained();
        let ids = tok.encode(&synthetic_corpus(1000, 2));
        assert!(ids.iter().all(|&i| (i as usize) < tok.vocab_size()));
    }

    #[test]
    fn byte_fallback_handles_arbitrary_bytes() {
        let tok = trained();
        let text = "\u{1F600} emoji + ümlaut";
        assert_eq!(tok.decode(&tok.encode(text)), text);
    }

    #[test]
    fn training_is_deterministic() {
        let a = Tokenizer::train(&synthetic_corpus(2000, 3), 400);
        let b = Tokenizer::train(&synthetic_corpus(2000, 3), 400);
        assert_eq!(a.encode("ternary lookup"), b.encode("ternary lookup"));
    }
}
