//! **BTNZ** — the model container format (a GGUF-like substrate built from
//! scratch): a binary file holding the model config, ternary weights in a
//! compact 2-bit stream plus the high-precision tensors, independent of
//! any kernel's packing (kernels re-pack at load time, exactly as
//! Bitnet.cpp converts checkpoints into its kernel formats).
//!
//! Layout (little-endian):
//! ```text
//! magic "BTNZ" | u32 version | config block | u32 n_tensors
//! per tensor: u16 name_len | name | u8 dtype | u32 rows | u32 cols |
//!             f32 scale | u64 payload_len | payload
//! ```
//! dtype 0 = ternary (2-bit packed, code w+1), dtype 1 = f32.

use pallas_kernels::kernels::quant::TernaryWeights;
use crate::model::config::ModelConfig;
use crate::model::weights::{Checkpoint, LayerWeights};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"BTNZ";
const VERSION: u32 = 1;

/// Serialize a checkpoint to a BTNZ file.
pub fn save(ck: &Checkpoint, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    write_config(&mut w, &ck.config)?;

    let n_tensors = 2 + ck.layers.len() * 9 + 1;
    w.write_all(&(n_tensors as u32).to_le_bytes())?;

    let cfg = &ck.config;
    write_f32_tensor(&mut w, "tok_embed", &ck.tok_embed, cfg.vocab_size, cfg.hidden)?;
    for (i, l) in ck.layers.iter().enumerate() {
        let p = |s: &str| format!("layers.{i}.{s}");
        write_ternary_tensor(&mut w, &p("wq"), &l.wq)?;
        write_ternary_tensor(&mut w, &p("wk"), &l.wk)?;
        write_ternary_tensor(&mut w, &p("wv"), &l.wv)?;
        write_ternary_tensor(&mut w, &p("wo"), &l.wo)?;
        write_ternary_tensor(&mut w, &p("w_gate"), &l.w_gate)?;
        write_ternary_tensor(&mut w, &p("w_up"), &l.w_up)?;
        write_ternary_tensor(&mut w, &p("w_down"), &l.w_down)?;
        write_f32_tensor(&mut w, &p("attn_norm"), &l.attn_norm, 1, cfg.hidden)?;
        write_f32_tensor(&mut w, &p("ffn_norm"), &l.ffn_norm, 1, cfg.hidden)?;
    }
    write_f32_tensor(&mut w, "final_norm", &ck.final_norm, 1, cfg.hidden)?;
    write_f32_tensor(&mut w, "lm_head", &ck.lm_head, cfg.vocab_size, cfg.hidden)?;
    w.flush()?;
    Ok(())
}

/// Load a BTNZ file back into an unpacked checkpoint.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let file =
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a BTNZ file (magic {:?})", magic);
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported BTNZ version {version}");
    }
    let config = read_config(&mut r)?;
    let n_tensors = read_u32(&mut r)? as usize;

    let mut tensors = std::collections::HashMap::new();
    for _ in 0..n_tensors {
        let (name, t) = read_tensor(&mut r)?;
        tensors.insert(name, t);
    }

    type Map = std::collections::HashMap<String, Tensor>;
    fn take_f32(tensors: &mut Map, name: &str) -> Result<Vec<f32>> {
        match tensors.remove(name) {
            Some(Tensor::F32(v, _, _)) => Ok(v),
            Some(_) => bail!("tensor {name} has wrong dtype"),
            None => bail!("missing tensor {name}"),
        }
    }
    fn take_ternary(tensors: &mut Map, name: &str) -> Result<TernaryWeights> {
        match tensors.remove(name) {
            Some(Tensor::Ternary(t)) => Ok(t),
            Some(_) => bail!("tensor {name} has wrong dtype"),
            None => bail!("missing tensor {name}"),
        }
    }

    let tok_embed = take_f32(&mut tensors, "tok_embed")?;
    let mut layers = Vec::with_capacity(config.n_layers);
    for i in 0..config.n_layers {
        let p = |s: &str| format!("layers.{i}.{s}");
        layers.push(LayerWeights {
            wq: take_ternary(&mut tensors, &p("wq"))?,
            wk: take_ternary(&mut tensors, &p("wk"))?,
            wv: take_ternary(&mut tensors, &p("wv"))?,
            wo: take_ternary(&mut tensors, &p("wo"))?,
            w_gate: take_ternary(&mut tensors, &p("w_gate"))?,
            w_up: take_ternary(&mut tensors, &p("w_up"))?,
            w_down: take_ternary(&mut tensors, &p("w_down"))?,
            attn_norm: take_f32(&mut tensors, &p("attn_norm"))?,
            ffn_norm: take_f32(&mut tensors, &p("ffn_norm"))?,
        });
    }
    let final_norm = take_f32(&mut tensors, "final_norm")?;
    let lm_head = take_f32(&mut tensors, "lm_head")?;
    Ok(Checkpoint { config, tok_embed, layers, final_norm, lm_head })
}

enum Tensor {
    Ternary(TernaryWeights),
    F32(Vec<f32>, #[allow(dead_code)] usize, #[allow(dead_code)] usize),
}

fn write_config(w: &mut impl Write, cfg: &ModelConfig) -> Result<()> {
    let name = cfg.name.as_bytes();
    w.write_all(&(name.len() as u16).to_le_bytes())?;
    w.write_all(name)?;
    for v in [
        cfg.hidden,
        cfg.ffn,
        cfg.n_layers,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.vocab_size,
        cfg.max_seq_len,
    ] {
        w.write_all(&(v as u32).to_le_bytes())?;
    }
    w.write_all(&cfg.rope_theta.to_le_bytes())?;
    w.write_all(&cfg.rms_eps.to_le_bytes())?;
    Ok(())
}

fn read_config(r: &mut impl Read) -> Result<ModelConfig> {
    let name_len = read_u16(r)? as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let name_str = String::from_utf8(name).context("config name utf8")?;
    let hidden = read_u32(r)? as usize;
    let ffn = read_u32(r)? as usize;
    let n_layers = read_u32(r)? as usize;
    let n_heads = read_u32(r)? as usize;
    let n_kv_heads = read_u32(r)? as usize;
    let vocab_size = read_u32(r)? as usize;
    let max_seq_len = read_u32(r)? as usize;
    let rope_theta = read_f32(r)?;
    let rms_eps = read_f32(r)?;
    // Map back to a preset name when possible, else leak the name (configs
    // are few and long-lived; this keeps ModelConfig.name a &'static str).
    let name_static: &'static str = match ModelConfig::preset(&name_str) {
        Some(p) => p.name,
        None => Box::leak(name_str.into_boxed_str()),
    };
    Ok(ModelConfig {
        name: name_static,
        hidden,
        ffn,
        n_layers,
        n_heads,
        n_kv_heads,
        vocab_size,
        max_seq_len,
        rope_theta,
        rms_eps,
    })
}

fn write_ternary_tensor(w: &mut impl Write, name: &str, t: &TernaryWeights) -> Result<()> {
    write_tensor_header(w, name, 0, t.m, t.k, t.scale)?;
    // 2-bit stream, 4 weights per byte.
    let mut payload = vec![0u8; pallas_core::util::ceil_div(t.q.len(), 4)];
    for (i, &q) in t.q.iter().enumerate() {
        payload[i / 4] |= (((q + 1) as u8) & 0x3) << (2 * (i % 4));
    }
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(&payload)?;
    Ok(())
}

fn write_f32_tensor(w: &mut impl Write, name: &str, v: &[f32], rows: usize, cols: usize) -> Result<()> {
    assert_eq!(v.len(), rows * cols, "{name}");
    write_tensor_header(w, name, 1, rows, cols, 1.0)?;
    w.write_all(&((v.len() * 4) as u64).to_le_bytes())?;
    let mut bytes = Vec::with_capacity(v.len() * 4);
    for &x in v {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)?;
    Ok(())
}

fn write_tensor_header(
    w: &mut impl Write,
    name: &str,
    dtype: u8,
    rows: usize,
    cols: usize,
    scale: f32,
) -> Result<()> {
    let nb = name.as_bytes();
    w.write_all(&(nb.len() as u16).to_le_bytes())?;
    w.write_all(nb)?;
    w.write_all(&[dtype])?;
    w.write_all(&(rows as u32).to_le_bytes())?;
    w.write_all(&(cols as u32).to_le_bytes())?;
    w.write_all(&scale.to_le_bytes())?;
    Ok(())
}

fn read_tensor(r: &mut impl Read) -> Result<(String, Tensor)> {
    let name_len = read_u16(r)? as usize;
    let mut nb = vec![0u8; name_len];
    r.read_exact(&mut nb)?;
    let name = String::from_utf8(nb).context("tensor name utf8")?;
    let mut dtype = [0u8; 1];
    r.read_exact(&mut dtype)?;
    let rows = read_u32(r)? as usize;
    let cols = read_u32(r)? as usize;
    let scale = read_f32(r)?;
    let payload_len = read_u64(r)? as usize;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload)?;
    let t = match dtype[0] {
        0 => {
            let n = rows * cols;
            if payload_len != pallas_core::util::ceil_div(n, 4) {
                bail!("{name}: ternary payload {payload_len} for {n} weights");
            }
            let mut q = Vec::with_capacity(n);
            for i in 0..n {
                let code = (payload[i / 4] >> (2 * (i % 4))) & 0x3;
                q.push(code as i8 - 1);
            }
            Tensor::Ternary(TernaryWeights { q, m: rows, k: cols, scale })
        }
        1 => {
            if payload_len != rows * cols * 4 {
                bail!("{name}: f32 payload {payload_len} for {rows}x{cols}");
            }
            let v = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Tensor::F32(v, rows, cols)
        }
        d => bail!("{name}: unknown dtype {d}"),
    };
    Ok((name, t))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}
fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip() {
        let cfg = ModelConfig::tiny();
        let ck = Checkpoint::synthetic(&cfg, 11);
        let dir = std::env::temp_dir().join("btnz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.btnz");
        save(&ck, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.config, cfg);
        assert_eq!(back.tok_embed, ck.tok_embed);
        assert_eq!(back.lm_head, ck.lm_head);
        for (a, b) in back.layers.iter().zip(ck.layers.iter()) {
            assert_eq!(a.wq.q, b.wq.q);
            assert_eq!(a.wq.scale, b.wq.scale);
            assert_eq!(a.w_down.q, b.w_down.q);
            assert_eq!(a.attn_norm, b.attn_norm);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn ternary_file_is_compact() {
        // The ternary stream must be ~2 bits/weight, far below f32.
        let cfg = ModelConfig::tiny();
        let ck = Checkpoint::synthetic(&cfg, 12);
        let dir = std::env::temp_dir().join("btnz_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny2.btnz");
        save(&ck, &path).unwrap();
        let file_bytes = std::fs::metadata(&path).unwrap().len();
        let ternary_params = cfg.ternary_param_count();
        let fp_params = cfg.param_count() - ternary_params;
        // Expected: ternary at 0.25 B/param + fp at 4 B/param + slack.
        let expect = ternary_params / 4 + fp_params * 4;
        assert!(file_bytes < (expect as f64 * 1.05) as u64 + 4096, "{file_bytes} vs {expect}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("btnz_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.btnz");
        std::fs::write(&path, b"NOPE everything else").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn loaded_model_runs_identically() {
        use pallas_kernels::kernels::QuantType;
        use crate::model::Transformer;
        let cfg = ModelConfig::tiny();
        let ck = Checkpoint::synthetic(&cfg, 13);
        let dir = std::env::temp_dir().join("btnz_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny4.btnz");
        save(&ck, &path).unwrap();
        let loaded = load(&path).unwrap();
        let m1 = Transformer::from_checkpoint(&ck, QuantType::I2S, 1);
        let m2 = Transformer::from_checkpoint(&loaded, QuantType::I2S, 1);
        let mut s1 = m1.new_session(16);
        let mut s2 = m2.new_session(16);
        let l1 = m1.prefill(&mut s1, &[1, 2, 3]);
        let l2 = m2.prefill(&mut s2, &[1, 2, 3]);
        assert_eq!(l1, l2);
        std::fs::remove_file(&path).unwrap();
    }
}
