//! Minimal argument parser (clap is unavailable offline): subcommands,
//! `--flag value` options and positional arguments.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Declared option for help text.
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse argv (past the program name). The first non-flag token is the
    /// subcommand; `--name value` pairs become options unless `name` is in
    /// `bool_flags`.
    pub fn parse<I: Iterator<Item = String>>(argv: I, bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let Some(val) = it.next() else {
                        bail!("option --{name} expects a value");
                    };
                    out.options.insert(name.to_string(), val);
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> impl Iterator<Item = String> + '_ {
        s.split_whitespace().map(|t| t.to_string())
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(argv("serve --kernel TL2_0 --threads 4 --verbose extra"), &["verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("kernel"), Some("TL2_0"));
        assert_eq!(a.get_usize("threads", 1).unwrap(), 4);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv("run --kernel"), &[]).is_err());
    }

    #[test]
    fn bad_integer_errors() {
        let a = Args::parse(argv("run --threads abc"), &[]).unwrap();
        assert!(a.get_usize("threads", 1).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv("bench"), &[]).unwrap();
        assert_eq!(a.get_or("kernel", "I2_S"), "I2_S");
        assert_eq!(a.get_usize("threads", 2).unwrap(), 2);
    }
}
