//! PJRT runtime — loads the AOT artifacts produced by `python/compile/`
//! (Layer 1 Pallas kernel + Layer 2 JAX model lowered to HLO text) and
//! executes them on the `xla` crate's CPU PJRT client. This is the only
//! bridge between the Rust request path and the Python build path; Python
//! itself never runs at inference time.
//!
//! Interchange format is **HLO text** (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT-backed implementation needs the `xla` crate, which is not
//! available in the offline build image, so it is gated behind the
//! **`pjrt` cargo feature** (add the `xla` dependency before enabling).
//! Without the feature a stub with the identical API compiles in; every
//! entry point returns an "unavailable" error at run time, and the
//! PJRT tests / examples skip themselves when artifacts are absent.

use crate::config::Config;
use anyhow::Result;
#[cfg(not(feature = "pjrt"))]
use anyhow::{bail, Context};
#[cfg(feature = "pjrt")]
use anyhow::Context;
use std::path::{Path, PathBuf};

#[cfg(not(feature = "pjrt"))]
const UNAVAILABLE: &str = "PJRT runtime unavailable: bitnet was built without the `pjrt` \
     feature (requires the `xla` crate; see rust/Cargo.toml)";

/// A loaded PJRT CPU client.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(not(feature = "pjrt"))]
    _unconstructable: (),
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create the CPU PJRT client.
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform name reported by the client (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub: always errors (built without the `pjrt` feature).
    pub fn new() -> Result<Runtime> {
        bail!(UNAVAILABLE);
    }

    /// Stub platform name.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Stub: always errors (built without the `pjrt` feature).
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let _ = path;
        bail!(UNAVAILABLE);
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (file stem).
    pub name: String,
}

impl Executable {
    /// Human-readable identity string.
    pub fn describe(&self) -> String {
        format!("executable '{}'", self.name)
    }

    /// Execute with deterministic pseudo-random inputs per the manifest
    /// entry (CLI smoke path).
    pub fn execute_random(&self, entry: &ManifestEntry) -> Result<Vec<Vec<f32>>> {
        let mut rng = pallas_core::util::Rng::new(0xB17);
        let buffers: Vec<Vec<f32>> = entry
            .input_shapes
            .iter()
            .map(|dims| {
                let n: usize = dims.iter().product();
                (0..n).map(|_| rng.next_f32_signed()).collect()
            })
            .collect();
        let inputs: Vec<(&[f32], &[usize])> = buffers
            .iter()
            .zip(entry.input_shapes.iter())
            .map(|(b, d)| (b.as_slice(), d.as_slice()))
            .collect();
        self.execute_f32(&inputs)
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with f32 inputs of the given shapes. The artifact is lowered
    /// with `return_tuple=True`, so the single output literal is a tuple;
    /// each element comes back as a flat f32 vector.
    pub fn execute_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let n: usize = dims.iter().product();
                anyhow::ensure!(n == data.len(), "shape {:?} vs {} values", dims, data.len());
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let parts = result.to_tuple().context("untupling result")?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}")))
            .collect()
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    /// Stub: always errors (built without the `pjrt` feature).
    pub fn execute_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let _ = inputs;
        bail!(UNAVAILABLE);
    }
}

/// Input-shape metadata for one artifact, read from
/// `artifacts/manifest.toml` (written by `python/compile/aot.py`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Artifact name (manifest section / file stem).
    pub name: String,
    /// One shape per positional input.
    pub input_shapes: Vec<Vec<usize>>,
}

/// Parse a shape list like `"512;256x512"` → `[[512], [256, 512]]`.
pub fn parse_shapes(spec: &str) -> Result<Vec<Vec<usize>>> {
    spec.split(';')
        .filter(|s| !s.trim().is_empty())
        .map(|shape| {
            shape
                .trim()
                .split('x')
                .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad dim in {shape:?}")))
                .collect()
        })
        .collect()
}

/// Look up the manifest entry for an artifact path
/// (`<dir>/manifest.toml`, section named after the file stem).
pub fn manifest_for(artifact: &Path) -> Option<ManifestEntry> {
    let stem = artifact.file_stem()?.to_string_lossy().into_owned();
    // `foo.hlo.txt` → file_stem is `foo.hlo`; drop the inner extension too.
    let stem = stem.strip_suffix(".hlo").unwrap_or(&stem).to_string();
    let manifest_path: PathBuf = artifact.parent()?.join("manifest.toml");
    let cfg = Config::load(&manifest_path).ok()?;
    let spec = cfg.get(&format!("{stem}.inputs"))?.as_str()?.to_string();
    Some(ManifestEntry { name: stem, input_shapes: parse_shapes(&spec).ok()? })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_spec_parses() {
        assert_eq!(parse_shapes("512;256x512").unwrap(), vec![vec![512], vec![256, 512]]);
        assert_eq!(parse_shapes("4").unwrap(), vec![vec![4]]);
        assert!(parse_shapes("a").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let Err(err) = Runtime::new() else {
            panic!("stub Runtime::new must error");
        };
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    // PJRT-backed tests live in rust/tests/runtime_pjrt.rs (they need the
    // artifacts built by `make artifacts`).
}
