//! Engine telemetry: counters and latency histograms, lock-free on the
//! hot path (atomics), snapshotable for reporting.

use std::sync::atomic::{AtomicU64, Ordering};

/// Fixed-bucket log-scale latency histogram (µs): 1µs .. ~17min.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const N_BUCKETS: usize = 30;

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: std::time::Duration) {
        let us = d.as_micros() as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(N_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-quantile).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Engine-wide metrics.
#[derive(Default)]
pub struct EngineMetrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub prompt_tokens: AtomicU64,
    pub generated_tokens: AtomicU64,
    pub decode_steps: AtomicU64,
    /// Sum of batch sizes over decode steps (mean batch = this / steps).
    pub batched_tokens: AtomicU64,
    /// Widest decode batch any step ran (phase-aware dispatch keys on it).
    pub peak_batch: AtomicU64,
    /// Longest prefill chunk (prompt tokens) any step ran — the other
    /// phase-aware dispatch key (prefill GEMM batch width).
    pub peak_prefill_chunk: AtomicU64,
    /// Kernel selections that found no tuned profile entry for their
    /// (m, k, n) and fell back to the profile default — nonzero means the
    /// tuning profile doesn't cover the serving workload (re-tune).
    pub dispatch_fallbacks: AtomicU64,
    /// Routed calls that resolved a tuned winner but could not run it
    /// (alternate budget / K alignment) and degraded to the primary —
    /// nonzero means a tuned winner is not actually live.
    pub dispatch_degraded: AtomicU64,
    /// Prepare-once cache: projections that reused an input's prepared
    /// batch instead of re-running preprocessing (wk/wv after wq, up
    /// after gate). High hit counts = amortization is working.
    pub prepare_cache_hits: AtomicU64,
    /// Prepare-once cache: preprocessing runs (one per layer input ×
    /// kernel, not one per projection).
    pub prepare_cache_misses: AtomicU64,
    /// Fresh prepare-buffer allocations. This stops growing once shapes
    /// are warm — steady-state decode is allocation-free in the prepare
    /// path.
    pub prepare_buffer_allocs: AtomicU64,
    /// Prepare builds that fully reused existing buffer capacity.
    pub prepare_buffer_reuses: AtomicU64,
    /// Engine steps recorded into the serving-shape trace (the histogram
    /// `tune --trace` consumes; steps that ran no GEMM don't count).
    pub trace_steps: AtomicU64,
    /// Distinct GEMM batch shapes (prefill chunk lengths + decode
    /// widths) the trace has observed — a small number that stops
    /// growing means the tuning sweep derived from this trace is cheap.
    pub trace_shapes: AtomicU64,
    /// KV arena pages currently held by running sequences.
    pub kv_pages_used: AtomicU64,
    /// High-water mark of held KV pages — with lazy minting this is also
    /// (pages-wise) the resident slab footprint.
    pub kv_pages_peak: AtomicU64,
    /// Total pages the KV budget allows (`kv_budget_tokens`, rounded up).
    pub kv_pages_total: AtomicU64,
    /// Bytes of KV slab storage actually allocated (minted pages only —
    /// proportional to the peak working set, not the worst-case budget).
    pub kv_resident_bytes: AtomicU64,
    /// Bytes the full KV page budget would occupy if every page minted.
    pub kv_capacity_bytes: AtomicU64,
    /// Sequences preempted back to Waiting because a decode-growth page
    /// reservation found the arena exhausted (they re-prefill on
    /// re-admission) — the price of watermark over worst-case admission.
    pub kv_preemptions: AtomicU64,
    /// Prompt tokens that actually went through a prefill GEMM (streamed
    /// chunks and preemption re-prefills included). With prefix sharing
    /// this runs *below* `prompt_tokens`: the gap is work the radix index
    /// saved.
    pub prefill_tokens_computed: AtomicU64,
    /// Prompt tokens served straight from the arena's radix prefix index
    /// (mapped copy-on-write instead of recomputed).
    pub prefix_hit_tokens: AtomicU64,
    /// Shared pages privately copied because a sequence wrote into them
    /// (copy-on-write splits).
    pub kv_cow_splits: AtomicU64,
    /// Tune-vs-serve shape drift (`ServingTrace::drift_l1` against the
    /// active tuning profile), stored ×1000 (milli-units) so the hot path
    /// stays integer-atomic. Zero when no profile is loaded.
    pub drift_l1_milli: AtomicU64,
    /// The SIMD dispatch tier the kernels run at, as
    /// `pallas_kernels::kernels::SimdLevel as u8` (0 scalar, 1 avx2, 2 neon) —
    /// mirrored at snapshot time ([`EngineMetrics::mirror_simd`]).
    pub simd_level: AtomicU64,
    /// Cumulative `gemv_rows` dispatches per SIMD tier, indexed
    /// `[scalar, avx2, neon]`. Mirrored from the kernel layer's global
    /// counters, so the numbers are process-wide, not per engine.
    pub simd_calls: [AtomicU64; 3],
    /// Cumulative weight blocks elided by the block-skip sparse layout,
    /// per SIMD tier, indexed `[scalar, avx2, neon]`. Mirrored from
    /// `pallas_kernels::kernels::sparse::elided_counts` like `simd_calls` —
    /// zero everywhere means no tensor packed sparse (iid-dense weights
    /// or a forced `--sparse off`).
    pub sparse_elided: [AtomicU64; 3],
    /// NUMA nodes the compute pool spans (1 ⇒ placement off).
    pub numa_nodes: AtomicU64,
    /// Pool chunks executed by each node's threads, indexed by node id
    /// (capped at [`EngineMetrics::MAX_NUMA_NODES`]). Mirrored from
    /// `ThreadPool::numa_stats` — every node having a nonzero count is
    /// the observable proof that row partitions ran where their weights
    /// live.
    pub numa_chunks: [AtomicU64; EngineMetrics::MAX_NUMA_NODES],
    /// Chunks a node executed from a foreign node's queue (cross-node
    /// steals in placed jobs — occasional rebalancing is healthy, a
    /// large share means the placement split is skewed).
    pub numa_steals: AtomicU64,
    /// KV slab bytes resident on each node (first-touch interleaving),
    /// same indexing as `numa_chunks`.
    pub numa_kv_bytes: [AtomicU64; EngineMetrics::MAX_NUMA_NODES],
    /// Cumulative forward-pass attention microseconds (the paged-KV
    /// fused attend), mirrored from the model's `PhaseStats` once per
    /// step — the per-phase decode profile's attention share.
    pub phase_attn_us: AtomicU64,
    /// Cumulative mpGEMM microseconds (BitLinear projections, their
    /// prepare-once preprocessing, and the f16 LM head).
    pub phase_gemm_us: AtomicU64,
    /// Cumulative other-ops microseconds (norms, RoPE, SwiGLU, KV
    /// appends, residual plumbing).
    pub phase_other_us: AtomicU64,
    pub step_latency: LatencyHistogram,
    pub ttft: LatencyHistogram,
}

impl EngineMetrics {
    /// Per-node counter slots (nodes beyond this are folded off the
    /// report — commodity boards stop at 8 sockets).
    pub const MAX_NUMA_NODES: usize = 8;

    pub fn new() -> EngineMetrics {
        EngineMetrics::default()
    }

    /// Copy the compute pool's per-node dispatch counters and the KV
    /// arena's per-node resident bytes into this snapshot (same mirror
    /// pattern as the SIMD and prepare-cache counters).
    pub fn mirror_numa(&self, stats: &pallas_core::threadpool::NumaStats, kv_by_node: &[usize]) {
        self.numa_nodes.store(stats.nodes as u64, Ordering::Relaxed);
        self.numa_steals.store(stats.steals, Ordering::Relaxed);
        for (i, slot) in self.numa_chunks.iter().enumerate() {
            slot.store(stats.chunks.get(i).copied().unwrap_or(0), Ordering::Relaxed);
        }
        for (i, slot) in self.numa_kv_bytes.iter().enumerate() {
            slot.store(kv_by_node.get(i).copied().unwrap_or(0) as u64, Ordering::Relaxed);
        }
    }

    /// The summary's NUMA segment: `numa off` on single-node pools, else
    /// per-node chunk counts, per-node resident KV KiB and the steal
    /// count.
    fn numa_summary(&self) -> String {
        let n = (self.numa_nodes.load(Ordering::Relaxed) as usize).min(Self::MAX_NUMA_NODES);
        if n <= 1 {
            return "numa off".to_string();
        }
        let chunks: Vec<String> = self.numa_chunks[..n]
            .iter()
            .map(|c| c.load(Ordering::Relaxed).to_string())
            .collect();
        let kv: Vec<String> = self.numa_kv_bytes[..n]
            .iter()
            .map(|c| (c.load(Ordering::Relaxed) / 1024).to_string())
            .collect();
        format!(
            "numa {n} nodes (chunks {}, kv KiB {}, steals {})",
            chunks.join("/"),
            kv.join("/"),
            self.numa_steals.load(Ordering::Relaxed)
        )
    }

    /// Copy the kernel layer's process-wide SIMD dispatch state (active
    /// level + per-level call counters) into this snapshot — the same
    /// mirror pattern as the prepare-cache and KV-arena counters: the
    /// hot path touches only the kernel-layer atomics, the engine copies
    /// them here once per step.
    pub fn mirror_simd(&self) {
        self.simd_level
            .store(pallas_kernels::kernels::simd::active_level() as u8 as u64, Ordering::Relaxed);
        let counts = pallas_kernels::kernels::simd::call_counts();
        for (slot, c) in self.simd_calls.iter().zip(counts) {
            slot.store(c, Ordering::Relaxed);
        }
        let elided = pallas_kernels::kernels::sparse::elided_counts();
        for (slot, c) in self.sparse_elided.iter().zip(elided) {
            slot.store(c, Ordering::Relaxed);
        }
    }

    /// Total elided weight blocks across SIMD tiers (mirrored state).
    pub fn sparse_elided_total(&self) -> u64 {
        self.sparse_elided.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Copy the model's cumulative per-phase forward-pass split
    /// (attention vs mpGEMM vs other ops, in µs) into this snapshot —
    /// same mirror pattern as the SIMD and prepare-cache counters.
    pub fn mirror_phase(&self, phase_us: (u64, u64, u64)) {
        let (a, g, o) = phase_us;
        self.phase_attn_us.store(a, Ordering::Relaxed);
        self.phase_gemm_us.store(g, Ordering::Relaxed);
        self.phase_other_us.store(o, Ordering::Relaxed);
    }

    /// The summary's phase segment: cumulative µs per phase plus each
    /// phase's share of the accounted forward-pass time.
    fn phase_summary(&self) -> String {
        let a = self.phase_attn_us.load(Ordering::Relaxed);
        let g = self.phase_gemm_us.load(Ordering::Relaxed);
        let o = self.phase_other_us.load(Ordering::Relaxed);
        let total = (a + g + o).max(1);
        format!(
            "phase µs attn/gemm/other {a}/{g}/{o} ({:.0}%/{:.0}%/{:.0}%)",
            100.0 * a as f64 / total as f64,
            100.0 * g as f64 / total as f64,
            100.0 * o as f64 / total as f64
        )
    }

    /// The mirrored SIMD tier's display name (see [`EngineMetrics::mirror_simd`]).
    pub fn simd_level_name(&self) -> &'static str {
        match self.simd_level.load(Ordering::Relaxed) {
            1 => "avx2",
            2 => "neon",
            _ => "scalar",
        }
    }

    /// The mirrored tune-vs-serve shape drift as its natural f64 (see
    /// `drift_l1_milli` for the storage encoding).
    pub fn drift_l1(&self) -> f64 {
        self.drift_l1_milli.load(Ordering::Relaxed) as f64 / 1000.0
    }

    pub fn mean_batch(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        if steps == 0 {
            0.0
        } else {
            self.batched_tokens.load(Ordering::Relaxed) as f64 / steps as f64
        }
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "req {}/{} done, {} rejected | tokens {}+{} | steps {} (mean batch {:.2}, peak {}) | step mean {:.1}µs p99 {}µs | ttft mean {:.1}µs | {} | dispatch fallbacks {} degraded {} | simd {} (calls scalar/avx2/neon {}/{}/{}) | sparse elided scalar/avx2/neon {}/{}/{} | prepare {} hits / {} misses (buffers {} reused, {} alloc'd) | trace {} steps / {} shapes (drift {:.3}) | kv {}/{} pages (peak {}) {} KiB resident, {} preemptions | prefix {} hit / {} computed tokens, {} cow splits | {}",
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.prompt_tokens.load(Ordering::Relaxed),
            self.generated_tokens.load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
            self.mean_batch(),
            self.peak_batch.load(Ordering::Relaxed),
            self.step_latency.mean_us(),
            self.step_latency.quantile_us(0.99),
            self.ttft.mean_us(),
            self.phase_summary(),
            self.dispatch_fallbacks.load(Ordering::Relaxed),
            self.dispatch_degraded.load(Ordering::Relaxed),
            self.simd_level_name(),
            self.simd_calls[0].load(Ordering::Relaxed),
            self.simd_calls[1].load(Ordering::Relaxed),
            self.simd_calls[2].load(Ordering::Relaxed),
            self.sparse_elided[0].load(Ordering::Relaxed),
            self.sparse_elided[1].load(Ordering::Relaxed),
            self.sparse_elided[2].load(Ordering::Relaxed),
            self.prepare_cache_hits.load(Ordering::Relaxed),
            self.prepare_cache_misses.load(Ordering::Relaxed),
            self.prepare_buffer_reuses.load(Ordering::Relaxed),
            self.prepare_buffer_allocs.load(Ordering::Relaxed),
            self.trace_steps.load(Ordering::Relaxed),
            self.trace_shapes.load(Ordering::Relaxed),
            self.drift_l1(),
            self.kv_pages_used.load(Ordering::Relaxed),
            self.kv_pages_total.load(Ordering::Relaxed),
            self.kv_pages_peak.load(Ordering::Relaxed),
            self.kv_resident_bytes.load(Ordering::Relaxed) / 1024,
            self.kv_preemptions.load(Ordering::Relaxed),
            self.prefix_hit_tokens.load(Ordering::Relaxed),
            self.prefill_tokens_computed.load(Ordering::Relaxed),
            self.kv_cow_splits.load(Ordering::Relaxed),
            self.numa_summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_us() > 2000.0);
        assert_eq!(h.max_us(), 10_000);
        // p50 bucket upper bound covers ≤ 40µs values.
        assert!(h.quantile_us(0.5) <= 64);
        assert!(h.quantile_us(1.0) >= 10_000 / 2);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn simd_mirror_reports_a_known_level() {
        let m = EngineMetrics::new();
        assert_eq!(m.simd_level_name(), "scalar", "unmirrored default");
        m.mirror_simd();
        assert!(["scalar", "avx2", "neon"].contains(&m.simd_level_name()));
        // The summary line renders the mirrored state.
        assert!(m.summary().contains("simd "));
        assert!(m.summary().contains("sparse elided "));
    }

    #[test]
    fn sparse_elided_mirror_tracks_kernel_counters() {
        use pallas_kernels::kernels::{sparse, SimdLevel};
        let m = EngineMetrics::new();
        m.mirror_simd();
        let before = m.sparse_elided_total();
        sparse::note_elided(SimdLevel::Scalar, 7);
        m.mirror_simd();
        assert!(m.sparse_elided_total() >= before + 7);
    }

    #[test]
    fn drift_and_prefix_metrics_render_in_summary() {
        let m = EngineMetrics::new();
        m.drift_l1_milli.store(125, Ordering::Relaxed);
        m.prefix_hit_tokens.store(32, Ordering::Relaxed);
        m.prefill_tokens_computed.store(48, Ordering::Relaxed);
        m.kv_cow_splits.store(2, Ordering::Relaxed);
        assert_eq!(m.drift_l1(), 0.125);
        let s = m.summary();
        assert!(s.contains("drift 0.125"), "{s}");
        assert!(s.contains("prefix 32 hit / 48 computed tokens, 2 cow splits"), "{s}");
    }

    #[test]
    fn numa_segment_renders_off_and_per_node() {
        use pallas_core::threadpool::NumaStats;
        let m = EngineMetrics::new();
        assert!(m.summary().contains("numa off"), "unmirrored default");
        m.mirror_numa(
            &NumaStats { nodes: 2, mocked: true, chunks: vec![10, 7], steals: 3 },
            &[2048, 1024],
        );
        let s = m.summary();
        assert!(s.contains("numa 2 nodes (chunks 10/7, kv KiB 2/1, steals 3)"), "{s}");
        // Back to a single-node pool: the segment collapses again.
        m.mirror_numa(&NumaStats { nodes: 1, mocked: false, chunks: vec![4], steals: 0 }, &[64]);
        assert!(m.summary().contains("numa off"));
    }

    #[test]
    fn phase_segment_renders_in_summary() {
        let m = EngineMetrics::new();
        m.mirror_phase((120, 300, 80));
        let s = m.summary();
        assert!(s.contains("phase µs attn/gemm/other 120/300/80 (24%/60%/16%)"), "{s}");
    }

    #[test]
    fn mean_batch_math() {
        let m = EngineMetrics::new();
        m.decode_steps.store(4, Ordering::Relaxed);
        m.batched_tokens.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch(), 2.5);
    }
}
