//! `bitnet` — the Bitnet.cpp-reproduction launcher.
//!
//! Subcommands:
//!   info                         print the kernel library (paper Table 1)
//!   gen-model                    generate a synthetic BTNZ checkpoint
//!   run                          generate tokens from a prompt
//!   serve                        run the batching engine on a synthetic workload
//!   tune                         micro-benchmark kernels, write a tuning profile
//!   pjrt                         execute an AOT artifact through PJRT
//!
//! Common options: --preset tiny|100M|700M|…, --kernel I2_S|TL2_0|…|auto
//! (--qtype is an alias), --tune-profile profile.json, --threads N,
//! --config path.toml. See README for examples.

use anyhow::{bail, Context, Result};
use crate::cli::Args;
use crate::config::{Config, LaunchConfig};
use crate::coordinator::trace::DRIFT_WARN_L1;
use crate::coordinator::{Engine, EngineConfig, KvDtype, Request, ServingTrace};
use pallas_kernels::kernels::tuner::{self, TuneConfig, TuningProfile};
use pallas_model::tuner_e2e::{self, OverrideSearchConfig};
use pallas_kernels::kernels::{
    library_table, simd, sparse, Dispatch, DispatchPlan, QuantType, SimdLevel,
};
use pallas_kernels::kernels::sparse::SparseMode;
use pallas_model::model::{ModelConfig, SamplingParams, Transformer};
use pallas_model::model::weights::Checkpoint;
use pallas_model::tokenizer::{synthetic_corpus, Tokenizer};
use std::path::{Path, PathBuf};

/// Binary entry point, called by the facade's `src/main.rs`.
pub fn cli_main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: bitnet <info|gen-model|run|serve|tune|pjrt> [options]
  info
  gen-model --preset tiny --seed 42 --out model.btnz
  run       --preset tiny --kernel I2_S --threads 1 --prompt 'text' --max-new 32
            [--model model.btnz] [--temperature 0.0]
            [--qtype auto --tune-profile profile.json]
            [--kv-dtype f32|f16] [--record-trace trace.json] [--verbose]
  serve     --preset tiny --kernel TL2_0 --threads 2 --requests 16 --max-batch 8
            [--qtype auto --tune-profile profile.json]
            [--kv-dtype f32|f16] [--kv-budget 8192]
            [--prefix-cache on|off] [--prefill-chunk N] [--shared-prefix N]
            [--record-trace trace.json]
  tune      --out profile.json [--preset tiny] [--threads 1] [--batches 1,4]
            [--trace trace.json] [--trace-widths 16] [--search-overrides]
            [--kernels I2_S,TL1_0,…|all] [--measure-ms 60] [--e2e] [--verbose]
            (default candidates: compact ternary kernels; `all` adds the
             dense/general baselines; --e2e additionally measures the
             tuned profile end to end against the fixed default and
             records the result in the profile's `e2e` section)
  pjrt      --artifact artifacts/ternary_matmul.hlo.txt

  --qtype is an alias of --kernel; the value `auto` selects the kernel
  per projection shape, per layer and per batch width from the
  --tune-profile file (v1 and v2 profiles load; see docs/tuning.md).
  Under auto, prefill chunks and batched decode re-dispatch per call
  using the profile's n>1 entries — `--verbose` prints the per-layer,
  per-phase winners.

  Trace-driven tuning closes the loop: `run`/`serve --record-trace`
  persist the shape histogram the workload exhibited; `tune --trace`
  sweeps exactly those shapes (replacing --batches) weighted by their
  observed frequency; `tune --search-overrides` additionally sweeps
  first/last-vs-middle per-layer kernel compositions end to end and
  writes the winning LayerOverride rows into the profile. Under auto
  dispatch, run/serve compare the live shape histogram against the
  profile's tuned widths and warn when traffic has drifted (re-tune).

  KV memory is paged: --kv-budget caps total KV tokens across
  sequences, --kv-dtype f16 halves resident KV bytes (f32 stays
  bit-exact); the scheduler admits on prompt-fit and preempts
  LIFO under pressure. --prefix-cache on shares KV pages across
  sequences with a common prompt prefix (copy-on-write, radix
  prompt index); --prefill-chunk N streams long prompts into the
  cache N tokens per step instead of admitting all-or-nothing;
  --shared-prefix N prepends an N-token synthetic system prompt
  to every serve request (prefix-sharing workloads).
  See docs/serving.md.

  --simd auto|scalar|avx2|neon (any subcommand) pins the kernels'
  SIMD dispatch tier; `auto` (the default) probes the CPU. Unsupported
  requests clamp to what the host can run, with a warning. The scalar
  and vector paths are bit-identical (docs/kernels.md); `tune` measures
  every usable tier and records the winner's tier in the profile, and
  profiles tuned with a vector winner degrade to their fastest usable
  measurement on hosts without it (counted in dispatch fallbacks).
  RUST_PALLAS_SIMD=<tier> is the env equivalent (tests/CI).

  --numa auto|off (any subcommand) controls NUMA-aware execution:
  `auto` (the default) reads /sys/devices/system/node and, on a
  multi-node host, pins per-node worker groups, first-touches weight
  packs and KV pages on their owning node, and routes GEMM row ranges
  to the node owning those rows; `off` (or any single-node host) runs
  the pre-NUMA scheduling. Results are bit-identical either way; the
  engine summary reports per-node chunk counts, resident KV bytes and
  cross-node steals. RUST_PALLAS_NUMA=<mode> is the env equivalent and
  RUST_PALLAS_NUMA_MOCK=<n> synthesizes an n-node topology without
  pinning (tests/CI).

  --sparse auto|on|off (any subcommand) controls the block-skip sparse
  layout the ternary kernels emit at pack time: `auto` (the default)
  measures each tensor's zero-block fraction and packs sparse past the
  threshold, `on` forces the layout, `off` packs everything dense.
  Sparse and dense results are bit-identical; elided-block counts per
  SIMD tier appear in the engine metrics and under `run --verbose`.
  RUST_PALLAS_SPARSE=<mode> is the env equivalent (tests/CI).";

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["help", "verbose", "e2e", "search-overrides"])?;
    if args.has_flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    // Pin the SIMD dispatch tier before any kernel work (packing,
    // tuning and serving all route through it). "auto" leaves the
    // lazy CPU-detection default in place.
    if let Some(s) = args.get("simd") {
        if !s.eq_ignore_ascii_case("auto") {
            let level = SimdLevel::parse(s).with_context(|| {
                format!("unknown --simd level {s:?} (expected auto, scalar, avx2 or neon)")
            })?;
            let applied = simd::set_level(level);
            if applied != level {
                eprintln!(
                    "warning: --simd {} is not available on this host; running at {}",
                    level.name(),
                    applied.name()
                );
            }
        }
    }
    // Pick the sparse packing mode before any tensor packs (overrides
    // the RUST_PALLAS_SPARSE env default).
    if let Some(s) = args.get("sparse") {
        let mode = SparseMode::parse(s)
            .with_context(|| format!("unknown --sparse mode {s:?} (expected auto, on or off)"))?;
        sparse::set_mode(mode);
    }
    // Resolve NUMA placement before the shared pool exists (the first
    // pool construction detects the topology; a later set_mode is a
    // no-op). Overrides the RUST_PALLAS_NUMA env default.
    if let Some(s) = args.get("numa") {
        let mode = pallas_core::topology::NumaMode::parse(s)
            .with_context(|| format!("unknown --numa mode {s:?} (expected auto or off)"))?;
        pallas_core::topology::set_mode(mode);
    }
    match args.subcommand.as_deref().unwrap() {
        "info" => cmd_info(),
        "gen-model" => cmd_gen_model(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "tune" => cmd_tune(&args),
        "pjrt" => cmd_pjrt(&args),
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn launch_config(args: &Args) -> Result<LaunchConfig> {
    let mut lc = match args.get("config") {
        Some(path) => LaunchConfig::from_config(&Config::load(&PathBuf::from(path))?),
        None => LaunchConfig::default(),
    };
    if let Some(p) = args.get("preset") {
        lc.model_preset = p.to_string();
    }
    // --qtype is an alias of --kernel (last one on the command line wins
    // is not supported by the mini-parser, so --qtype takes precedence).
    if let Some(k) = args.get("kernel") {
        lc.kernel = k.to_string();
    }
    if let Some(k) = args.get("qtype") {
        lc.kernel = k.to_string();
    }
    if let Some(p) = args.get("tune-profile") {
        lc.tune_profile = Some(p.to_string());
    }
    if let Some(m) = args.get("model") {
        lc.model_path = Some(m.to_string());
    }
    lc.threads = args.get_usize("threads", lc.threads)?;
    lc.max_batch = args.get_usize("max-batch", lc.max_batch)?;
    lc.kv_budget_tokens = args.get_usize("kv-budget", lc.kv_budget_tokens)?;
    if let Some(d) = args.get("kv-dtype") {
        lc.kv_dtype = d.to_string();
    }
    lc.seed = args.get_usize("seed", lc.seed as usize)? as u64;
    Ok(lc)
}

/// Resolve the `--kv-dtype`/config value into a [`KvDtype`].
fn build_kv_dtype(lc: &LaunchConfig) -> Result<KvDtype> {
    KvDtype::parse(&lc.kv_dtype)
        .with_context(|| format!("unknown --kv-dtype {:?} (expected f32 or f16)", lc.kv_dtype))
}

/// Warn when the shapes a run actually exhibited drifted from the widths
/// its tuning profile was measured at (ROADMAP: re-tune triggers from
/// serving). `profile_widths` comes from
/// `TuningProfile::weighted_widths()` captured at profile load; empty
/// when dispatch is fixed or the profile has no entries.
fn warn_on_trace_drift(profile_widths: &[(usize, f64)], trace: &ServingTrace) {
    if profile_widths.is_empty() || trace.is_empty() {
        return;
    }
    let drift = trace.drift_l1(profile_widths);
    if drift > DRIFT_WARN_L1 {
        eprintln!(
            "warning: live serving shapes drifted from the tuning profile \
             (L1 distance {drift:.2} > {DRIFT_WARN_L1}): the profile was measured at batch \
             widths this workload no longer runs; re-record with --record-trace and re-run \
             `bitnet tune --trace <trace.json>`"
        );
    }
}

/// The tuned batch-width distribution to check serving drift against —
/// captured before the model moves into the engine.
fn profile_widths_of(model: &Transformer) -> Vec<(usize, f64)> {
    match model.plan.dispatch() {
        Dispatch::Auto(profile) => profile.weighted_widths(),
        Dispatch::Fixed(_) => Vec::new(),
    }
}

/// Resolve the `--kernel`/`--qtype` value into a dispatch policy.
fn build_dispatch(lc: &LaunchConfig) -> Result<Dispatch> {
    if lc.kernel.eq_ignore_ascii_case("auto") {
        let path = lc.tune_profile.as_deref().with_context(|| {
            "--qtype auto requires --tune-profile <path> (generate one with `bitnet tune --out profile.json`)"
                .to_string()
        })?;
        let profile = TuningProfile::load(Path::new(path))?;
        if profile.threads != lc.threads {
            eprintln!(
                "warning: profile was tuned at {} threads but running with {} — \
                 selections may be stale (re-run `bitnet tune --threads {}`)",
                profile.threads, lc.threads, lc.threads
            );
        }
        Ok(Dispatch::Auto(profile))
    } else {
        let qtype = QuantType::parse(&lc.kernel)
            .with_context(|| format!("unknown kernel {:?}", lc.kernel))?;
        Ok(Dispatch::Fixed(qtype))
    }
}

fn build_model(lc: &LaunchConfig, verbose: bool) -> Result<Transformer> {
    let dispatch = build_dispatch(lc)?;
    let plan = DispatchPlan::new(dispatch).with_verbose(verbose);
    let ck = match &lc.model_path {
        Some(path) => pallas_model::modelio::load(&PathBuf::from(path))?,
        None => {
            let cfg = ModelConfig::preset(&lc.model_preset)
                .with_context(|| format!("unknown preset {:?}", lc.model_preset))?;
            Checkpoint::synthetic(&cfg, lc.seed)
        }
    };
    let model = Transformer::from_checkpoint_plan(&ck, plan, lc.threads);
    eprintln!(
        "model {} ({:.1}M params, {:.1}M ternary) dispatch {} threads {} simd {}",
        ck.config.name,
        ck.config.param_count() as f64 / 1e6,
        ck.config.ternary_param_count() as f64 / 1e6,
        model.plan.describe(),
        lc.threads,
        simd::active_level().name()
    );
    if verbose {
        for (m, k, q) in model.kernel_summary() {
            eprintln!("dispatch: {m}x{k} -> {} (n=1 primary)", q.name());
        }
        // Per-layer, per-phase winners (decode n=1 vs a representative
        // prefill chunk): the phase-aware picture behind the primaries.
        for line in model.plan_summary(lc.max_batch.max(8)) {
            eprintln!("plan: {line}");
        }
    }
    Ok(model)
}

fn cmd_info() -> Result<()> {
    println!("Bitnet.cpp ternary mpGEMM library (paper Table 1 + baselines)");
    println!("{:<9} {:<10} {:<13} {:>6} {:>9} {:>7}", "kernel", "class", "unit", "bpw", "lossless", "K mult");
    for info in library_table() {
        println!(
            "{:<9} {:<10} {:<13} {:>6.2} {:>9} {:>7}",
            info.name,
            match info.class {
                pallas_kernels::kernels::KernelClass::LutBased => "LUT",
                pallas_kernels::kernels::KernelClass::MadBased => "MAD",
            },
            if info.element_wise { "element-wise" } else { "bit-wise" },
            info.bpw,
            if info.lossless { "yes" } else { "no" },
            info.k_multiple
        );
    }
    Ok(())
}

fn cmd_gen_model(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "tiny");
    let seed = args.get_usize("seed", 42)? as u64;
    let out = PathBuf::from(args.get_or("out", "model.btnz"));
    let cfg = ModelConfig::preset(&preset).with_context(|| format!("unknown preset {preset:?}"))?;
    let ck = Checkpoint::synthetic(&cfg, seed);
    pallas_model::modelio::save(&ck, &out)?;
    println!(
        "wrote {} ({} params, {} bytes)",
        out.display(),
        cfg.param_count(),
        std::fs::metadata(&out)?.len()
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let lc = launch_config(args)?;
    let model = build_model(&lc, args.has_flag("verbose"))?;
    let prompt_text = args.get_or("prompt", "the ternary model");
    let max_new = args.get_usize("max-new", 32)?;
    let temperature: f32 = args.get_or("temperature", "0.0").parse().context("--temperature")?;

    let kv_dtype = build_kv_dtype(&lc)?;
    let tok = Tokenizer::train(&synthetic_corpus(5000, 1), model.cfg.vocab_size.min(2048));
    let prompt = tok.encode(&prompt_text);
    let mut session = model.new_session_dtype(prompt.len() + max_new, kv_dtype);

    let t0 = std::time::Instant::now();
    let mut logits = model.prefill(&mut session, &prompt);
    let prefill_time = t0.elapsed();
    let phase_prefill = model.phase_us();

    let params = SamplingParams { temperature, top_k: 40, top_p: 0.95 };
    let mut rng = pallas_core::util::Rng::new(lc.seed);
    let mut generated = Vec::new();
    let t1 = std::time::Instant::now();
    for _ in 0..max_new {
        let next = pallas_model::model::sample(&logits, &params, &mut rng);
        generated.push(next);
        logits = model.decode_step(&mut session, next);
    }
    let decode_time = t1.elapsed();
    let phase_total = model.phase_us();

    println!("{}", tok.decode(&generated));
    eprintln!(
        "prefill {} tok in {:.1} ms | decode {} tok in {:.1} ms ({:.2} tok/s)",
        prompt.len(),
        prefill_time.as_secs_f64() * 1e3,
        max_new,
        decode_time.as_secs_f64() * 1e3,
        max_new as f64 / decode_time.as_secs_f64()
    );
    if args.has_flag("verbose") {
        // Prepare-once observability: one miss per layer input × kernel,
        // hits for every projection that shared it (wk/wv, up); buffer
        // allocs must flatline once shapes are warm.
        let ps = model.prepare_stats();
        eprintln!(
            "prepare cache: {} hits / {} misses | buffers: {} reused, {} alloc'd",
            ps.hits, ps.misses, ps.buffer_reuses, ps.buffer_allocs
        );
        // Per-phase decode profile: where each decode step's time went
        // (paged-KV fused attention vs mpGEMM projections vs the other
        // ops) — the decode-only delta between the two phase snapshots.
        let steps = max_new.max(1) as u64;
        let attn_us = phase_total.0.saturating_sub(phase_prefill.0);
        let gemm_us = phase_total.1.saturating_sub(phase_prefill.1);
        let other_us = phase_total.2.saturating_sub(phase_prefill.2);
        eprintln!(
            "decode phase: attention {}µs + mpGEMM {}µs + other ops {}µs per step (prefill totals {}/{}/{}µs)",
            attn_us / steps,
            gemm_us / steps,
            other_us / steps,
            phase_prefill.0,
            phase_prefill.1,
            phase_prefill.2
        );
        // Attention workspace: allocs flatline once the score buffer
        // covers the longest context seen (steady-state decode attention
        // is allocation-free).
        let (ws_allocs, ws_reuses) = session.attn_workspace_stats();
        eprintln!("attn workspace: {ws_allocs} allocs, {ws_reuses} reuses");
        // KV arena stats: pages actually held and their resident bytes
        // (lazy minting — not the worst-case capacity).
        eprintln!(
            "kv arena: {} pages held, {} KV bytes resident ({} dtype)",
            session.held_pages(),
            session.kv_bytes(),
            kv_dtype.name()
        );
        // Block-skip elision: weight blocks the sparse layout skipped,
        // per SIMD tier. All zeros = every tensor packed dense (iid
        // ternary under --sparse auto, or a forced off).
        let el = sparse::elided_counts();
        eprintln!(
            "sparse ({}): elided blocks scalar/avx2/neon {}/{}/{}",
            sparse::mode().name(),
            el[0],
            el[1],
            el[2]
        );
    }
    // The shape histogram this run exhibited: one prefill chunk of the
    // prompt length, then `max_new` single-sequence decode steps — used
    // for the profile-drift check and, with --record-trace, persisted
    // for `tune --trace`.
    let mut trace = ServingTrace::new();
    trace.record_prefill(prompt.len());
    for _ in 0..max_new {
        trace.record_decode(1);
    }
    trace.steps = 1 + max_new as u64;
    warn_on_trace_drift(&profile_widths_of(&model), &trace);
    if let Some(tp) = args.get("record-trace") {
        trace.save(Path::new(tp))?;
        eprintln!("wrote trace {tp} ({})", trace.summary());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let lc = launch_config(args)?;
    let n_requests = args.get_usize("requests", 16)?;
    let max_new = args.get_usize("max-new", 16)?;
    let kv_dtype = build_kv_dtype(&lc)?;
    let prefix_cache = match args.get_or("prefix-cache", "off").as_str() {
        "on" => true,
        "off" => false,
        other => bail!("unknown --prefix-cache {other:?} (expected on or off)"),
    };
    let prefill_chunk = args.get_usize("prefill-chunk", 0)?;
    let shared_prefix = args.get_usize("shared-prefix", 0)?;
    let model = build_model(&lc, args.has_flag("verbose"))?;
    let vocab = model.cfg.vocab_size as u32;
    let profile_widths = profile_widths_of(&model);
    let engine = Engine::start(
        model,
        EngineConfig {
            max_batch: lc.max_batch,
            kv_budget_tokens: lc.kv_budget_tokens,
            eos_token: 1,
            seed: lc.seed,
            kv_dtype,
            prefix_cache,
            prefill_chunk,
            profile_widths: profile_widths.clone(),
        },
    );
    let mut rng = pallas_core::util::Rng::new(lc.seed + 1);
    // The shared-prefix workload: every request opens with the same
    // deterministic N-token "system prompt" before its random tail —
    // the traffic shape prefix caching is built for.
    let system: Vec<u32> =
        (0..shared_prefix).map(|i| 3 + (i * 17 + 5) as u32 % (vocab - 3)).collect();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|_| {
            let len = 4 + rng.next_below(12);
            let mut prompt = system.clone();
            prompt.extend((0..len).map(|_| 3 + rng.next_below(vocab as usize - 3) as u32));
            engine.submit(Request::greedy(prompt, max_new))
        })
        .collect();
    let mut total_tokens = 0usize;
    for h in handles {
        let (tokens, reason, stats) = h.wait();
        total_tokens += tokens.len();
        if args.has_flag("verbose") {
            eprintln!("req done: {} tokens, {:?}, ttft {:.1}ms", tokens.len(), reason, stats.ttft.as_secs_f64() * 1e3);
        }
    }
    let wall = t0.elapsed();
    println!(
        "served {n_requests} requests, {total_tokens} tokens in {:.2}s → {:.2} tok/s aggregate",
        wall.as_secs_f64(),
        total_tokens as f64 / wall.as_secs_f64()
    );
    println!("engine: {}", engine.metrics.summary());
    // KV arena footprint: resident bytes track the peak pages actually
    // minted, never the worst-case budget — enforced here so the CI
    // serve smoke fails loudly if paging ever regresses to eager
    // worst-case allocation.
    let resident = engine.metrics.kv_resident_bytes.load(std::sync::atomic::Ordering::Relaxed);
    let budget = engine.metrics.kv_capacity_bytes.load(std::sync::atomic::Ordering::Relaxed);
    let preemptions = engine.metrics.kv_preemptions.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "kv arena: {kv} dtype, {resident} of {budget} budget bytes resident, {preemptions} preemptions",
        kv = kv_dtype.name()
    );
    if resident > budget {
        bail!("KV arena resident bytes {resident} exceed the {budget}-byte budget");
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    let hit = engine.metrics.prefix_hit_tokens.load(ord);
    let computed = engine.metrics.prefill_tokens_computed.load(ord);
    let splits = engine.metrics.kv_cow_splits.load(ord);
    println!(
        "prefix cache: {}, {hit} hit tokens, {computed} prefill tokens computed, {splits} cow splits",
        if prefix_cache { "on" } else { "off" }
    );
    // The CI prefix-cache smoke invariant: with sharing on and every
    // request opening with the same system prompt, the index must serve
    // hits — zero means the radix lookup or registration regressed.
    if prefix_cache && shared_prefix > 0 && hit == 0 {
        bail!("--prefix-cache on with --shared-prefix {shared_prefix} served zero hit tokens");
    }
    if args.has_flag("verbose") {
        println!("kernels: {}", engine.kernel_info);
        println!(
            "phase: attention {}µs, mpGEMM {}µs, other ops {}µs (cumulative)",
            engine.metrics.phase_attn_us.load(ord),
            engine.metrics.phase_gemm_us.load(ord),
            engine.metrics.phase_other_us.load(ord)
        );
    }
    let trace = engine.trace_snapshot();
    warn_on_trace_drift(&profile_widths, &trace);
    if let Some(tp) = args.get("record-trace") {
        trace.save(Path::new(tp))?;
        eprintln!("wrote trace {tp} ({})", trace.summary());
    }
    Ok(())
}

/// Micro-benchmark every applicable kernel on the projection shapes of a
/// model preset and write the winners to a JSON tuning profile.
fn cmd_tune(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "tiny");
    let model_cfg = ModelConfig::preset(&preset)
        .with_context(|| format!("unknown preset {preset:?}"))?;
    let out = PathBuf::from(args.get_or("out", "profile.json"));
    let threads = args.get_usize("threads", 1)?;
    let measure_ms = args.get_usize("measure-ms", 60)?;
    // Trace-driven mode: sweep the shapes a recorded serving run actually
    // exhibited, weighted by frequency — no fixed --batches fallback.
    let trace: Option<ServingTrace> = match args.get("trace") {
        Some(tp) => {
            if args.get("batches").is_some() {
                bail!(
                    "--trace replaces the --batches sweep with the trace's observed \
                     shapes; pass one or the other"
                );
            }
            let t = ServingTrace::load(Path::new(tp))?;
            if t.is_empty() {
                bail!(
                    "trace {tp} records no shapes; re-record with \
                     `run`/`serve --record-trace` on a real workload"
                );
            }
            Some(t)
        }
        None => None,
    };
    if trace.is_none() && args.get("trace-widths").is_some() {
        bail!("--trace-widths caps the --trace sweep; it does nothing without --trace");
    }
    let batches: Vec<usize> = args
        .get_or("batches", "1,4")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| match s.trim().parse::<usize>() {
            Ok(0) => Err(anyhow::anyhow!("--batches entries must be >= 1, got 0")),
            Ok(n) => Ok(n),
            Err(_) => Err(anyhow::anyhow!("--batches expects integers, got {s:?}")),
        })
        .collect::<Result<_>>()?;
    if trace.is_none() && batches.is_empty() {
        bail!("--batches must name at least one batch size (e.g. --batches 1,4)");
    }
    // Default candidates are the compact ternary serving kernels; the
    // dense/general baselines can win small cache-resident shapes and
    // would silently pack the model at up to 32 bpw. `--kernels all`
    // measures everything anyway.
    let candidates: Vec<QuantType> = match args.get("kernels") {
        None => tuner::default_candidates(),
        Some(list) if list.eq_ignore_ascii_case("all") => QuantType::ALL.to_vec(),
        Some(list) => list
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                QuantType::parse(s.trim())
                    .with_context(|| format!("unknown kernel {s:?} in --kernels"))
            })
            .collect::<Result<_>>()?,
    };
    if candidates.is_empty() {
        bail!("--kernels must name at least one kernel");
    }
    let mut cfg = TuneConfig {
        shapes: tuner_e2e::shapes_for_model(&model_cfg),
        batches,
        threads,
        candidates,
        min_iters: 3,
        min_seconds: measure_ms as f64 / 1e3,
        ..TuneConfig::default()
    };
    if let Some(t) = &trace {
        // Cap the sweep at the heaviest observed widths: a long-tail
        // workload where nearly every prompt length is distinct would
        // otherwise multiply tuning cost per unique length. Never
        // silent — the dropped traffic share is printed.
        let max_widths = args.get_usize("trace-widths", 16)?;
        if max_widths == 0 {
            bail!(
                "--trace-widths must be >= 1 (the cap guards against long-tail traces; \
                 pass a large value to keep more of the tail)"
            );
        }
        let (widths, dropped) = t.top_weighted_batches(max_widths);
        cfg.set_weighted_batches(&widths);
        eprintln!("trace-driven sweep: {}", t.summary());
        if dropped > 0 {
            let kept: f64 = widths.iter().map(|(_, w)| w).sum();
            eprintln!(
                "capping sweep to the {} heaviest widths (--trace-widths {max_widths}); \
                 {dropped} long-tail widths carrying {:.1}% of traffic dropped",
                widths.len(),
                (1.0 - kept) * 100.0
            );
        }
        eprintln!(
            "observed batch widths: {}",
            cfg.batches
                .iter()
                .zip(cfg.batch_weights.iter())
                .map(|(n, w)| format!("{n} ({:.0}%)", w * 100.0))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    eprintln!(
        "tuning preset {} ({} shapes x {} batches, {} candidate kernels, {} threads)",
        preset,
        cfg.shapes.len(),
        cfg.batches.len(),
        cfg.candidates.len(),
        threads
    );
    let verbose = args.has_flag("verbose");
    let mut log = |s: &str| eprintln!("{s}");
    let mut profile = tuner::tune(&cfg, if verbose { Some(&mut log) } else { None });
    for e in &profile.entries {
        println!("{}x{} n={}: {}", e.m, e.k, e.n, e.best.name());
    }
    // Persist the sweep before any optional post-processing: a failed
    // --e2e step (e.g. an unhostable preset) must not discard minutes of
    // completed measurements.
    profile.save(&out)?;
    // Shapes for every e2e measurement below (--e2e and
    // --search-overrides): the trace's modal prefill chunk and decode
    // width when one was given — so both e2e sections measure at the
    // same, workload-observed shapes — else the defaults.
    let search_defaults = OverrideSearchConfig::default();
    let e2e_prefill = trace
        .as_ref()
        .and_then(|t| t.modal_prefill_chunk())
        .unwrap_or(search_defaults.prefill_tokens);
    let e2e_width = trace
        .as_ref()
        .and_then(|t| t.modal_decode_width())
        .unwrap_or(search_defaults.decode_width);
    if args.has_flag("e2e") {
        // Layer-composition check: per-shape winners can compose
        // differently than they measure in isolation, so time the tuned
        // profile against the fixed default on the full model and record
        // both in the profile's `e2e` section.
        eprintln!("measuring end-to-end layer composition on preset {preset}...");
        let entries = tuner_e2e::measure_e2e(
            &profile,
            &model_cfg,
            threads,
            e2e_prefill,
            search_defaults.decode_tokens,
            e2e_width,
        )?;
        for e in &entries {
            println!(
                "e2e {}: prefill {:.1} tok/s, decode {:.1} tok/s",
                e.label, e.prefill_tok_s, e.decode_tok_s
            );
        }
        profile.e2e = entries;
        profile.save(&out)?;
    }
    if args.has_flag("search-overrides") {
        // Automatic per-layer override search: sweep first/last-vs-middle
        // kernel compositions end to end and keep the winner. The phase
        // blend scoring the sweep comes from the trace when one was
        // given (real traffic), else an even split.
        eprintln!("searching per-layer override compositions on preset {preset}...");
        // Compositions are measured at the same shapes as --e2e above
        // (trace-derived when available) and scored by the trace's
        // phase blend; without a trace, an even split.
        let scfg = OverrideSearchConfig {
            prefill_weight: trace.as_ref().map(|t| t.prefill_token_fraction()).unwrap_or(0.5),
            prefill_tokens: e2e_prefill,
            decode_width: e2e_width,
            ..search_defaults
        };
        let outcome = tuner_e2e::search_overrides(&profile, &model_cfg, threads, &scfg, Some(&mut log))?;
        println!(
            "override search: winner {} ({} override rows; uniform {:.1} vs best {:.1} tok/s blended)",
            outcome.winner,
            outcome.overrides.len(),
            outcome.uniform_score,
            outcome.best_score
        );
        profile.overrides = outcome.overrides;
        profile.e2e.extend(outcome.measurements);
        profile.save(&out)?;
    }
    println!(
        "wrote {} ({} entries, {} overrides)",
        out.display(),
        profile.entries.len(),
        profile.overrides.len()
    );
    Ok(())
}

fn cmd_pjrt(args: &Args) -> Result<()> {
    let artifact = args.get_or("artifact", "artifacts/ternary_matmul.hlo.txt");
    let rt = crate::runtime::Runtime::new()?;
    let exe = rt.load_hlo_text(&PathBuf::from(&artifact))?;
    println!("loaded {artifact}: {}", exe.describe());
    // Smoke-execute with the manifest-declared shapes if present.
    match crate::runtime::manifest_for(&PathBuf::from(&artifact)) {
        Some(entry) => {
            let outputs = exe.execute_random(&entry)?;
            println!("executed: {} outputs, first values {:?}", outputs.len(), &outputs[0][..outputs[0].len().min(4)]);
        }
        None => println!("no manifest entry; skipping execution"),
    }
    Ok(())
}
