//! Continuous-batching scheduler: decides, each engine step, which waiting
//! requests to admit (prefill) and which running sequences decode — under
//! a max-batch-size cap and the [`KvArena`] page budget. Pure state
//! machine, no threads, so policies are unit-testable.
//!
//! Policy (vLLM-style FCFS with recompute preemption):
//! * finished sequences release their pages immediately;
//! * **chunked watermark admission**: a waiting request admits when its
//!   *next prefill chunk* (plus, if that chunk completes the prompt, this
//!   step's decode append) fits the arena *now* — not when its worst-case
//!   `prompt + max_new_tokens` demand does. With a chunk cap
//!   ([`Scheduler::prefill_chunk`]) long prompts stream across steps:
//!   admit on the first page-sized chunk, reserve one more chunk per step
//!   until the prompt is resident, and only then join the decode batch;
//! * prompt tokens already mapped from the arena's prefix index
//!   ([`SeqState::prefix_tokens`]) are never re-prefilled — the first
//!   chunk starts at the divergence point;
//! * running sequences grow page-by-page as they decode; when a growth
//!   reservation finds the arena exhausted, the **newest-admitted**
//!   running sequence is preempted back to `Waiting` (LIFO — the oldest
//!   always progresses, which is the no-deadlock guarantee), its page
//!   refcounts dropped immediately (prefix-shared pages survive via their
//!   other referents), its cache re-prefilled on re-admission;
//! * decode runs as one batch over every running sequence whose prompt is
//!   resident or completes this step; mid-prefill sequences wait.

use super::kv_pool::KvArena;
use std::collections::VecDeque;

/// Scheduler-side view of a sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub id: u64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub generated: usize,
    pub phase: Phase,
    /// Prompt tokens already cache-resident via prefix-index mapping (set
    /// at submit time by the engine; forfeited on preemption — the
    /// re-admission re-prefills from position 0, since the index may have
    /// evicted those pages meanwhile).
    pub prefix_tokens: usize,
    /// Prompt/resume tokens *confirmed* in the KV cache, driven by the
    /// engine's `on_prefill_progress`/`on_prefilled` notifications
    /// (starts at `prefix_tokens`: mapped pages are already resident).
    pub prefilled: usize,
    /// Prompt/resume tokens *planned* for prefill so far, advanced at
    /// planning time. Runs ahead of `prefilled` within a step; keeping
    /// the two separate is what stops [`Scheduler::step`] from re-planning
    /// a chunk the engine has not acknowledged yet.
    pub planned: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Waiting,
    /// Admitted; prompt not yet fully prefilled (chunks may stream across
    /// several steps).
    Prefill,
    Decoding,
}

impl SeqState {
    pub fn new(id: u64, prompt_len: usize, max_new_tokens: usize) -> SeqState {
        SeqState {
            id,
            prompt_len,
            max_new_tokens,
            generated: 0,
            phase: Phase::Waiting,
            prefix_tokens: 0,
            prefilled: 0,
            planned: 0,
        }
    }

    /// Worst-case KV tokens this sequence can ever hold.
    pub fn worst_case_tokens(&self) -> usize {
        self.prompt_len + self.max_new_tokens
    }
    /// KV tokens committed so far (streamed prefill progress, plus
    /// sampled tokens once decoding — see [`Scheduler::kv_tokens_in_cache`]).
    pub fn current_tokens(&self) -> usize {
        match self.phase {
            Phase::Waiting => 0,
            Phase::Prefill => self.prefilled,
            Phase::Decoding => self.prompt_len + self.generated,
        }
    }
    /// Tokens the engine must (re)prefill to admit this sequence: the
    /// prompt — plus, after a preemption, every generated token except
    /// the last, which the next decode step appends (the engine keeps it
    /// as `last_token`; see the resume path in `coordinator::engine`).
    pub fn resume_tokens(&self) -> usize {
        self.prompt_len + self.generated.saturating_sub(1)
    }
}

/// What the engine should do this step. Besides the request ids, the
/// plan carries the *shape* of the step — prefill chunk sizes and the
/// decode batch width — which is exactly what phase-aware kernel
/// dispatch keys on (a prefill chunk of 100 tokens and a decode batch
/// of 4 hit different tuned regimes; see `kernels::tuner::DispatchPlan`).
#[derive(Debug, Default, PartialEq, Eq)]
pub struct StepPlan {
    /// Requests to run a prefill chunk for this step (in order): newly
    /// admitted ones, streamed continuations of earlier admissions, and
    /// re-admissions of preempted sequences with their longer resume
    /// chunks.
    pub prefill: Vec<u64>,
    /// Prefill chunk size (tokens entering the cache) per entry of
    /// `prefill`, parallel to it — the GEMM batch width each prefill
    /// chunk will run at.
    pub prefill_chunks: Vec<usize>,
    /// Running sequences to decode as one batch. Mid-prefill sequences
    /// (prompt still incomplete after this step's chunk) are excluded.
    pub decode: Vec<u64>,
    /// Sequences evicted from the running set this step (pages already
    /// released); the engine must reset their sessions so re-admission
    /// re-prefills from position 0.
    pub preempted: Vec<u64>,
}

impl StepPlan {
    /// The decode GEMM batch width of this step.
    pub fn decode_width(&self) -> usize {
        self.decode.len()
    }

    /// Total prompt tokens this step will prefill.
    pub fn prefill_tokens(&self) -> usize {
        self.prefill_chunks.iter().sum()
    }
}

/// The scheduler.
pub struct Scheduler {
    pub max_batch: usize,
    /// Prefill chunk cap in tokens; 0 = unlimited, i.e. whole-prompt
    /// chunks (the pre-streaming behavior).
    pub prefill_chunk: usize,
    waiting: VecDeque<SeqState>,
    /// Admission order: index 0 is the oldest-admitted sequence — the one
    /// preemption never evicts while anything newer is running.
    running: Vec<SeqState>,
}

/// Page-budget work one running sequence needs this step.
enum Work {
    /// Decoding: reserve the page this step's decode append commits.
    DecodeGrow { tokens: usize },
    /// Mid-prefill: reserve (and plan) the next streamed chunk.
    Chunk { chunk: usize, completes: bool, write_from: usize },
    /// Nothing to reserve (retiring, or awaiting a prefill notification).
    None,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Scheduler {
        Scheduler {
            max_batch: max_batch.max(1),
            prefill_chunk: 0,
            waiting: VecDeque::new(),
            running: Vec::new(),
        }
    }

    /// Enqueue a new request. Returns false if it can *never* run
    /// (worst-case demand exceeds the whole arena — the one check that
    /// must stay worst-case: it is what guarantees a sequence running
    /// alone always completes, i.e. preemption cannot deadlock).
    pub fn submit(&mut self, seq: SeqState, arena: &KvArena) -> bool {
        if arena.pages_for(seq.worst_case_tokens()) > arena.total_pages() {
            return false;
        }
        self.waiting.push_back(seq);
        true
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Mark a running sequence as having generated one more token.
    pub fn on_token(&mut self, id: u64) {
        if let Some(s) = self.running.iter_mut().find(|s| s.id == id) {
            s.generated += 1;
        }
    }

    /// Notification from the engine that `id`'s prompt is now fully in
    /// the KV cache. The `Prefill → Decoding` flip happens here — *after*
    /// the engine actually ran the final chunk — not at planning time:
    /// flipping inside [`Scheduler::step`] made `current_tokens()` claim
    /// KV occupancy for prompts that were not yet prefilled, misreporting
    /// cache pressure for the duration of the step.
    pub fn on_prefilled(&mut self, id: u64) {
        if let Some(s) =
            self.running.iter_mut().find(|s| s.id == id && s.phase == Phase::Prefill)
        {
            s.prefilled = s.resume_tokens();
            s.planned = s.prefilled;
            s.phase = Phase::Decoding;
        }
    }

    /// Notification that the engine ran a partial prefill chunk of
    /// `tokens` for `id` (streamed admission); the sequence stays in
    /// `Phase::Prefill` until [`Scheduler::on_prefilled`].
    pub fn on_prefill_progress(&mut self, id: u64, tokens: usize) {
        if let Some(s) =
            self.running.iter_mut().find(|s| s.id == id && s.phase == Phase::Prefill)
        {
            s.prefilled += tokens;
        }
    }

    /// Notification that `id` sampled its stop token: the engine retires
    /// it at the next step without decoding again, so growth must not
    /// reserve a page — or preempt a neighbour — on its behalf.
    /// Implemented by clamping the budget to what was generated; the
    /// finish-pending guard in [`Scheduler::step`] then skips it.
    pub fn on_stop(&mut self, id: u64) {
        if let Some(s) = self.running.iter_mut().find(|s| s.id == id) {
            s.max_new_tokens = s.max_new_tokens.min(s.generated);
        }
    }

    /// KV tokens committed across every running sequence: resident
    /// prompt tokens plus every sampled token (the most recent of which
    /// is appended to the cache at the *next* decode step — committed
    /// occupancy, which is what capacity accounting needs, can lead
    /// physical residency by one token per decoding sequence).
    /// Mid-prefill sequences contribute their confirmed chunks (and
    /// mapped prefix tokens) only.
    pub fn kv_tokens_in_cache(&self) -> usize {
        self.running.iter().map(|s| s.current_tokens()).sum()
    }

    /// Remove a finished sequence and release its pages (refcount
    /// decrements — prefix-shared pages stay live for the index or other
    /// referents).
    pub fn finish(&mut self, id: u64, arena: &mut KvArena) {
        self.running.retain(|s| s.id != id);
        arena.release(id);
    }

    /// Evict the newest-admitted running sequence back to the waiting
    /// *front* (it re-admits before fresh arrivals), releasing its pages.
    /// Returns the evicted id.
    fn preempt_newest(&mut self, arena: &mut KvArena, plan: &mut StepPlan) -> u64 {
        let mut victim = self.running.pop().expect("preempt requires a running sequence");
        arena.release(victim.id);
        arena.note_preemption();
        victim.phase = Phase::Waiting;
        victim.prefix_tokens = 0;
        victim.prefilled = 0;
        victim.planned = 0;
        let id = victim.id;
        plan.preempted.push(id);
        self.waiting.push_front(victim);
        id
    }

    /// Admission found the arena exhausted with *nothing running*: no
    /// future decode will free pages, so the only reclaimable capacity is
    /// prefix mappings held by waiting sequences — their pages pin index
    /// nodes at refcount ≥ 2, which the arena's own LRU eviction must not
    /// touch. Drop one (newest-queued first, the head's own mapping
    /// last); the dropped sequence re-prefills from scratch when it
    /// admits. This restores the pre-sharing progress guarantee: once
    /// every waiting mapping is gone, only index-held pages remain and
    /// the arena can evict those itself. Returns false when there was
    /// nothing left to drop.
    fn drop_one_waiting_mapping(&mut self, arena: &mut KvArena, plan: &mut StepPlan) -> bool {
        for s in self.waiting.iter_mut().rev() {
            if s.prefix_tokens > 0 {
                arena.release(s.id);
                s.prefix_tokens = 0;
                s.prefilled = 0;
                s.planned = 0;
                plan.preempted.push(s.id);
                return true;
            }
        }
        false
    }

    /// The next prefill chunk for `remaining` unprefilled tokens, under
    /// the configured cap.
    fn chunk_of(&self, remaining: usize) -> usize {
        if self.prefill_chunk == 0 {
            remaining
        } else {
            remaining.min(self.prefill_chunk)
        }
    }

    /// Plan one engine step.
    ///
    /// 1. **Growth and prefill streaming**, oldest-admitted first: every
    ///    decoding sequence reserves the page its decode append commits
    ///    this step (a write into a prefix-shared page splits it — the
    ///    reservation covers the private copy too); every mid-prefill
    ///    sequence reserves and plans its next chunk. When the arena is
    ///    exhausted, the newest running sequence is preempted (possibly
    ///    the grower itself — FCFS: older always beats newer) until the
    ///    reservation fits. Progress guarantee: the oldest sequence can
    ///    always grow by evicting everything newer (the arena itself
    ///    evicts index-only pages), because [`Scheduler::submit`] bounded
    ///    its worst case by the whole arena.
    /// 2. **Watermark admission**, FCFS: the waiting head admits when its
    ///    first (re)prefill chunk — plus, if that chunk completes the
    ///    prompt, one decode append — fits *now*. Head-of-line blocking
    ///    is intentional (fairness): if the head doesn't fit, nothing
    ///    behind it jumps.
    /// 3. Sequences decode this step iff their prompt is resident or its
    ///    final chunk runs this step; newly admitted ones stay in
    ///    `Phase::Prefill` until the engine reports the prefill actually
    ///    happened (`on_prefilled`).
    pub fn step(&mut self, arena: &mut KvArena) -> StepPlan {
        let mut plan = StepPlan::default();
        let mut i = 0;
        while i < self.running.len() {
            let s = &self.running[i];
            let work = match s.phase {
                // Sequences the engine retires this step (budget reached)
                // don't append.
                Phase::Decoding if s.generated < s.max_new_tokens => {
                    Work::DecodeGrow { tokens: s.prompt_len + s.generated }
                }
                Phase::Prefill if s.planned < s.resume_tokens() => {
                    let target = s.resume_tokens();
                    let chunk = self.chunk_of(target - s.planned);
                    Work::Chunk {
                        chunk,
                        completes: s.planned + chunk >= target,
                        write_from: s.planned,
                    }
                }
                // Waiting-in-running can't happen; fully planned Prefill
                // sequences are awaiting their on_prefilled notification.
                _ => Work::None,
            };
            match work {
                Work::None => {
                    i += 1;
                }
                Work::DecodeGrow { tokens } => loop {
                    let id = self.running[i].id;
                    if arena.reserve_for_write(id, tokens, tokens.saturating_sub(1)) {
                        i += 1;
                        break;
                    }
                    self.preempt_newest(arena, &mut plan);
                    if self.running.len() == i {
                        break; // the grower itself was evicted
                    }
                },
                Work::Chunk { chunk, completes, write_from } => loop {
                    let id = self.running[i].id;
                    let reserve_to = write_from + chunk + usize::from(completes);
                    if arena.reserve_for_write(id, reserve_to, write_from) {
                        let s = &mut self.running[i];
                        s.planned += chunk;
                        plan.prefill.push(id);
                        plan.prefill_chunks.push(chunk);
                        i += 1;
                        break;
                    }
                    self.preempt_newest(arena, &mut plan);
                    if self.running.len() == i {
                        break; // the mid-prefill sequence itself was evicted
                    }
                },
            }
        }
        while self.running.len() < self.max_batch {
            let Some(head) = self.waiting.front() else { break };
            let target = head.resume_tokens();
            let done = head.prefix_tokens;
            let chunk = self.chunk_of(target - done);
            let completes = done + chunk >= target;
            let reserve_to = done + chunk + usize::from(completes);
            if !arena.reserve_for_write(head.id, reserve_to, done) {
                if self.running.is_empty() && self.drop_one_waiting_mapping(arena, &mut plan) {
                    continue; // re-plan the head with the freed pages
                }
                break;
            }
            let mut seq = self.waiting.pop_front().unwrap();
            seq.phase = Phase::Prefill;
            seq.prefilled = done;
            seq.planned = done + chunk;
            plan.prefill.push(seq.id);
            plan.prefill_chunks.push(chunk);
            self.running.push(seq);
        }
        for s in self.running.iter() {
            // Mid-prefill sequences have nothing to decode yet; those
            // whose final chunk runs this step join the batch (the engine
            // samples their first token off the prefill logits).
            let mid_prefill = s.phase == Phase::Prefill && s.planned < s.resume_tokens();
            if !mid_prefill {
                plan.decode.push(s.id);
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(id: u64, prompt: usize, max_new: usize) -> SeqState {
        SeqState::new(id, prompt, max_new)
    }

    #[test]
    fn admits_up_to_batch_cap() {
        let mut arena = KvArena::accounting(16 * 100);
        let mut sch = Scheduler::new(2);
        for i in 0..4 {
            assert!(sch.submit(seq(i, 8, 8), &arena));
        }
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![0, 1]);
        assert_eq!(plan.decode, vec![0, 1]);
        assert_eq!(sch.waiting_len(), 2);
    }

    #[test]
    fn watermark_admission_outruns_worst_case() {
        let mut arena = KvArena::accounting(16 * 4); // 4 pages
        let mut sch = Scheduler::new(8);
        sch.submit(seq(1, 16, 16), &arena); // worst case 2 pages
        sch.submit(seq(2, 16, 32), &arena); // worst case 3 pages
        // Worst-case reservation could never co-run these (2 + 3 > 4
        // pages); prompt-fit admission holds both (17 tokens → 2 pages
        // each).
        assert!(arena.pages_for(16 + 16) + arena.pages_for(16 + 32) > arena.total_pages());
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![1, 2]);
        assert_eq!(arena.free_page_count(), 0);
    }

    #[test]
    fn admission_blocks_when_prompt_does_not_fit() {
        let mut arena = KvArena::accounting(16 * 4); // 4 pages
        let mut sch = Scheduler::new(8);
        sch.submit(seq(1, 62, 2), &arena); // prompt+1 → 4 pages
        sch.submit(seq(2, 8, 8), &arena); // 1 page — could fit, but behind 1
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![1]);
        let plan = sch.step(&mut arena);
        assert!(plan.prefill.is_empty(), "2 must wait for 1's pages (FCFS head-of-line)");
        assert_eq!(plan.decode, vec![1]);
        // Finish 1 → 2 admits next step.
        sch.finish(1, &mut arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![2]);
    }

    #[test]
    fn chunked_prefill_streams_across_steps() {
        let mut arena = KvArena::accounting(16 * 100);
        let mut sch = Scheduler::new(4);
        sch.prefill_chunk = 16; // one page per step
        sch.submit(seq(1, 40, 4), &arena);
        // Step 1: admit on the first chunk only; no decode yet.
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![1]);
        assert_eq!(plan.prefill_chunks, vec![16]);
        assert!(plan.decode.is_empty(), "mid-prefill sequences don't decode");
        sch.on_prefill_progress(1, 16);
        assert_eq!(sch.kv_tokens_in_cache(), 16);
        // Step 2: second chunk.
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill_chunks, vec![16]);
        assert!(plan.decode.is_empty());
        sch.on_prefill_progress(1, 16);
        // Step 3: final 8-token chunk completes the prompt → decodes.
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill_chunks, vec![8]);
        assert_eq!(plan.decode, vec![1], "completion chunk joins the decode batch");
        sch.on_prefilled(1);
        assert_eq!(sch.kv_tokens_in_cache(), 40);
        sch.on_token(1);
        // Steady decode from here.
        let plan = sch.step(&mut arena);
        assert!(plan.prefill.is_empty());
        assert_eq!(plan.decode, vec![1]);
    }

    #[test]
    fn chunked_admission_admits_long_prompt_page_by_page() {
        // 4-page arena, 62-token prompt: all-or-nothing admission needed
        // every page up front; chunked admission starts on one.
        let mut arena = KvArena::accounting(16 * 4);
        let mut sch = Scheduler::new(4);
        sch.prefill_chunk = 16;
        sch.submit(seq(1, 62, 2), &arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill_chunks, vec![16]);
        assert_eq!(arena.held_pages(1), 1, "admitted on a single page");
        sch.on_prefill_progress(1, 16);
        for expect in [16, 16, 14] {
            let plan = sch.step(&mut arena);
            assert_eq!(plan.prefill_chunks, vec![expect]);
            if expect == 14 {
                sch.on_prefilled(1);
            } else {
                sch.on_prefill_progress(1, expect);
            }
        }
        assert_eq!(sch.kv_tokens_in_cache(), 62);
    }

    #[test]
    fn prefix_mapped_tokens_skip_prefill() {
        let mut arena = KvArena::accounting(16 * 100);
        let mut sch = Scheduler::new(4);
        let prompt: Vec<u32> = (0..40).collect();
        // Engine-side: a finished sequence indexed its prompt pages, and
        // map_prefix put 32 of the 40 prompt tokens in this one's table.
        assert!(arena.reserve(900, 40));
        arena.register_prefix(900, &prompt);
        arena.release(900);
        let shared = arena.map_prefix(1, &prompt);
        assert_eq!(shared, 32);
        let mut s = seq(1, 40, 4);
        s.prefix_tokens = shared;
        sch.submit(s, &arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![1]);
        assert_eq!(plan.prefill_chunks, vec![8], "only the divergent tail prefills");
        assert_eq!(plan.decode, vec![1], "tail chunk completes the prompt");
        assert_eq!(sch.kv_tokens_in_cache(), 32, "mapped tokens resident before the chunk runs");
        sch.on_prefilled(1);
        assert_eq!(sch.kv_tokens_in_cache(), 40);
    }

    #[test]
    fn oversized_request_rejected_at_submit() {
        let arena = KvArena::accounting(16 * 4);
        let mut sch = Scheduler::new(8);
        assert!(!sch.submit(seq(1, 100, 100), &arena));
        assert_eq!(sch.waiting_len(), 0);
    }

    #[test]
    fn continuous_batching_joins_mid_stream() {
        let mut arena = KvArena::accounting(16 * 100);
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 4, 4), &arena);
        let p1 = sch.step(&mut arena);
        assert_eq!(p1.decode, vec![1]);
        sch.on_prefilled(1);
        sch.on_token(1);
        // New request joins while 1 is mid-decode.
        sch.submit(seq(2, 4, 4), &arena);
        let p2 = sch.step(&mut arena);
        assert_eq!(p2.prefill, vec![2]);
        assert_eq!(p2.decode, vec![1, 2]);
    }

    #[test]
    fn step_plan_reports_phase_shapes() {
        let mut arena = KvArena::accounting(16 * 100);
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 5, 4), &arena);
        sch.submit(seq(2, 9, 4), &arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill_chunks, vec![5, 9]);
        assert_eq!(plan.prefill_tokens(), 14);
        assert_eq!(plan.decode_width(), 2);
        // Next step: no admissions, pure decode batch.
        let plan = sch.step(&mut arena);
        assert!(plan.prefill.is_empty() && plan.prefill_chunks.is_empty());
        assert_eq!(plan.prefill_tokens(), 0);
        assert_eq!(plan.decode_width(), 2);
    }

    #[test]
    fn phase_flips_on_engine_notification_not_at_planning() {
        let mut arena = KvArena::accounting(16 * 100);
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 10, 4), &arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![1]);
        assert_eq!(plan.decode, vec![1], "admitted sequence still decodes this step");
        // Planning must NOT claim KV occupancy for a prompt the engine
        // has not prefilled yet.
        assert_eq!(sch.kv_tokens_in_cache(), 0, "prefill not yet executed");
        sch.on_prefilled(1);
        assert_eq!(sch.kv_tokens_in_cache(), 10, "prompt resident after prefill");
        sch.on_token(1);
        // Committed occupancy: the sampled token is counted now (it
        // enters the cache at the next decode step).
        assert_eq!(sch.kv_tokens_in_cache(), 11);
        // Later steps leave the phase alone.
        let plan = sch.step(&mut arena);
        assert!(plan.prefill.is_empty());
        assert_eq!(sch.kv_tokens_in_cache(), 11);
        // Unknown ids are a no-op.
        sch.on_prefilled(99);
    }

    #[test]
    fn finish_releases_pages() {
        let mut arena = KvArena::accounting(16 * 2);
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 16, 16), &arena);
        sch.step(&mut arena);
        assert_eq!(arena.free_page_count(), 0);
        sch.finish(1, &mut arena);
        assert_eq!(arena.free_page_count(), 2);
        assert_eq!(sch.running_len(), 0);
    }

    #[test]
    fn growth_preempts_newest_lifo() {
        let mut arena = KvArena::accounting(16 * 4); // 4 pages
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 16, 33), &arena);
        sch.submit(seq(2, 16, 33), &arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![1, 2], "watermark admits both");
        sch.on_prefilled(1);
        sch.on_prefilled(2);
        // Decode until each holds 2 pages and the next growth must evict.
        for g in 0..17 {
            sch.on_token(1);
            sch.on_token(2);
            let plan = sch.step(&mut arena);
            if g < 15 {
                assert!(plan.preempted.is_empty(), "tokens fit reserved pages at g={g}");
            }
        }
        // Sequence 1 (oldest) needed a third page; 2 (newest) was evicted.
        assert_eq!(sch.running_len(), 1);
        assert_eq!(sch.waiting_len(), 1);
        assert_eq!(arena.preemptions(), 1);
        assert_eq!(arena.held_pages(2), 0, "preemption releases pages immediately");
        assert!(arena.held_pages(1) >= 3, "the oldest sequence kept growing");
    }

    #[test]
    fn preempted_sequence_readmits_with_resume_chunk() {
        let mut arena = KvArena::accounting(16 * 2); // 2 pages
        let mut sch = Scheduler::new(4);
        // Worst case 32 tokens = 2 pages each: accepted, but they can't
        // both grow past their first page.
        assert!(sch.submit(seq(1, 8, 24), &arena));
        assert!(sch.submit(seq(2, 8, 24), &arena));
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill_chunks, vec![8, 8]);
        sch.on_prefilled(1);
        sch.on_prefilled(2);
        // Push 1 past its first page: 2 gets evicted.
        for _ in 0..9 {
            sch.on_token(1);
            sch.on_token(2);
            sch.step(&mut arena);
        }
        assert_eq!(arena.preemptions(), 1);
        assert_eq!(sch.waiting_len(), 1);
        // Free the arena; 2 re-admits with prompt + generated - 1 tokens
        // to re-prefill (the last sampled token is appended by decode).
        sch.finish(1, &mut arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![2]);
        assert_eq!(plan.prefill_chunks, vec![8 + 9 - 1]);
        sch.on_prefilled(2);
        assert_eq!(sch.kv_tokens_in_cache(), 8 + 9);
    }

    #[test]
    fn preempted_midprefill_sequence_restarts_clean() {
        let mut arena = KvArena::accounting(16 * 3); // 3 pages
        let mut sch = Scheduler::new(4);
        sch.prefill_chunk = 16;
        // 1 decodes; 2 streams a long prompt behind it.
        sch.submit(seq(1, 8, 40), &arena);
        sch.submit(seq(2, 30, 2), &arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![1, 2]);
        assert_eq!(plan.prefill_chunks, vec![8, 16]);
        sch.on_prefilled(1);
        sch.on_prefill_progress(2, 16);
        // Drive 1's decode growth until it claims 2's pages: at 3 pages
        // total, 1 growing past 16 and then past 32 tokens forces the
        // mid-prefill 2 out (LIFO).
        for _ in 0..26 {
            sch.on_token(1);
            sch.step(&mut arena);
        }
        assert!(arena.preemptions() >= 1);
        assert_eq!(sch.waiting_len(), 1);
        assert_eq!(arena.held_pages(2), 0);
        // 2 lost its streamed progress: re-admission replans from zero.
        sch.finish(1, &mut arena);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![2]);
        assert_eq!(plan.prefill_chunks, vec![16], "restart from the first chunk");
        sch.on_prefill_progress(2, 16);
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill_chunks, vec![14]);
        sch.on_prefilled(2);
        assert_eq!(sch.kv_tokens_in_cache(), 30);
    }

    #[test]
    fn stalled_admission_drops_waiting_prefix_mappings() {
        // Two disjoint 2-page prefixes fill a 4-page arena; both waiting
        // sequences map one each at submit. The head's tail chunk needs a
        // page, nothing is running to free one, and the mapped pages pin
        // their index nodes above the arena's eviction threshold — the
        // scheduler must shed a waiting mapping rather than stall forever.
        let mut arena = KvArena::accounting(16 * 4);
        let prompt_a: Vec<u32> = (0..40).collect();
        let prompt_b: Vec<u32> = (500..540).collect();
        for (seed, p) in [(900u64, &prompt_a), (901, &prompt_b)] {
            // Register exactly the two-page prefix (32 tokens) so both
            // fit the 4-page arena fully indexed.
            assert!(arena.reserve(seed, 32));
            arena.register_prefix(seed, &p[..32]);
            arena.release(seed);
        }
        let mut sch = Scheduler::new(4);
        let mut s1 = seq(1, 40, 8);
        s1.prefix_tokens = arena.map_prefix(1, &prompt_a);
        let mut s2 = seq(2, 40, 8);
        s2.prefix_tokens = arena.map_prefix(2, &prompt_b);
        assert_eq!((s1.prefix_tokens, s2.prefix_tokens), (32, 32));
        assert!(sch.submit(s1, &arena));
        assert!(sch.submit(s2, &arena));
        let plan = sch.step(&mut arena);
        // 2's mapping was dropped (newest first); 1 kept its prefix and
        // admitted on the 8-token divergent tail.
        assert_eq!(plan.preempted, vec![2]);
        assert_eq!(plan.prefill, vec![1]);
        assert_eq!(plan.prefill_chunks, vec![8]);
        assert_eq!(sch.waiting_len(), 1, "2 waits for pages, mapping gone");
        assert_eq!(arena.held_pages(2), 0);
        assert_eq!(sch.kv_tokens_in_cache(), 32, "1's mapped prefix survived");
    }

    #[test]
    fn stop_notification_prevents_growth_and_preemption() {
        let mut arena = KvArena::accounting(16 * 2); // 2 pages
        let mut sch = Scheduler::new(4);
        assert!(sch.submit(seq(1, 8, 24), &arena));
        assert!(sch.submit(seq(2, 8, 24), &arena));
        let plan = sch.step(&mut arena);
        assert_eq!(plan.prefill, vec![1, 2], "1 page each, arena full");
        sch.on_prefilled(1);
        sch.on_prefilled(2);
        // 1 crosses into a second page next step (8+9 = 17 tokens);
        // 2 still fits its first page (8+7 = 15).
        for _ in 0..9 {
            sch.on_token(1);
        }
        for _ in 0..7 {
            sch.on_token(2);
        }
        // 1 sampled its stop token: without this notification its growth
        // reservation would exhaust the arena and evict 2 for nothing.
        sch.on_stop(1);
        let plan = sch.step(&mut arena);
        assert!(plan.preempted.is_empty(), "no page needed, no eviction");
        assert_eq!(arena.held_pages(1), 1, "no growth for a retiring sequence");
        assert_eq!(arena.preemptions(), 0);
        assert_eq!(sch.running_len(), 2);
    }

    #[test]
    fn preemption_never_deadlocks() {
        // Tiny arena, many competing sequences: every accepted sequence
        // must complete within a bounded number of steps (the oldest
        // running sequence always progresses).
        let mut arena = KvArena::accounting(16 * 3); // 3 pages
        let mut sch = Scheduler::new(4);
        let mut target = std::collections::HashMap::new();
        for id in 0..6u64 {
            let max_new = 10 + (id as usize % 3) * 10;
            assert!(sch.submit(seq(id, 8, max_new), &arena));
            target.insert(id, max_new);
        }
        let mut gen: std::collections::HashMap<u64, usize> = Default::default();
        let mut completed = 0usize;
        for _ in 0..10_000 {
            let plan = sch.step(&mut arena);
            if plan.decode.is_empty() {
                break;
            }
            // Mirror the engine: prefill flips the phase; fresh prefills
            // also sample the first token.
            for id in &plan.prefill {
                sch.on_prefilled(*id);
                let g = gen.entry(*id).or_insert(0);
                if *g == 0 {
                    *g = 1;
                    sch.on_token(*id);
                }
            }
            // Retire finished, decode the rest.
            for id in plan.decode.clone() {
                let g = gen.entry(id).or_insert(0);
                if *g >= target[&id] {
                    sch.finish(id, &mut arena);
                    completed += 1;
                } else if !plan.preempted.contains(&id) {
                    *g += 1;
                    sch.on_token(id);
                }
            }
        }
        assert_eq!(completed, 6, "all sequences complete despite preemption");
        assert!(arena.preemptions() > 0, "the workload must exercise preemption");
        assert_eq!(arena.used_pages(), 0, "all pages released at the end");
    }

    #[test]
    fn chunked_preemption_never_deadlocks() {
        // Same churn workload with streamed 16-token chunks: chunked
        // admission must preserve the progress guarantee.
        let mut arena = KvArena::accounting(16 * 3); // 3 pages
        let mut sch = Scheduler::new(4);
        sch.prefill_chunk = 16;
        let mut target = std::collections::HashMap::new();
        for id in 0..5u64 {
            let max_new = 6 + (id as usize % 3) * 8;
            assert!(sch.submit(seq(id, 20, max_new), &arena));
            target.insert(id, max_new);
        }
        let mut gen: std::collections::HashMap<u64, usize> = Default::default();
        let mut completed = 0usize;
        for _ in 0..10_000 {
            let plan = sch.step(&mut arena);
            if plan.decode.is_empty() && plan.prefill.is_empty() {
                break;
            }
            for (id, chunk) in plan.prefill.iter().zip(&plan.prefill_chunks) {
                if plan.decode.contains(id) {
                    sch.on_prefilled(*id);
                    let g = gen.entry(*id).or_insert(0);
                    if *g == 0 {
                        *g = 1;
                        sch.on_token(*id);
                    }
                } else {
                    sch.on_prefill_progress(*id, *chunk);
                }
            }
            for id in plan.decode.clone() {
                let g = gen.entry(id).or_insert(0);
                if *g >= target[&id] {
                    sch.finish(id, &mut arena);
                    completed += 1;
                } else if !plan.preempted.contains(&id) {
                    *g += 1;
                    sch.on_token(id);
                }
            }
        }
        assert_eq!(completed, 5, "all sequences complete despite chunked churn");
        assert_eq!(arena.used_pages(), 0, "all pages released at the end");
    }
}
