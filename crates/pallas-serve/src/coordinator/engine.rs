//! The serving engine: one background thread owns the model, sessions and
//! scheduler; clients submit requests over a channel and stream token
//! events back. Decode runs as one batched GEMM per step over every
//! running sequence (continuous batching), prefill is chunked per admitted
//! request — the standard split the paper's serving setting assumes. With
//! the prefix cache enabled, submitted prompts map their longest indexed
//! prefix straight out of the KV arena (copy-on-write pages) and only the
//! divergent tail is prefilled; with a prefill-chunk cap, long prompts
//! stream into the cache across steps instead of admitting all-or-nothing.

use super::kv_pool::{KvArena, KvDtype};
use super::request::{Event, FinishReason, Request, RequestHandle, RequestStats};
use super::scheduler::{Scheduler, SeqState};
use super::trace::{ServingTrace, TraceRecorder};
use crate::metrics::EngineMetrics;
use pallas_model::model::{sample, Session, Transformer};
use pallas_core::util::Rng;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Maximum sequences decoded per step.
    pub max_batch: usize,
    /// Total KV token budget across sequences.
    pub kv_budget_tokens: usize,
    /// EOS token id for `stop_on_eos`.
    pub eos_token: u32,
    /// Sampling RNG seed (deterministic serving runs).
    pub seed: u64,
    /// Element type the KV arena stores (`F16` halves resident KV bytes
    /// at a small quality cost; `F32` is bit-exact with the pre-paged
    /// layout).
    pub kv_dtype: KvDtype,
    /// Share KV pages across sequences with a common prompt prefix: on
    /// submit, the longest page-granular prefix already in the arena's
    /// radix index is mapped copy-on-write into the new sequence and only
    /// the divergent tail is prefilled; completed fresh prompts are
    /// indexed for later arrivals. Off by default — sharing keeps pages
    /// resident for reuse, which callers that assert an empty arena
    /// between workloads must opt into.
    pub prefix_cache: bool,
    /// Prefill chunk cap in tokens; 0 = whole-prompt chunks. A page-sized
    /// cap (e.g. 16) lets long prompts admit as soon as one chunk fits
    /// and stream across steps instead of waiting for every page at once.
    pub prefill_chunk: usize,
    /// Tuning-profile shape weights for the per-step trace-drift metric
    /// (`ServingTrace::drift_l1`): empty disables the computation (the
    /// common case for fixed-kernel runs, which have no profile).
    pub profile_widths: Vec<(usize, f64)>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            kv_budget_tokens: 8192,
            eos_token: 1,
            seed: 0,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefill_chunk: 0,
            profile_widths: Vec::new(),
        }
    }
}

enum Command {
    Submit(u64, Request, Sender<Event>),
    Shutdown,
}

/// Public engine handle (cheap to clone submissions through).
pub struct Engine {
    cmd: Sender<Command>,
    next_id: std::sync::atomic::AtomicU64,
    pub metrics: Arc<EngineMetrics>,
    /// The dispatch policy the model was packed with plus its per-shape
    /// kernel picks (e.g. `fixed(I2_S)` or `auto(...): 256x256->TL2_0 ...`)
    /// — recorded at startup so serving logs can attribute throughput to
    /// kernel selection.
    pub kernel_info: String,
    /// The serving-shape trace the step loop records (prefill chunk
    /// lengths, decode batch widths): the input `tune --trace` consumes.
    /// Always on — one lock per step, far off the GEMM path.
    trace: Arc<TraceRecorder>,
    worker: Option<JoinHandle<()>>,
}

impl Engine {
    /// Start the engine thread around a packed model.
    pub fn start(model: Transformer, config: EngineConfig) -> Engine {
        let (tx, rx) = channel();
        let metrics = Arc::new(EngineMetrics::new());
        let m2 = Arc::clone(&metrics);
        // Materialize the packings the plan selects for the decode
        // regimes this engine will actually run (single-sequence and
        // full-batch width), so the first requests don't pay repack
        // latency mid-stream. Prefill chunks still pack lazily (prompt
        // lengths aren't known yet).
        model.prepack(&[1, config.max_batch.max(1)]);
        // Packing/prepack-time fallbacks are visible immediately, not
        // only after the first served request.
        metrics.dispatch_fallbacks.store(model.plan.fallbacks(), Ordering::Relaxed);
        metrics.dispatch_degraded.store(model.plan.degraded(), Ordering::Relaxed);
        mirror_prepare_stats(&model, &metrics);
        metrics.mirror_phase(model.phase_us());
        metrics.mirror_simd();
        let kernel_info = {
            let shapes: Vec<String> = model
                .kernel_summary()
                .into_iter()
                .map(|(m, k, q)| format!("{m}x{k}->{}", q.name()))
                .collect();
            format!("{}: {}", model.plan.describe(), shapes.join(" "))
        };
        let trace = Arc::new(TraceRecorder::new());
        let t2 = Arc::clone(&trace);
        let worker = std::thread::Builder::new()
            .name("bitnet-engine".into())
            .spawn(move || run_loop(model, config, rx, m2, t2))
            .expect("spawn engine thread");
        Engine { cmd: tx, next_id: 0.into(), metrics, kernel_info, trace, worker: Some(worker) }
    }

    /// Copy of the serving-shape trace recorded so far (persist it with
    /// [`ServingTrace::save`]; `serve --record-trace <path>` does).
    pub fn trace_snapshot(&self) -> ServingTrace {
        self.trace.snapshot()
    }

    /// Submit a request; returns a streaming handle.
    pub fn submit(&self, req: Request) -> RequestHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        // If the engine is gone the receiver hangs up immediately, which
        // RequestHandle::wait maps to Cancelled.
        let _ = self.cmd.send(Command::Submit(id, req, tx));
        RequestHandle { id, events: rx }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.cmd.send(Command::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Copy the KV arena's page/byte/preemption/prefix counters into the
/// lock-free engine metrics (one lock per step, far off the GEMM path).
fn mirror_kv_stats(arena: &Arc<Mutex<KvArena>>, metrics: &EngineMetrics) {
    let a = arena.lock().unwrap();
    metrics.kv_pages_used.store(a.used_pages() as u64, Ordering::Relaxed);
    metrics.kv_pages_peak.store(a.peak_used_pages() as u64, Ordering::Relaxed);
    metrics.kv_pages_total.store(a.total_pages() as u64, Ordering::Relaxed);
    metrics.kv_resident_bytes.store(a.resident_bytes() as u64, Ordering::Relaxed);
    metrics.kv_capacity_bytes.store(a.capacity_bytes() as u64, Ordering::Relaxed);
    metrics.kv_preemptions.store(a.preemptions(), Ordering::Relaxed);
    metrics.prefix_hit_tokens.store(a.prefix_hit_tokens(), Ordering::Relaxed);
    metrics.kv_cow_splits.store(a.cow_splits(), Ordering::Relaxed);
}

/// Copy the pool's per-node dispatch counters and the arena's per-node
/// resident bytes into the engine metrics. On a single-node pool the
/// summary renders "numa off" from the mirrored node count.
fn mirror_numa_stats(model: &Transformer, arena: &Arc<Mutex<KvArena>>, metrics: &EngineMetrics) {
    let stats = model.pool.numa_stats();
    let a = arena.lock().unwrap();
    metrics.mirror_numa(&stats, a.resident_bytes_by_node());
}

/// Copy the model's prepare-once cache counters into the engine metrics
/// (the workspace lives behind the model's mutex; metrics are the
/// lock-free read side).
fn mirror_prepare_stats(model: &Transformer, metrics: &EngineMetrics) {
    let ps = model.prepare_stats();
    metrics.prepare_cache_hits.store(ps.hits, Ordering::Relaxed);
    metrics.prepare_cache_misses.store(ps.misses, Ordering::Relaxed);
    metrics.prepare_buffer_allocs.store(ps.buffer_allocs, Ordering::Relaxed);
    metrics.prepare_buffer_reuses.store(ps.buffer_reuses, Ordering::Relaxed);
}

/// Engine-side per-request state.
struct Live {
    session: Session,
    req: Request,
    events: Sender<Event>,
    submitted: Instant,
    prefilled_at: Option<Instant>,
    last_token: u32,
    generated: Vec<u32>,
}

fn run_loop(
    model: Transformer,
    config: EngineConfig,
    rx: Receiver<Command>,
    metrics: Arc<EngineMetrics>,
    trace: Arc<TraceRecorder>,
) {
    // The one KV arena every serving session shares: the scheduler
    // reserves pages in it, sessions read/write through it, and its
    // counters are mirrored into the engine metrics each step. On a
    // multi-node pool, pages mint interleaved across nodes with their
    // slabs first-touched by the owning node (single-node: inert).
    let arena = Arc::new(Mutex::new({
        let mut a = KvArena::new(
            model.cfg.n_layers,
            model.cfg.kv_dim(),
            config.kv_budget_tokens,
            config.kv_dtype,
        );
        a.set_placement(Arc::clone(&model.pool));
        a
    }));
    let mut scheduler = Scheduler::new(config.max_batch);
    scheduler.prefill_chunk = config.prefill_chunk;
    let mut live: HashMap<u64, Live> = HashMap::new();
    let mut rng = Rng::new(config.seed);
    mirror_kv_stats(&arena, &metrics);
    mirror_numa_stats(&model, &arena, &metrics);

    'outer: loop {
        // Drain commands. Block when idle (no running/waiting work).
        let idle = scheduler.running_len() == 0 && scheduler.waiting_len() == 0;
        loop {
            let cmd = if idle && live.is_empty() {
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => break 'outer,
                }
            } else {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'outer,
                }
            };
            match cmd {
                Command::Shutdown => break 'outer,
                Command::Submit(id, req, events) => {
                    let prompt_len = req.prompt.len().max(1);
                    let mut seq = SeqState::new(id, prompt_len, req.max_new_tokens);
                    let accepted = !req.prompt.is_empty() && {
                        let mut a = arena.lock().unwrap();
                        let fits = a.pages_for(seq.worst_case_tokens()) <= a.total_pages();
                        if fits && config.prefix_cache {
                            // Map the longest indexed prefix into this
                            // sequence's page table (shared, refcounted)
                            // before admission planning: the scheduler's
                            // first chunk starts at the divergence point
                            // and the mapped tokens are never recomputed.
                            seq.prefix_tokens = a.map_prefix(id, &req.prompt);
                            seq.prefilled = seq.prefix_tokens;
                        }
                        fits && scheduler.submit(seq.clone(), &a)
                    };
                    if !accepted {
                        metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                        let _ = events.send(Event::Done {
                            request_id: id,
                            reason: FinishReason::Rejected,
                            stats: RequestStats::default(),
                        });
                        continue;
                    }
                    metrics.prompt_tokens.fetch_add(prompt_len as u64, Ordering::Relaxed);
                    let mut session =
                        model.new_session_shared(&arena, id, prompt_len + req.max_new_tokens);
                    // The mapped prefix is already cache-resident: the
                    // session resumes mid-prompt.
                    session.pos = seq.prefix_tokens;
                    live.insert(
                        id,
                        Live {
                            session,
                            req,
                            events,
                            submitted: Instant::now(),
                            prefilled_at: None,
                            last_token: 0,
                            generated: Vec::new(),
                        },
                    );
                }
            }
            if idle {
                break; // got one command while idle; re-plan
            }
        }

        let plan = {
            let mut a = arena.lock().unwrap();
            scheduler.step(&mut a)
        };
        if plan.prefill.is_empty() && plan.decode.is_empty() {
            continue;
        }
        metrics.peak_batch.fetch_max(plan.decode_width() as u64, Ordering::Relaxed);
        if let Some(&chunk) = plan.prefill_chunks.iter().max() {
            metrics.peak_prefill_chunk.fetch_max(chunk as u64, Ordering::Relaxed);
        }

        // Preempted sequences lost their pages (released by the
        // scheduler — shared prefix pages survive through the index or
        // other referents): reset their page-table views so re-admission
        // re-prefills from position 0.
        for id in &plan.preempted {
            if let Some(l) = live.get_mut(id) {
                l.session.clear();
            }
        }

        // Run this step's prefill chunks. Fresh prompts stream from the
        // divergence point (`session.pos`: past the mapped prefix and any
        // chunks from earlier steps); the chunk that completes the prompt
        // yields the logits the first sampled token comes from.
        // Re-admissions after a preemption rebuild the cache instead:
        // prompt plus every generated token except the last (which the
        // next decode step appends) — already-emitted tokens are never
        // re-sampled.
        for (id, &chunk) in plan.prefill.iter().zip(plan.prefill_chunks.iter()) {
            let l = live.get_mut(id).expect("live entry for admitted seq");
            let fresh = l.generated.is_empty();
            let target: Vec<u32> = if fresh {
                l.req.prompt.clone()
            } else {
                let mut t = l.req.prompt.clone();
                t.extend_from_slice(&l.generated[..l.generated.len() - 1]);
                t
            };
            let start = l.session.pos;
            let end = (start + chunk).min(target.len());
            let logits = model.prefill(&mut l.session, &target[start..end]);
            metrics.prefill_tokens_computed.fetch_add((end - start) as u64, Ordering::Relaxed);
            if end < target.len() {
                // Mid-prompt chunk: more stream next step.
                scheduler.on_prefill_progress(*id, end - start);
                continue;
            }
            // The full prompt is in the KV cache *now* — this
            // notification, not admission planning, is what flips
            // Prefill → Decoding (so `current_tokens` never claims
            // unprefilled occupancy).
            scheduler.on_prefilled(*id);
            if !fresh {
                continue;
            }
            if config.prefix_cache {
                // Index the completed prompt's full pages so later
                // arrivals with the same prefix map them instead of
                // recomputing.
                arena.lock().unwrap().register_prefix(*id, &l.req.prompt);
            }
            let tok = sample(&logits, &l.req.sampling, &mut rng);
            l.prefilled_at = Some(Instant::now());
            metrics.ttft.record(l.submitted.elapsed());
            l.last_token = tok;
            l.generated.push(tok);
            let _ = l.events.send(Event::Token { request_id: *id, token: tok });
            scheduler.on_token(*id);
            if l.req.stop_on_eos && tok == config.eos_token {
                // Retired at the next step's retire scan: stop the
                // scheduler reserving (or preempting) for a decode
                // append that will never run.
                scheduler.on_stop(*id);
            }
            metrics.generated_tokens.fetch_add(1, Ordering::Relaxed);
        }

        // Retire sequences that already hit a stop condition.
        let mut finished: Vec<(u64, FinishReason)> = Vec::new();
        for id in &plan.decode {
            let l = &live[id];
            if l.generated.len() >= l.req.max_new_tokens {
                finished.push((*id, FinishReason::Length));
            } else if l.req.stop_on_eos && l.last_token == config.eos_token {
                finished.push((*id, FinishReason::Eos));
            }
        }
        let decode_ids: Vec<u64> =
            plan.decode.iter().copied().filter(|id| !finished.iter().any(|(f, _)| f == id)).collect();

        // Batched decode step over every still-running sequence.
        if !decode_ids.is_empty() {
            let t0 = Instant::now();
            let tokens: Vec<u32> = decode_ids.iter().map(|id| live[id].last_token).collect();
            // Pull the sessions out to satisfy the borrow checker, then
            // reinstall (cheap: Session is a couple of Vecs moved by ptr).
            let mut entries: Vec<(u64, &mut Live)> = live
                .iter_mut()
                .filter(|(id, _)| decode_ids.contains(id))
                .map(|(id, l)| (*id, l))
                .collect();
            entries.sort_by_key(|(id, _)| decode_ids.iter().position(|d| d == id).unwrap());
            let mut sessions: Vec<&mut Session> =
                entries.iter_mut().map(|(_, l)| &mut l.session).collect();
            let logits = model.decode_batch(&mut sessions, &tokens);
            drop(sessions);
            metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
            metrics.batched_tokens.fetch_add(decode_ids.len() as u64, Ordering::Relaxed);
            metrics.step_latency.record(t0.elapsed());

            for ((id, l), lg) in entries.into_iter().zip(logits.iter()) {
                let tok = sample(lg, &l.req.sampling, &mut rng);
                l.last_token = tok;
                l.generated.push(tok);
                let _ = l.events.send(Event::Token { request_id: id, token: tok });
                scheduler.on_token(id);
                if l.req.stop_on_eos && tok == config.eos_token {
                    scheduler.on_stop(id);
                }
                metrics.generated_tokens.fetch_add(1, Ordering::Relaxed);
            }
        }

        // Serving-shape trace: the GEMM widths this step actually ran
        // (the decode width can shrink below the plan's when sequences
        // retired before the batched GEMM).
        let (trace_steps, trace_shapes) = trace.record_step(&plan, decode_ids.len());
        metrics.trace_steps.store(trace_steps, Ordering::Relaxed);
        metrics.trace_shapes.store(trace_shapes, Ordering::Relaxed);
        if !config.profile_widths.is_empty() {
            // Numeric tune-vs-serve drift, live per step (the one-shot
            // end-of-run warning in `main` uses the same quantity).
            let drift = trace.snapshot().drift_l1(&config.profile_widths);
            metrics.drift_l1_milli.store((drift * 1000.0).round() as u64, Ordering::Relaxed);
        }

        // Mirror the model's dispatch-observability counters (untuned-
        // shape fallbacks and winners that could not run — see
        // kernels::tuner::DispatchPlan) after the step's forwards;
        // Engine::start seeds the same counters for packing/prepack time.
        metrics.dispatch_fallbacks.store(model.plan.fallbacks(), Ordering::Relaxed);
        metrics.dispatch_degraded.store(model.plan.degraded(), Ordering::Relaxed);
        mirror_prepare_stats(&model, &metrics);
        metrics.mirror_phase(model.phase_us());
        metrics.mirror_simd();

        // Release finished sequences' pages, then mirror the arena state
        // *before* any Done event goes out: a client woken by Done must
        // observe post-release occupancy in the metrics.
        for (id, _) in &finished {
            scheduler.finish(*id, &mut arena.lock().unwrap());
        }
        mirror_kv_stats(&arena, &metrics);
        mirror_numa_stats(&model, &arena, &metrics);

        // Emit completions.
        for (id, reason) in finished {
            if let Some(l) = live.remove(&id) {
                let stats = RequestStats {
                    queue_wait: l
                        .prefilled_at
                        .map(|t| t.duration_since(l.submitted))
                        .unwrap_or_default(),
                    ttft: l
                        .prefilled_at
                        .map(|t| t.duration_since(l.submitted))
                        .unwrap_or_default(),
                    prompt_tokens: l.req.prompt.len(),
                    new_tokens: l.generated.len(),
                    total: l.submitted.elapsed(),
                };
                metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
                let _ = l.events.send(Event::Done { request_id: id, reason, stats });
            }
        }
    }

    // Shutdown: cancel everything still live.
    for (id, l) in live {
        let _ = l.events.send(Event::Done {
            request_id: id,
            reason: FinishReason::Cancelled,
            stats: RequestStats::default(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_kernels::kernels::QuantType;
    use pallas_model::model::{ModelConfig, SamplingParams};

    fn tiny_engine(max_batch: usize) -> Engine {
        let model = Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 3);
        Engine::start(
            model,
            EngineConfig { max_batch, kv_budget_tokens: 2048, eos_token: 1, seed: 7, ..Default::default() },
        )
    }

    #[test]
    fn single_request_completes() {
        let engine = tiny_engine(4);
        assert!(engine.kernel_info.contains("fixed(I2_S)"), "{}", engine.kernel_info);
        let h = engine.submit(Request::greedy(vec![5, 6, 7], 8));
        let (tokens, reason, stats) = h.wait();
        assert_eq!(tokens.len(), 8);
        assert_eq!(reason, FinishReason::Length);
        assert_eq!(stats.prompt_tokens, 3);
        assert_eq!(stats.new_tokens, 8);
    }

    #[test]
    fn greedy_is_deterministic_across_engines() {
        let a = {
            let engine = tiny_engine(4);
            engine.submit(Request::greedy(vec![9, 9, 9], 6)).wait().0
        };
        let b = {
            let engine = tiny_engine(4);
            engine.submit(Request::greedy(vec![9, 9, 9], 6)).wait().0
        };
        assert_eq!(a, b);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let engine = tiny_engine(3);
        let handles: Vec<_> = (0..6)
            .map(|i| engine.submit(Request::greedy(vec![i as u32 + 1, 2, 3], 5)))
            .collect();
        for h in handles {
            let (tokens, reason, _) = h.wait();
            assert_eq!(tokens.len(), 5);
            assert_eq!(reason, FinishReason::Length);
        }
        assert!(engine.metrics.mean_batch() > 1.0, "batching should engage");
    }

    #[test]
    fn batched_output_matches_sequential_output() {
        // Continuous batching must not change greedy outputs.
        let prompts: Vec<Vec<u32>> = vec![vec![4, 5], vec![6, 7, 8], vec![100]];
        let sequential: Vec<Vec<u32>> = {
            let engine = tiny_engine(1); // batch of 1 → sequential
            prompts
                .iter()
                .map(|p| engine.submit(Request::greedy(p.clone(), 6)).wait().0)
                .collect()
        };
        let engine = tiny_engine(4);
        let handles: Vec<_> =
            prompts.iter().map(|p| engine.submit(Request::greedy(p.clone(), 6))).collect();
        let batched: Vec<Vec<u32>> = handles.into_iter().map(|h| h.wait().0).collect();
        assert_eq!(sequential, batched);
    }

    #[test]
    fn chunked_prefill_matches_whole_prompt_output() {
        // Streaming the prompt into the cache page-by-page must not
        // change greedy outputs (same GEMMs, different step boundaries).
        let prompt: Vec<u32> = (0..45).map(|i| (i * 7) % 512).collect();
        let whole = {
            let engine = tiny_engine(2);
            engine.submit(Request::greedy(prompt.clone(), 8)).wait().0
        };
        for chunk in [16, 48] {
            let model = Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 3);
            let engine = Engine::start(
                model,
                EngineConfig {
                    max_batch: 2,
                    kv_budget_tokens: 2048,
                    seed: 7,
                    prefill_chunk: chunk,
                    ..Default::default()
                },
            );
            let chunked = engine.submit(Request::greedy(prompt.clone(), 8)).wait().0;
            assert_eq!(whole, chunked, "chunk={chunk} diverged");
        }
    }

    #[test]
    fn prefix_cache_reuses_shared_prompt() {
        // Two identical prompts: the second maps the first's pages and
        // prefills only the final token; outputs stay identical.
        let prompt: Vec<u32> = (0..40).map(|i| (i * 3) % 512).collect();
        let model = Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 3);
        let engine = Engine::start(
            model,
            EngineConfig {
                max_batch: 2,
                kv_budget_tokens: 2048,
                seed: 7,
                prefix_cache: true,
                ..Default::default()
            },
        );
        let a = engine.submit(Request::greedy(prompt.clone(), 6)).wait().0;
        let b = engine.submit(Request::greedy(prompt.clone(), 6)).wait().0;
        assert_eq!(a, b, "shared-prefix decode must be bit-identical");
        let hit = engine.metrics.prefix_hit_tokens.load(Ordering::Relaxed);
        assert!(hit > 0, "second request should map the indexed prefix");
        let computed = engine.metrics.prefill_tokens_computed.load(Ordering::Relaxed);
        assert_eq!(
            computed as usize,
            prompt.len() + (prompt.len() - hit as usize),
            "only the unmapped tail of the second prompt was recomputed"
        );
    }

    #[test]
    fn oversized_prompt_is_rejected() {
        let model = Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 3);
        let engine = Engine::start(
            model,
            EngineConfig { max_batch: 2, kv_budget_tokens: 64, eos_token: 1, seed: 0, ..Default::default() },
        );
        let h = engine.submit(Request::greedy((0..100).collect(), 50));
        let (_, reason, _) = h.wait();
        assert_eq!(reason, FinishReason::Rejected);
    }

    #[test]
    fn empty_prompt_is_rejected() {
        let engine = tiny_engine(2);
        let (_, reason, _) = engine.submit(Request::greedy(vec![], 4)).wait();
        assert_eq!(reason, FinishReason::Rejected);
    }

    #[test]
    fn prepare_cache_metrics_are_populated() {
        let engine = tiny_engine(2);
        let (tokens, _, _) = engine.submit(Request::greedy(vec![5, 6, 7], 4)).wait();
        assert_eq!(tokens.len(), 4);
        let hits = engine.metrics.prepare_cache_hits.load(Ordering::Relaxed);
        let misses = engine.metrics.prepare_cache_misses.load(Ordering::Relaxed);
        // Every layer input prepares once (miss) and wk/wv + up share it
        // (hits): 4 misses / 3 hits per layer per step.
        assert!(misses > 0, "prepare misses should be mirrored");
        assert!(hits > 0, "prepare hits should be mirrored (qkv/gate+up sharing)");
        assert_eq!(hits % 3, 0, "3 hits per layer per step, got {hits}");
        assert_eq!(misses % 4, 0, "4 misses per layer per step, got {misses}");
    }

    #[test]
    fn sampled_generation_stays_in_vocab() {
        let engine = tiny_engine(2);
        let req = Request {
            prompt: vec![1, 2],
            max_new_tokens: 12,
            sampling: SamplingParams { temperature: 1.0, top_k: 50, top_p: 0.95 },
            stop_on_eos: false,
        };
        let (tokens, _, _) = engine.submit(req).wait();
        assert_eq!(tokens.len(), 12);
        assert!(tokens.iter().all(|&t| (t as usize) < 512));
    }
}
