//! Serving-shape traces: the histogram of GEMM batch shapes a real run
//! exhibits — prefill chunk lengths and decode batch widths, each with
//! its occurrence count — recorded by the engine step loop and persisted
//! as `trace.json` (`run`/`serve --record-trace`).
//!
//! Why this exists: the tuner's value depends on measuring the shapes the
//! workload actually runs. A fixed `--batches 1,4` sweep tunes a guess;
//! a recorded trace tunes the observed distribution, and its frequencies
//! weight the resulting profile entries so they reflect real traffic
//! (`tune --trace`, see `kernels::tuner` and docs/tuning.md).
#![deny(missing_docs)]

use super::scheduler::StepPlan;
use pallas_core::util::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

/// Trace file format version written by [`ServingTrace::to_json`] (bump
/// on breaking schema changes).
pub const TRACE_VERSION: u64 = 1;

/// L1-distance threshold above which `run`/`serve` warn that live
/// traffic has drifted from the shapes the loaded profile was tuned at
/// (see [`ServingTrace::drift_l1`]; the distance lives in `[0, 2]`, so
/// 0.5 means a quarter of the probability mass moved).
pub const DRIFT_WARN_L1: f64 = 0.5;

/// A recorded serving-shape histogram. Keys are GEMM batch widths (rows
/// of the activation batch): prompt tokens per prefill call, sequences
/// per batched decode call. `BTreeMap` keeps iteration (and the JSON on
/// disk) deterministically ordered.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServingTrace {
    /// Engine steps that executed at least one GEMM.
    pub steps: u64,
    /// Prefill chunk length (prompt tokens) → occurrences.
    pub prefill_chunks: BTreeMap<usize, u64>,
    /// Decode batch width (sequences) → occurrences.
    pub decode_widths: BTreeMap<usize, u64>,
}

impl ServingTrace {
    /// An empty trace.
    pub fn new() -> ServingTrace {
        ServingTrace::default()
    }

    /// True when nothing was recorded (tuning from such a trace is an
    /// error — there are no observed shapes to tune at).
    pub fn is_empty(&self) -> bool {
        self.prefill_chunks.is_empty() && self.decode_widths.is_empty()
    }

    /// Record one prefill call of `chunk` prompt tokens. Returns true if
    /// this chunk length had not been seen before.
    pub fn record_prefill(&mut self, chunk: usize) -> bool {
        if chunk == 0 {
            return false;
        }
        let c = self.prefill_chunks.entry(chunk).or_insert(0);
        *c += 1;
        *c == 1
    }

    /// Record one batched decode call over `width` sequences. Returns
    /// true if this width had not been seen before.
    pub fn record_decode(&mut self, width: usize) -> bool {
        if width == 0 {
            return false;
        }
        let c = self.decode_widths.entry(width).or_insert(0);
        *c += 1;
        *c == 1
    }

    /// Record the shapes of one planned engine step (`decode_width` is
    /// the width the step actually decoded at, which can be smaller than
    /// the plan's when sequences retired before the batched GEMM).
    /// Returns how many *merged-distinct* shapes (see
    /// [`ServingTrace::distinct_shapes`]) this step introduced, so
    /// callers can maintain a running count without rescanning the
    /// histograms every step.
    pub fn record_step(&mut self, plan: &StepPlan, decode_width: usize) -> usize {
        if plan.prefill_chunks.is_empty() && decode_width == 0 {
            return 0;
        }
        self.steps += 1;
        let mut new_shapes = 0;
        for &chunk in &plan.prefill_chunks {
            let merged_new = !self.prefill_chunks.contains_key(&chunk)
                && !self.decode_widths.contains_key(&chunk);
            // Both conditions matter: merged_new alone would count a
            // zero-length chunk (absent from both maps, but rejected by
            // record_prefill) as a new shape.
            if self.record_prefill(chunk) && merged_new {
                new_shapes += 1;
            }
        }
        if decode_width > 0 {
            let merged_new = !self.prefill_chunks.contains_key(&decode_width)
                && !self.decode_widths.contains_key(&decode_width);
            if self.record_decode(decode_width) && merged_new {
                new_shapes += 1;
            }
        }
        new_shapes
    }

    /// Total recorded GEMM calls (prefill + decode events).
    pub fn total_events(&self) -> u64 {
        self.prefill_chunks.values().sum::<u64>() + self.decode_widths.values().sum::<u64>()
    }

    /// Distinct shape keys observed (prefill chunk lengths plus decode
    /// widths; a width that appears as both counts once).
    pub fn distinct_shapes(&self) -> usize {
        let mut keys: Vec<usize> =
            self.prefill_chunks.keys().chain(self.decode_widths.keys()).copied().collect();
        keys.sort_unstable();
        keys.dedup();
        keys.len()
    }

    /// The trace as a tuner batch sweep: every observed GEMM batch width
    /// (prefill chunk lengths and decode widths merged), ascending, each
    /// with its fraction of total recorded events as weight. Weights are
    /// per *call*, not per token: one prefill chunk of 100 tokens and one
    /// decode step over 4 sequences each streamed the weights once, which
    /// is what the tuner's per-matmul rate ranks.
    pub fn weighted_batches(&self) -> Vec<(usize, f64)> {
        let total = self.total_events();
        if total == 0 {
            return Vec::new();
        }
        let mut merged: BTreeMap<usize, u64> = self.prefill_chunks.clone();
        for (&w, &c) in &self.decode_widths {
            *merged.entry(w).or_insert(0) += c;
        }
        merged.into_iter().map(|(n, c)| (n, c as f64 / total as f64)).collect()
    }

    /// [`ServingTrace::weighted_batches`] truncated to the `k`
    /// highest-weight widths (ties keep the smaller width — the decode
    /// regimes), returned ascending along with how many observed widths
    /// were dropped. Weights keep their full-trace fractions, so a
    /// truncated sweep's weights sum below 1 by exactly the dropped
    /// traffic share — the caller should log the drop, never hide it.
    /// Guards `tune --trace` against long-tail workloads where nearly
    /// every prompt length is distinct and would each become a tuned
    /// width.
    pub fn top_weighted_batches(&self, k: usize) -> (Vec<(usize, f64)>, usize) {
        let mut all = self.weighted_batches();
        if k == 0 || all.len() <= k {
            return (all, 0);
        }
        let dropped = all.len() - k;
        all.sort_by(|a, b| b.1.partial_cmp(&a.1).expect("finite weight").then(a.0.cmp(&b.0)));
        all.truncate(k);
        all.sort_unstable_by_key(|&(n, _)| n);
        (all, dropped)
    }

    /// The most frequently observed prefill chunk length (ties resolve
    /// to the longest; `None` when no prefill was recorded) — the chunk
    /// the override search times compositions at under `tune --trace`.
    pub fn modal_prefill_chunk(&self) -> Option<usize> {
        self.prefill_chunks.iter().max_by_key(|&(&n, &c)| (c, n)).map(|(&n, _)| n)
    }

    /// The most frequently observed decode batch width (ties resolve to
    /// the widest; `None` when no decode was recorded).
    pub fn modal_decode_width(&self) -> Option<usize> {
        self.decode_widths.iter().max_by_key(|&(&n, &c)| (c, n)).map(|(&n, _)| n)
    }

    /// Fraction of recorded *tokens* that came from prefill (chunk
    /// lengths weighted by count vs decode widths weighted by count) —
    /// the phase blend the override search scores compositions with.
    /// Returns 0.5 when the trace is empty (no evidence either way).
    pub fn prefill_token_fraction(&self) -> f64 {
        let prefill: u64 = self.prefill_chunks.iter().map(|(&n, &c)| n as u64 * c).sum();
        let decode: u64 = self.decode_widths.iter().map(|(&n, &c)| n as u64 * c).sum();
        if prefill + decode == 0 {
            0.5
        } else {
            prefill as f64 / (prefill + decode) as f64
        }
    }

    /// L1 distance in `[0, 2]` between this trace's batch-width
    /// distribution ([`ServingTrace::weighted_batches`]) and a tuning
    /// profile's recorded per-width traffic weights
    /// (`TuningProfile::weighted_widths`). Both sides are normalized
    /// over the union of widths, so mass on widths only one side knows
    /// about counts in full — a workload running shapes the profile
    /// never measured *is* drift. `run`/`serve` compare the live trace
    /// against the loaded profile and suggest a re-tune above
    /// [`DRIFT_WARN_L1`].
    pub fn drift_l1(&self, profile_widths: &[(usize, f64)]) -> f64 {
        let live = self.weighted_batches();
        let total_p: f64 = profile_widths.iter().map(|&(_, w)| w).sum();
        let mut widths: Vec<usize> = live
            .iter()
            .map(|&(n, _)| n)
            .chain(profile_widths.iter().map(|&(n, _)| n))
            .collect();
        widths.sort_unstable();
        widths.dedup();
        let weight_of = |v: &[(usize, f64)], n: usize| {
            v.iter().find(|&&(m, _)| m == n).map_or(0.0, |&(_, w)| w)
        };
        widths
            .iter()
            .map(|&n| {
                let p = if total_p > 0.0 { weight_of(profile_widths, n) / total_p } else { 0.0 };
                (weight_of(&live, n) - p).abs()
            })
            .sum()
    }

    /// One-line human summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{} steps, {} prefill chunks ({} distinct), {} decode batches ({} distinct)",
            self.steps,
            self.prefill_chunks.values().sum::<u64>(),
            self.prefill_chunks.len(),
            self.decode_widths.values().sum::<u64>(),
            self.decode_widths.len()
        )
    }

    /// Serialize to the JSON trace schema.
    pub fn to_json(&self) -> Json {
        let hist = |map: &BTreeMap<usize, u64>| {
            Json::Arr(
                map.iter()
                    .map(|(&n, &c)| {
                        Json::Obj(vec![
                            ("n".into(), Json::Num(n as f64)),
                            ("count".into(), Json::Num(c as f64)),
                        ])
                    })
                    .collect(),
            )
        };
        Json::Obj(vec![
            ("version".into(), Json::Num(TRACE_VERSION as f64)),
            ("steps".into(), Json::Num(self.steps as f64)),
            ("prefill_chunks".into(), hist(&self.prefill_chunks)),
            ("decode_widths".into(), hist(&self.decode_widths)),
        ])
    }

    /// Parse from the JSON trace schema (clear errors, no field-order
    /// guessing — same contract as the tuning profile loader).
    pub fn from_json(v: &Json) -> Result<ServingTrace> {
        let version = v.get("version").and_then(Json::as_usize).context("trace: version")?;
        if version as u64 != TRACE_VERSION {
            bail!(
                "unsupported trace version {version} (supported: {TRACE_VERSION}); \
                 re-record with `--record-trace <path>`"
            );
        }
        let steps = v.get("steps").and_then(Json::as_usize).context("trace: steps")? as u64;
        let hist = |name: &str| -> Result<BTreeMap<usize, u64>> {
            let mut map = BTreeMap::new();
            for (i, e) in v
                .get(name)
                .and_then(Json::as_array)
                .with_context(|| format!("trace: {name}"))?
                .iter()
                .enumerate()
            {
                let n = e
                    .get("n")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("trace {name}[{i}]: n"))?;
                let count = e
                    .get("count")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("trace {name}[{i}]: count"))?;
                if n == 0 || count == 0 {
                    bail!("trace {name}[{i}]: zero shape or count");
                }
                if map.insert(n, count as u64).is_some() {
                    bail!("trace {name}[{i}]: duplicate shape {n}");
                }
            }
            Ok(map)
        };
        Ok(ServingTrace {
            steps,
            prefill_chunks: hist("prefill_chunks")?,
            decode_widths: hist("decode_widths")?,
        })
    }

    /// Write the trace to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing trace {}", path.display()))
    }

    /// Load a trace from a JSON file.
    pub fn load(path: &Path) -> Result<ServingTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing trace {}", path.display()))?;
        Self::from_json(&v)
    }
}

/// Thread-safe trace accumulator shared between the engine thread (which
/// records) and the client side (which snapshots / persists). Step-rate
/// locking, not hot-path: one lock per engine step, far off the GEMM
/// path. The distinct-shape total is maintained incrementally from
/// [`ServingTrace::record_step`]'s return value rather than rescanned.
#[derive(Default)]
pub struct TraceRecorder {
    /// The trace plus its running merged-distinct shape count.
    inner: Mutex<(ServingTrace, u64)>,
}

impl TraceRecorder {
    /// A fresh, empty recorder.
    pub fn new() -> TraceRecorder {
        TraceRecorder::default()
    }

    /// Record one executed engine step (see [`ServingTrace::record_step`]).
    /// Returns the running `(steps, distinct shapes)` totals so callers
    /// can mirror them into lock-free metrics without re-locking.
    pub fn record_step(&self, plan: &StepPlan, decode_width: usize) -> (u64, u64) {
        let mut guard = self.inner.lock().unwrap();
        let (t, shapes) = &mut *guard;
        *shapes += t.record_step(plan, decode_width) as u64;
        (t.steps, *shapes)
    }

    /// Copy of everything recorded so far.
    pub fn snapshot(&self) -> ServingTrace {
        self.inner.lock().unwrap().0.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(chunks: Vec<usize>, decode: Vec<u64>) -> StepPlan {
        StepPlan {
            prefill: (0..chunks.len() as u64).collect(),
            prefill_chunks: chunks,
            decode,
            preempted: Vec::new(),
        }
    }

    #[test]
    fn records_step_shapes() {
        let mut t = ServingTrace::new();
        // Returns count newly-seen merged shapes: {5, 9, 2}, then none,
        // then {1}.
        assert_eq!(t.record_step(&plan(vec![5, 9], vec![1, 2]), 2), 3);
        assert_eq!(t.record_step(&plan(vec![], vec![1, 2]), 2), 0);
        assert_eq!(t.record_step(&plan(vec![5], vec![1]), 1), 1);
        assert_eq!(t.steps, 3);
        assert_eq!(t.prefill_chunks.get(&5), Some(&2));
        assert_eq!(t.prefill_chunks.get(&9), Some(&1));
        assert_eq!(t.decode_widths.get(&2), Some(&2));
        assert_eq!(t.decode_widths.get(&1), Some(&1));
        assert_eq!(t.total_events(), 6);
        assert_eq!(t.distinct_shapes(), 4); // 5, 9, 2, 1
    }

    #[test]
    fn empty_steps_are_not_counted() {
        let mut t = ServingTrace::new();
        t.record_step(&plan(vec![], vec![]), 0);
        assert_eq!(t.steps, 0);
        assert!(t.is_empty());
        assert!(t.weighted_batches().is_empty());
        assert_eq!(t.prefill_token_fraction(), 0.5);
    }

    #[test]
    fn weighted_batches_merge_phases_and_sum_to_one() {
        let mut t = ServingTrace::new();
        for _ in 0..3 {
            t.record_prefill(8);
        }
        t.record_prefill(2);
        for _ in 0..4 {
            t.record_decode(2);
        }
        // n=2 appears as both a prefill chunk and a decode width: merged.
        let wb = t.weighted_batches();
        assert_eq!(wb.len(), 2);
        assert_eq!(wb[0].0, 2);
        assert!((wb[0].1 - 5.0 / 8.0).abs() < 1e-12, "{wb:?}");
        assert_eq!(wb[1].0, 8);
        assert!((wb[1].1 - 3.0 / 8.0).abs() < 1e-12, "{wb:?}");
        let total: f64 = wb.iter().map(|(_, w)| w).sum();
        assert!((total - 1.0).abs() < 1e-12);
        // Token-weighted phase fraction: 3*8 + 1*2 = 26 prefill tokens,
        // 4*2 = 8 decode tokens.
        assert!((t.prefill_token_fraction() - 26.0 / 34.0).abs() < 1e-12);
        // Modal shapes: 8 is the most frequent chunk, 2 the only width.
        assert_eq!(t.modal_prefill_chunk(), Some(8));
        assert_eq!(t.modal_decode_width(), Some(2));
        assert_eq!(ServingTrace::new().modal_prefill_chunk(), None);
        assert_eq!(ServingTrace::new().modal_decode_width(), None);
    }

    #[test]
    fn top_weighted_batches_keeps_heaviest_widths() {
        let mut t = ServingTrace::new();
        for _ in 0..10 {
            t.record_decode(1);
        }
        for _ in 0..6 {
            t.record_decode(4);
        }
        for _ in 0..3 {
            t.record_prefill(32);
        }
        t.record_prefill(17); // long tail
        // Full distribution: no truncation.
        assert_eq!(t.top_weighted_batches(10), (t.weighted_batches(), 0));
        assert_eq!(t.top_weighted_batches(0), (t.weighted_batches(), 0));
        // Top 2 by weight: widths 1 (10/20) and 4 (6/20), ascending,
        // with 2 tail widths dropped and weights keeping their
        // full-trace fractions (sum < 1 by the dropped share).
        let (top, dropped) = t.top_weighted_batches(2);
        assert_eq!(dropped, 2);
        assert_eq!(top.iter().map(|&(n, _)| n).collect::<Vec<_>>(), vec![1, 4]);
        let kept: f64 = top.iter().map(|(_, w)| w).sum();
        assert!((kept - 16.0 / 20.0).abs() < 1e-12, "{kept}");
    }

    #[test]
    fn drift_is_zero_for_matching_distributions() {
        let mut t = ServingTrace::new();
        for _ in 0..3 {
            t.record_decode(1);
        }
        t.record_prefill(8);
        // Profile weights proportional to the trace (un-normalized on
        // purpose: drift_l1 normalizes the profile side).
        let widths = vec![(1usize, 7.5), (8usize, 2.5)];
        assert!(t.drift_l1(&widths) < 1e-12);
    }

    #[test]
    fn drift_counts_disjoint_mass_in_full() {
        let mut t = ServingTrace::new();
        t.record_decode(4); // all live traffic at width 4
        let widths = vec![(1usize, 1.0)]; // profile tuned only width 1
        let d = t.drift_l1(&widths);
        assert!((d - 2.0).abs() < 1e-12, "fully disjoint → L1 of 2, got {d}");
        assert!(d > DRIFT_WARN_L1);
        // Half the live mass moved off the tuned width: L1 = 1.0.
        t.record_decode(1);
        let d = t.drift_l1(&widths);
        assert!((d - 1.0).abs() < 1e-12, "{d}");
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let mut t = ServingTrace::new();
        t.record_step(&plan(vec![7, 31], vec![1, 2, 3]), 3);
        t.record_step(&plan(vec![7], vec![1]), 1);
        let back = ServingTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        let text = t.to_json().to_string_pretty();
        let back2 = ServingTrace::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back2, t);
    }

    #[test]
    fn from_json_rejects_bad_traces() {
        assert!(ServingTrace::from_json(&Json::parse("{}").unwrap()).is_err());
        let wrong_version =
            r#"{"version": 9, "steps": 0, "prefill_chunks": [], "decode_widths": []}"#;
        let err = ServingTrace::from_json(&Json::parse(wrong_version).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("supported"), "{err:#}");
        let zero_shape = r#"{"version": 1, "steps": 1,
            "prefill_chunks": [{"n": 0, "count": 3}], "decode_widths": []}"#;
        assert!(ServingTrace::from_json(&Json::parse(zero_shape).unwrap()).is_err());
        let dup = r#"{"version": 1, "steps": 1, "prefill_chunks": [],
            "decode_widths": [{"n": 2, "count": 1}, {"n": 2, "count": 4}]}"#;
        assert!(ServingTrace::from_json(&Json::parse(dup).unwrap()).is_err());
    }

    #[test]
    fn recorder_reports_running_totals() {
        let r = TraceRecorder::new();
        assert_eq!(r.record_step(&plan(vec![5], vec![1]), 1), (1, 2));
        assert_eq!(r.record_step(&plan(vec![5], vec![1, 2]), 2), (2, 3));
        assert_eq!(r.record_step(&plan(vec![], vec![1]), 1), (3, 3));
        // A step with no GEMM work leaves the totals untouched.
        assert_eq!(r.record_step(&plan(vec![], vec![]), 0), (3, 3));
        let snap = r.snapshot();
        assert_eq!(snap.steps, 3);
        assert_eq!(snap.distinct_shapes(), 3);
    }
}
