//! Request/response types for the serving engine.

use pallas_model::model::SamplingParams;
use std::sync::mpsc::Receiver;
use std::time::Duration;

/// A generation request submitted to the engine.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    /// Stop on the EOS token id (engine-configured).
    pub stop_on_eos: bool,
}

impl Request {
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            prompt,
            max_new_tokens,
            sampling: SamplingParams::greedy(),
            stop_on_eos: false,
        }
    }
}

/// Why a request finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// Sampled the EOS token.
    Eos,
    /// Engine shut down before completion.
    Cancelled,
    /// Rejected at admission (prompt longer than KV budget).
    Rejected,
}

/// Timing/throughput statistics reported with `Event::Done`.
#[derive(Clone, Debug, Default)]
pub struct RequestStats {
    /// Queue wait before prefill started.
    pub queue_wait: Duration,
    /// Time to first token (submission → first decode token).
    pub ttft: Duration,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Generated tokens.
    pub new_tokens: usize,
    /// Total wall time from submission to completion.
    pub total: Duration,
}

impl RequestStats {
    /// Decode throughput in tokens/s (excludes prefill).
    pub fn decode_tps(&self) -> f64 {
        let decode_time = self.total.saturating_sub(self.ttft).as_secs_f64();
        if decode_time <= 0.0 || self.new_tokens <= 1 {
            return 0.0;
        }
        (self.new_tokens - 1) as f64 / decode_time
    }
}

/// Streamed engine → client events.
#[derive(Clone, Debug)]
pub enum Event {
    /// One generated token.
    Token { request_id: u64, token: u32 },
    /// Request finished; no more events follow.
    Done { request_id: u64, reason: FinishReason, stats: RequestStats },
}

/// Client-side handle: the request id plus the event stream.
pub struct RequestHandle {
    pub id: u64,
    pub events: Receiver<Event>,
}

impl RequestHandle {
    /// Block until completion, collecting all generated tokens.
    pub fn wait(self) -> (Vec<u32>, FinishReason, RequestStats) {
        let mut tokens = Vec::new();
        for ev in self.events.iter() {
            match ev {
                Event::Token { token, .. } => tokens.push(token),
                Event::Done { reason, stats, .. } => return (tokens, reason, stats),
            }
        }
        (tokens, FinishReason::Cancelled, RequestStats::default())
    }
}
