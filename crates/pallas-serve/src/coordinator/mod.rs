//! Layer-3 coordinator: the serving system around the kernel library —
//! request router, a continuous-batching scheduler with watermark
//! admission and LIFO preemption, and the engine event loop (the role
//! llama.cpp's `server` / vLLM's router play for the paper's system).
//!
//! The paged KV arena that owns the cache bytes lives one layer below
//! since the crate split ([`pallas_core::arena`]) so `model::Session`
//! and this scheduler share it without the model reaching up into the
//! coordinator; [`kv_pool`] re-exports it under its historical path.
//!
//! Threading model: one engine thread owns the model and all sessions;
//! clients submit [`request::Request`]s over a channel and stream
//! [`request::Event`]s back. Python is never involved; the binary is
//! self-contained after `make artifacts`.

pub mod engine;
pub mod request;
pub mod scheduler;
pub mod trace;

/// Historical home of the KV arena — now a re-export of
/// [`pallas_core::arena`] (the arena moved below both `model` and the
/// scheduler in the workspace crate split).
pub mod kv_pool {
    pub use pallas_core::arena::*;
}

pub use engine::{Engine, EngineConfig};
pub use kv_pool::{AttnWorkspace, KvArena, KvDtype, PAGE_TOKENS};
pub use request::{Event, FinishReason, Request, RequestHandle};
pub use trace::{ServingTrace, TraceRecorder};
