//! # pallas-serve — serving layer
//!
//! The continuous-batching engine and watermark scheduler
//! ([`coordinator`]), engine [`metrics`], the PJRT-backed [`runtime`]
//! (stub unless the `pjrt` feature is enabled), launch [`config`]
//! presets, the minimal [`cli`] argument parser, and the `bitnet`
//! binary's entry point ([`entry`]).
//!
//! Top of the workspace graph: depends on [`pallas_model`],
//! [`pallas_kernels`] and [`pallas_core`]; nothing depends on it except
//! the `rust_pallas` facade.

#![warn(clippy::undocumented_unsafe_blocks)]

#[deny(unsafe_code)]
pub mod cli;
#[deny(unsafe_code)]
pub mod config;
#[deny(unsafe_code)]
pub mod coordinator;
#[deny(unsafe_code)]
pub mod entry;
#[deny(unsafe_code)]
pub mod metrics;
#[deny(unsafe_code)]
pub mod runtime;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
