//! Configuration system: a TOML-subset parser (no external crates are
//! available offline) plus typed launcher configs.
//!
//! Supported syntax: `[section]` headers, `key = value` pairs, `#`
//! comments, values of type string (`"..."`), integer, float and bool.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: `section.key → value` (top-level keys use section "").
#[derive(Debug, Default)]
pub struct Config {
    values: HashMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            values.insert(full, parse_value(val.trim(), lineno + 1)?);
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str().map(|s| s.to_string())).unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_int()).map(|i| i as usize).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .with_context(|| format!("line {lineno}: unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {lineno}: cannot parse value {s:?}")
}

/// Launcher-level configuration (CLI `--config engine.toml`).
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub model_preset: String,
    pub model_path: Option<String>,
    /// Kernel name (`I2_S`, `TL2_0`, …) or `auto` for profile-driven
    /// dispatch (requires [`LaunchConfig::tune_profile`]).
    pub kernel: String,
    /// Path to a `bitnet tune` JSON profile, consulted when `kernel` is
    /// `auto` (config key `model.tune_profile`, CLI `--tune-profile`).
    pub tune_profile: Option<String>,
    pub threads: usize,
    pub max_batch: usize,
    pub kv_budget_tokens: usize,
    /// KV-cache element type: `"f32"` (bit-exact default) or `"f16"`
    /// (half the resident KV bytes; config key `engine.kv_dtype`, CLI
    /// `--kv-dtype`).
    pub kv_dtype: String,
    pub seed: u64,
}

impl Default for LaunchConfig {
    fn default() -> Self {
        LaunchConfig {
            model_preset: "tiny".into(),
            model_path: None,
            kernel: "I2_S".into(),
            tune_profile: None,
            threads: 1,
            max_batch: 8,
            kv_budget_tokens: 8192,
            kv_dtype: "f32".into(),
            seed: 0,
        }
    }
}

impl LaunchConfig {
    pub fn from_config(cfg: &Config) -> LaunchConfig {
        let d = LaunchConfig::default();
        LaunchConfig {
            model_preset: cfg.get_str("model.preset", &d.model_preset),
            model_path: cfg.get("model.path").and_then(|v| v.as_str().map(|s| s.to_string())),
            kernel: cfg.get_str("model.kernel", &d.kernel),
            tune_profile: cfg
                .get("model.tune_profile")
                .and_then(|v| v.as_str().map(|s| s.to_string())),
            threads: cfg.get_usize("engine.threads", d.threads),
            max_batch: cfg.get_usize("engine.max_batch", d.max_batch),
            kv_budget_tokens: cfg.get_usize("engine.kv_budget_tokens", d.kv_budget_tokens),
            kv_dtype: cfg.get_str("engine.kv_dtype", &d.kv_dtype),
            seed: cfg.get_usize("engine.seed", d.seed as usize) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# engine config
[model]
preset = "3.8B"
kernel = "TL2_0"   # the headline kernel; or "auto" + tune_profile
tune_profile = "profile.json"

[engine]
threads = 8
max_batch = 16
kv_budget_tokens = 32768
temperature = 0.7
stream = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.get_str("model.preset", ""), "3.8B");
        assert_eq!(cfg.get_str("model.kernel", ""), "TL2_0");
        assert_eq!(cfg.get_usize("engine.threads", 0), 8);
        assert_eq!(cfg.get_f64("engine.temperature", 0.0), 0.7);
        assert!(cfg.get_bool("engine.stream", false));
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.get_usize("engine.threads", 4), 4);
        assert!(cfg.is_empty());
    }

    #[test]
    fn launch_config_mapping() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let lc = LaunchConfig::from_config(&cfg);
        assert_eq!(lc.model_preset, "3.8B");
        assert_eq!(lc.kernel, "TL2_0");
        assert_eq!(lc.max_batch, 16);
        assert_eq!(lc.kv_budget_tokens, 32768);
        assert_eq!(lc.kv_dtype, "f32", "kv_dtype defaults to the bit-exact f32");
        assert_eq!(lc.tune_profile.as_deref(), Some("profile.json"));
        assert_eq!(LaunchConfig::default().tune_profile, None);
    }

    #[test]
    fn comments_inside_strings_survive() {
        let cfg = Config::parse(r##"key = "a # not comment""##).unwrap();
        assert_eq!(cfg.get_str("key", ""), "a # not comment");
    }

    #[test]
    fn bad_syntax_errors() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = @@@").is_err());
    }
}
