//! NUMA topology discovery and placement policy.
//!
//! On multi-socket hosts the memory-bandwidth-bound mpGEMM only scales
//! if threads, weight slabs and KV pages are partitioned per NUMA node
//! instead of contending on one memory controller. This module is the
//! single source of truth for that partitioning:
//!
//! * [`Topology::detect`] reads `/sys/devices/system/node` (Linux) and
//!   falls back to a single node anywhere else;
//! * `RUST_PALLAS_NUMA_MOCK=N` synthesizes an `N`-node topology on any
//!   host, so placement logic and its tests run on single-socket CI
//!   boxes (mock topologies never pin threads);
//! * the mode is `--numa auto|off` on the CLI or `RUST_PALLAS_NUMA`
//!   in the environment (`off`/`0`/`false` disable placement); `off`
//!   always yields the single-node topology, which makes the NUMA-aware
//!   paths byte-for-byte the pre-NUMA code paths;
//! * [`Topology::row_ranges`] is the one row-partitioning rule shared
//!   by weight localization, `matmul_prepared` routing and the
//!   thread-pool's worker-to-node assignment, so "the node that owns
//!   the rows" means the same thing everywhere.
//!
//! Placement never changes *what* is accumulated — only where rows run
//! and which node's memory backs them — so results stay bit-identical
//! to `--numa off` by construction.

use std::ops::Range;
use std::sync::{Arc, OnceLock};

/// Whether NUMA-aware placement is enabled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumaMode {
    /// Use the detected (or mocked) topology; single-node hosts behave
    /// exactly as `Off`.
    Auto,
    /// Force the single-node topology: no pinning, no placement, no
    /// per-node queues.
    Off,
}

impl NumaMode {
    /// Parse a CLI/env value (`auto` | `off`; `0`/`false`/`no` also
    /// disable, matching the other `RUST_PALLAS_*` switches).
    pub fn parse(s: &str) -> Option<NumaMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "on" | "1" | "true" => Some(NumaMode::Auto),
            "off" | "0" | "false" | "no" => Some(NumaMode::Off),
            _ => None,
        }
    }
}

/// Process-wide mode override installed by the CLI (`--numa`), consulted
/// by [`resolved_mode`] ahead of the environment.
static MODE_OVERRIDE: OnceLock<NumaMode> = OnceLock::new();

/// Install the CLI's `--numa` choice. First caller wins (the shared pool
/// snapshots the topology when it is first built, so a later flip could
/// not take effect anyway); returns whether this call installed it.
pub fn set_mode(mode: NumaMode) -> bool {
    MODE_OVERRIDE.set(mode).is_ok()
}

/// The effective NUMA mode: CLI override if installed, else
/// `RUST_PALLAS_NUMA`, else `Auto`.
pub fn resolved_mode() -> NumaMode {
    if let Some(&m) = MODE_OVERRIDE.get() {
        return m;
    }
    match std::env::var("RUST_PALLAS_NUMA") {
        Ok(v) => NumaMode::parse(&v).unwrap_or(NumaMode::Auto),
        Err(_) => NumaMode::Auto,
    }
}

/// The host's NUMA layout (or a mock of one): which CPUs belong to each
/// node. Immutable once built; shared via `Arc` between the thread pool,
/// the KV arena and weight localization so they agree on ownership.
#[derive(Debug)]
pub struct Topology {
    /// CPU ids per node. Always at least one entry; single-node
    /// topologies may have an empty CPU list (nothing consults it).
    nodes: Vec<Vec<usize>>,
    /// True for `RUST_PALLAS_NUMA_MOCK` topologies: placement and
    /// counters behave as if multi-node, but threads are never pinned
    /// (the CPUs don't really form separate nodes).
    mocked: bool,
}

impl Topology {
    /// The trivial single-node topology (placement disabled).
    pub fn single() -> Arc<Topology> {
        Arc::new(Topology { nodes: vec![Vec::new()], mocked: false })
    }

    /// A synthetic `n`-node topology splitting the host's CPUs into `n`
    /// contiguous groups. Used by `RUST_PALLAS_NUMA_MOCK` and tests;
    /// never pins threads.
    pub fn mock(n: usize) -> Arc<Topology> {
        let n = n.max(1);
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let cores = cores.max(n);
        let nodes = (0..n)
            .map(|g| (g * cores / n..(g + 1) * cores / n).collect())
            .collect();
        Arc::new(Topology { nodes, mocked: true })
    }

    /// Detect the host topology under `mode`: `Off` is always single
    /// node; `RUST_PALLAS_NUMA_MOCK=N` (N ≥ 2) synthesizes N nodes;
    /// otherwise `/sys/devices/system/node/node*/cpulist` is parsed,
    /// falling back to a single node when absent or malformed.
    pub fn detect(mode: NumaMode) -> Arc<Topology> {
        if mode == NumaMode::Off {
            return Topology::single();
        }
        if let Ok(v) = std::env::var("RUST_PALLAS_NUMA_MOCK") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 2 {
                    return Topology::mock(n);
                }
            }
            return Topology::single();
        }
        match Topology::from_sysfs("/sys/devices/system/node") {
            Some(t) if t.nodes.len() >= 2 => Arc::new(t),
            _ => Topology::single(),
        }
    }

    /// Parse `node*/cpulist` entries under `root`. Returns `None` when
    /// the directory is missing or no node exposes any CPU.
    fn from_sysfs(root: &str) -> Option<Topology> {
        let mut ids: Vec<usize> = Vec::new();
        for entry in std::fs::read_dir(root).ok()? {
            let name = entry.ok()?.file_name();
            let name = name.to_str()?;
            if let Some(num) = name.strip_prefix("node") {
                if let Ok(id) = num.parse::<usize>() {
                    ids.push(id);
                }
            }
        }
        ids.sort_unstable();
        let mut nodes = Vec::new();
        for id in ids {
            let list = std::fs::read_to_string(format!("{root}/node{id}/cpulist")).ok()?;
            let cpus = parse_cpulist(&list);
            // CPU-less nodes (e.g. CXL memory-only) can't run workers;
            // skip them rather than assigning them empty worker groups.
            if !cpus.is_empty() {
                nodes.push(cpus);
            }
        }
        if nodes.is_empty() {
            return None;
        }
        Some(Topology { nodes, mocked: false })
    }

    /// Number of NUMA nodes (≥ 1).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Whether this topology came from `RUST_PALLAS_NUMA_MOCK` /
    /// [`Topology::mock`] (placement runs, pinning doesn't).
    pub fn is_mocked(&self) -> bool {
        self.mocked
    }

    /// CPU ids of `node` (empty for the trivial single-node topology).
    pub fn cpus(&self, node: usize) -> &[usize] {
        &self.nodes[node]
    }

    /// Partition `0..m` into one contiguous range per node — the single
    /// row-ownership rule shared by weight localization,
    /// `matmul_prepared` routing and worker assignment. Ranges are
    /// balanced to within one row; when `m < n_nodes` the tail ranges
    /// are empty.
    pub fn row_ranges(&self, m: usize) -> Vec<Range<usize>> {
        let n = self.nodes.len();
        (0..n).map(|g| g * m / n..(g + 1) * m / n).collect()
    }

    /// The node owning `row` under [`Topology::row_ranges`]`(m)`.
    pub fn node_of_row(&self, row: usize, m: usize) -> usize {
        debug_assert!(row < m);
        let n = self.nodes.len();
        if m == 0 {
            return 0;
        }
        // Inverse of `start = g*m/n`: the last g with g*m/n <= row.
        let g = ((row + 1) * n - 1) / m.max(1);
        g.min(n - 1)
    }
}

/// Parse a sysfs cpulist like `0-3,8,10-11` into CPU ids.
fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                for c in a..=b {
                    out.push(c);
                }
            }
        } else if let Ok(c) = part.parse::<usize>() {
            out.push(c);
        }
    }
    out
}

/// Best-effort: restrict the calling thread to `cpus` so its first-touch
/// allocations land on the owning node. Raw `sched_setaffinity` syscall
/// (no libc in the offline build); returns whether the kernel accepted
/// the mask. No-op (false) on non-Linux targets, empty masks and CPUs
/// above 1023.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    let mut mask = [0u64; 16]; // 1024-CPU mask, zeroed
    let mut any = false;
    for &c in cpus {
        if c < 1024 {
            mask[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    let size = core::mem::size_of_val(&mask);
    let ret: isize;
    #[cfg(target_arch = "x86_64")]
    // SAFETY: sched_setaffinity(pid=0, cpusetsize, mask*) only *reads*
    // `size` bytes from `mask`, which is a live, properly-sized stack
    // buffer for the whole syscall; pid 0 targets the calling thread, so
    // no other process state is touched. rcx/r11 are declared clobbered
    // as the syscall ABI requires.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,
            in("rsi") size,
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack, preserves_flags, readonly),
        );
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: same contract as the x86_64 arm — the kernel reads `size`
    // bytes from the live `mask` buffer and alters only this thread's
    // affinity (pid 0 = caller).
    unsafe {
        let mut x0: isize = 0; // pid 0 = calling thread
        core::arch::asm!(
            "svc 0",
            in("x8") 122usize, // __NR_sched_setaffinity
            inlateout("x0") x0,
            in("x1") size,
            in("x2") mask.as_ptr(),
            options(nostack, preserves_flags, readonly),
        );
        ret = x0;
    }
    ret == 0
}

/// Non-Linux / exotic-arch fallback: affinity is a locality hint, not a
/// correctness requirement, so silently do nothing.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn pin_current_thread(_cpus: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_cpulist_forms() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0,2,4"), vec![0, 2, 4]);
        assert_eq!(parse_cpulist("0-1,8-9\n"), vec![0, 1, 8, 9]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
    }

    #[test]
    fn mode_parse() {
        assert_eq!(NumaMode::parse("auto"), Some(NumaMode::Auto));
        assert_eq!(NumaMode::parse("OFF"), Some(NumaMode::Off));
        assert_eq!(NumaMode::parse("0"), Some(NumaMode::Off));
        assert_eq!(NumaMode::parse("bogus"), None);
    }

    #[test]
    fn single_topology_is_one_node() {
        let t = Topology::single();
        assert_eq!(t.n_nodes(), 1);
        assert!(!t.is_mocked());
        assert_eq!(t.row_ranges(10), vec![0..10]);
        assert_eq!(t.node_of_row(9, 10), 0);
    }

    #[test]
    fn mock_topology_partitions_rows() {
        let t = Topology::mock(2);
        assert_eq!(t.n_nodes(), 2);
        assert!(t.is_mocked());
        let r = t.row_ranges(10);
        assert_eq!(r, vec![0..5, 5..10]);
        // Ranges tile 0..m and node_of_row inverts them.
        for m in [1usize, 2, 3, 7, 10, 64, 1000] {
            let ranges = t.row_ranges(m);
            assert_eq!(ranges.first().unwrap().start, 0);
            assert_eq!(ranges.last().unwrap().end, m);
            for g in 1..ranges.len() {
                assert_eq!(ranges[g].start, ranges[g - 1].end);
            }
            for row in 0..m {
                let g = t.node_of_row(row, m);
                assert!(ranges[g].contains(&row), "row {row} of {m} -> node {g}");
            }
        }
    }

    #[test]
    fn mock_rounds_node_count_up_to_one() {
        assert_eq!(Topology::mock(0).n_nodes(), 1);
    }

    #[test]
    fn row_ranges_with_fewer_rows_than_nodes() {
        let t = Topology::mock(4);
        let r = t.row_ranges(2);
        assert_eq!(r.iter().map(|x| x.len()).sum::<usize>(), 2);
        for row in 0..2 {
            let g = t.node_of_row(row, 2);
            assert!(r[g].contains(&row));
        }
    }
}
