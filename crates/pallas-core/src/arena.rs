//! Paged KV-cache arena (vLLM-style block allocator that **owns the
//! bytes**).
//!
//! The arena divides the engine's KV budget into fixed-size pages of
//! [`PAGE_TOKENS`] tokens and backs them with real storage: one K slab and
//! one V slab per transformer layer, page-granular, in
//! [`KvDtype::F32`] (bit-exact with the pre-paged contiguous layout) or
//! [`KvDtype::F16`] (half the resident bytes, `--kv-dtype f16`). A page id
//! addresses the same page-sized region in every layer's slabs, so a
//! sequence needs exactly one page table however deep the model is.
//!
//! Memory is **lazy**: slabs grow only when a page id is minted for the
//! first time, so resident bytes track the *peak pages actually used*,
//! not the worst-case budget. Freed pages are recycled before new ones
//! are minted (continuous batching keeps the footprint near the working
//! set).
//!
//! Each page is its own allocation, so with NUMA placement installed
//! ([`KvArena::set_placement`]) minting zeroes — first-touches — a
//! page's bytes from a thread pinned to the owning node
//! (`page % n_nodes`), and [`KvArena::resident_bytes_by_node`] reports
//! where the working set actually lives. Placement only moves bytes
//! between memory controllers; reads, writes and COW copies are
//! bit-identical with or without it.
//!
//! Pages are **refcounted with copy-on-write semantics**: several page
//! tables (and the prompt index below) can map the same physical page,
//! release decrements, and only the last referent returns the page to the
//! free list. A write into a page mapped more than once first splits it —
//! allocates a private page and copies the K/V bytes across every layer —
//! so shared history is never clobbered ([`KvArena::reserve_for_write`]
//! does this eagerly at admission; [`KvArena::append`] keeps a lazy
//! safety net).
//!
//! On top of COW sits a **radix prompt index**: a page-granular trie over
//! token-id chunks ([`KvArena::register_prefix`] inserts a finished
//! prompt's full pages, [`KvArena::map_prefix`] maps the longest indexed
//! prefix of a new prompt into a fresh sequence's table, sharing the
//! pages instead of re-prefilling them). Index-held pages are evicted
//! LRU-leaf-first when an allocation would otherwise fail, so the index
//! is a cache, not a leak: admission always wins over retained prefixes.
//!
//! The arena sits below both the model layer (`pallas_model::Session`
//! appends and attends through it) and the serving scheduler
//! (`pallas_serve::coordinator::scheduler::Scheduler`), which uses it as
//! the admission-control ledger: `reserve`/`release` move
//! pages between the free list and per-sequence page tables, and
//! preemptions (watermark admission ran out of room mid-decode) are
//! counted here for the engine metrics.

use crate::simd::ops;
use crate::threadpool::ThreadPool;
use crate::util::f16::f16_to_f32_fast;
use crate::util::{ceil_div, f32_to_f16};
use std::collections::HashMap;
use std::sync::Arc;

/// Tokens per KV page.
pub const PAGE_TOKENS: usize = 16;

/// Element type a KV page stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvDtype {
    /// 4 bytes/element; bit-exact with the pre-paged contiguous cache.
    F32,
    /// 2 bytes/element; K/V rows round-trip through IEEE binary16 on
    /// append (half the resident bytes, small perplexity cost).
    F16,
}

impl KvDtype {
    /// Parse a CLI/config value (`f32` | `f16`, case-insensitive).
    pub fn parse(s: &str) -> Option<KvDtype> {
        if s.eq_ignore_ascii_case("f32") {
            Some(KvDtype::F32)
        } else if s.eq_ignore_ascii_case("f16") {
            Some(KvDtype::F16)
        } else {
            None
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
        }
    }

    pub fn elem_bytes(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
        }
    }
}

/// The backing storage of one physical page in one layer's K (or V)
/// slab. Each page is its own allocation (rather than a region of one
/// big `Vec`) so minting can zero — and therefore first-touch — the
/// bytes from a thread pinned to the NUMA node that owns the page; every
/// access is page-local, so the split costs nothing on the read path.
#[derive(Clone)]
enum PageStore {
    F32(Vec<f32>),
    F16(Vec<u16>),
}

impl PageStore {
    /// Allocate and zero a page's elements. The zeroing pass is the
    /// first touch: run it on the owning node's thread and the kernel
    /// backs the page with that node's memory.
    fn zeroed(dtype: KvDtype, elems: usize) -> PageStore {
        match dtype {
            KvDtype::F32 => PageStore::F32(vec![0.0; elems]),
            KvDtype::F16 => PageStore::F16(vec![0; elems]),
        }
    }

    fn byte_len(&self) -> usize {
        match self {
            PageStore::F32(v) => v.len() * 4,
            PageStore::F16(v) => v.len() * 2,
        }
    }
}

/// One layer's K (or V) storage: page-granular, grown lazily as pages are
/// minted. `pages[p]` backs physical page id `p`.
struct Slab {
    pages: Vec<PageStore>,
}

impl Slab {
    fn new() -> Slab {
        Slab { pages: Vec::new() }
    }

    fn byte_len(&self) -> usize {
        self.pages.iter().map(PageStore::byte_len).sum()
    }

    /// Write one row at element offset `off` inside `page`.
    fn write_row(&mut self, page: u32, off: usize, row: &[f32]) {
        match &mut self.pages[page as usize] {
            PageStore::F32(v) => v[off..off + row.len()].copy_from_slice(row),
            PageStore::F16(v) => {
                for (dst, &src) in v[off..off + row.len()].iter_mut().zip(row.iter()) {
                    *dst = f32_to_f16(src);
                }
            }
        }
    }

    /// Raw copy of one page's elements (COW split): bit-exact for both
    /// dtypes — f16 pages copy their stored binary16 words, no re-round.
    /// Copies element-wise into `dst`'s existing allocation, so the
    /// destination page keeps its first-touch placement.
    fn copy_page(&mut self, src: u32, dst: u32) {
        let (s, d) = (src as usize, dst as usize);
        if s == d {
            return;
        }
        let (head, tail) = self.pages.split_at_mut(s.max(d));
        let (src_p, dst_p) = if s < d { (&head[s], &mut tail[0]) } else { (&tail[0], &mut head[d]) };
        match (src_p, dst_p) {
            (PageStore::F32(a), PageStore::F32(b)) => b.copy_from_slice(a),
            (PageStore::F16(a), PageStore::F16(b)) => b.copy_from_slice(a),
            _ => unreachable!("slab pages share one dtype"),
        }
    }

    /// One row of `page` decoded to f32 (debug/test accessor — the hot
    /// path reads page elements in place via the fused attend loops).
    fn row_f32(&self, page: u32, off: usize, row_elems: usize) -> Vec<f32> {
        match &self.pages[page as usize] {
            PageStore::F32(v) => v[off..off + row_elems].to_vec(),
            PageStore::F16(v) => {
                v[off..off + row_elems].iter().map(|&b| f16_to_f32_fast(b)).collect()
            }
        }
    }
}

/// Reusable attention workspace: the per-call score buffer plus the
/// counters the allocation-free steady-state test reads. One per
/// session — sized by the largest `n_heads * ctx_len` seen, so it stops
/// allocating once the context stops growing past previous peaks.
#[derive(Debug, Default)]
pub struct AttnWorkspace {
    scores: Vec<f32>,
    allocs: u64,
    reuses: u64,
}

impl AttnWorkspace {
    pub fn new() -> AttnWorkspace {
        AttnWorkspace::default()
    }

    /// Times the score buffer had to grow (a heap allocation).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// Times existing capacity was reused (steady-state calls).
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    /// A zeroed `n`-element score buffer, growing only on capacity miss.
    /// Growth takes power-of-two headroom: decode lengthens the context
    /// one token per step, so sizing to exactly `n` would re-allocate on
    /// every step instead of O(log) times over a generation.
    fn ensure(&mut self, n: usize) -> &mut [f32] {
        if self.scores.capacity() < n {
            self.allocs += 1;
            self.scores = vec![0f32; n.next_power_of_two()];
        } else {
            self.reuses += 1;
        }
        self.scores.clear();
        self.scores.resize(n, 0.0);
        &mut self.scores[..n]
    }
}

/// One node of the radix prompt index: a full page's worth of token ids
/// (`key`) plus the physical page holding their K/V rows. The node holds
/// one refcount on `page` for as long as it is live.
struct TrieNode {
    key: Vec<u32>,
    page: u32,
    parent: usize,
    children: Vec<usize>,
    /// Logical LRU clock value of the last lookup/insert touching this
    /// node (no wall clock: deterministic under test).
    touch: u64,
    live: bool,
}

/// Page-granular trie over prompt token ids. Node 0 is the root (no key,
/// no page, never evicted); nodes are slab-allocated with slot reuse.
struct PrefixIndex {
    nodes: Vec<TrieNode>,
    free_slots: Vec<usize>,
    clock: u64,
}

impl PrefixIndex {
    fn new() -> PrefixIndex {
        PrefixIndex {
            nodes: vec![TrieNode {
                key: Vec::new(),
                page: u32::MAX,
                parent: 0,
                children: Vec::new(),
                touch: 0,
                live: true,
            }],
            free_slots: Vec::new(),
            clock: 0,
        }
    }

    /// The child of `node` whose key matches `chunk`, if indexed.
    fn child_matching(&self, node: usize, chunk: &[u32]) -> Option<usize> {
        self.nodes[node].children.iter().copied().find(|&c| self.nodes[c].key.as_slice() == chunk)
    }

    fn alloc_node(&mut self, node: TrieNode) -> usize {
        if let Some(slot) = self.free_slots.pop() {
            self.nodes[slot] = node;
            slot
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    /// Live nodes (== pages the index holds a refcount on).
    fn live_nodes(&self) -> usize {
        self.nodes.iter().skip(1).filter(|n| n.live).count()
    }
}

/// Page-granular KV arena: budget ledger + refcounted page tables +
/// prompt index + backing slabs.
pub struct KvArena {
    n_layers: usize,
    kv_dim: usize,
    dtype: KvDtype,
    page_tokens: usize,
    total_pages: usize,
    /// Recycled page ids (refcount reached zero before `next_page`
    /// reached the cap). Popped before minting, so balanced churn never
    /// grows the slabs.
    free_pages: Vec<u32>,
    /// Page ids minted so far == pages of slab storage actually resident.
    next_page: u32,
    /// seq id → page table (the indirection attention reads through).
    /// Entries may alias across tables (shared prefixes) — `refcounts`
    /// tracks how many referents each physical page has.
    tables: HashMap<u64, Vec<u32>>,
    /// Referents per minted page id: one per page-table entry mapping it
    /// plus one per live trie node holding it. Zero ⇔ on the free list.
    refcounts: Vec<u32>,
    prefix: PrefixIndex,
    peak_used: usize,
    preemptions: u64,
    prefix_hit_tokens: u64,
    cow_splits: u64,
    k_slabs: Vec<Slab>,
    v_slabs: Vec<Slab>,
    /// NUMA placement: when set (multi-node pool), page `p`'s slabs are
    /// zeroed — first-touched — on node `p % n_nodes` via
    /// [`ThreadPool::run_on_node`].
    placement: Option<Arc<ThreadPool>>,
    /// Bytes of slab storage minted on each node (single entry when no
    /// placement is installed).
    node_resident: Vec<usize>,
}

impl KvArena {
    /// Arena sized for `max_tokens` total KV tokens across all sequences.
    /// The page count rounds *up*: flooring would silently discard up to
    /// `PAGE_TOKENS - 1` tokens of budget the caller paid for (e.g. a
    /// 100-token budget serving only 96), so the invariant is
    /// `total_pages * PAGE_TOKENS >= max_tokens`. No slab memory is
    /// allocated here — pages mint lazily on first reserve.
    pub fn new(n_layers: usize, kv_dim: usize, max_tokens: usize, dtype: KvDtype) -> KvArena {
        Self::with_page_tokens(n_layers, kv_dim, max_tokens, dtype, PAGE_TOKENS)
    }

    /// [`KvArena::new`] with an explicit page size (tests: `page_tokens`
    /// larger than every sequence degenerates to the contiguous layout,
    /// the bit-identity reference).
    pub fn with_page_tokens(
        n_layers: usize,
        kv_dim: usize,
        max_tokens: usize,
        dtype: KvDtype,
        page_tokens: usize,
    ) -> KvArena {
        assert!(page_tokens > 0, "page size must be positive");
        KvArena {
            n_layers,
            kv_dim,
            dtype,
            page_tokens,
            total_pages: ceil_div(max_tokens, page_tokens),
            free_pages: Vec::new(),
            next_page: 0,
            tables: HashMap::new(),
            refcounts: Vec::new(),
            prefix: PrefixIndex::new(),
            peak_used: 0,
            preemptions: 0,
            prefix_hit_tokens: 0,
            cow_splits: 0,
            k_slabs: (0..n_layers).map(|_| Slab::new()).collect(),
            v_slabs: (0..n_layers).map(|_| Slab::new()).collect(),
            placement: None,
            node_resident: vec![0],
        }
    }

    /// Install NUMA placement: pages minted from now on are interleaved
    /// across `pool`'s nodes (`page % n_nodes`) and their slabs zeroed on
    /// the owning node, so each node's attention reads hit local memory.
    /// Call before the first reservation (already-minted pages keep
    /// whatever placement they got). No-op storage-wise on single-node
    /// pools — the arena stays bit-identical either way; placement only
    /// moves where page bytes live.
    pub fn set_placement(&mut self, pool: Arc<ThreadPool>) {
        self.node_resident = vec![0; pool.n_nodes().max(1)];
        if pool.n_nodes() > 1 {
            self.placement = Some(pool);
        } else {
            self.placement = None;
        }
    }

    /// Bytes of slab storage minted on each NUMA node (one entry when no
    /// multi-node placement is installed). Sums to
    /// [`KvArena::resident_bytes`].
    pub fn resident_bytes_by_node(&self) -> &[usize] {
        &self.node_resident
    }

    /// A zero-layer arena: pure page accounting, no backing bytes
    /// (scheduler unit tests and page-math property tests).
    pub fn accounting(max_tokens: usize) -> KvArena {
        Self::new(0, 0, max_tokens, KvDtype::F32)
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Pages still allocatable (recycled free-list entries plus pages the
    /// budget allows but that were never minted). Index-held pages are
    /// *not* free here — they become reclaimable through eviction when an
    /// allocation actually needs them (see [`KvArena::reserve`]).
    pub fn free_page_count(&self) -> usize {
        self.total_pages - self.used_pages()
    }

    /// Pages currently held by at least one referent (sequence tables
    /// and/or the prompt index).
    pub fn used_pages(&self) -> usize {
        self.next_page as usize - self.free_pages.len()
    }

    pub fn peak_used_pages(&self) -> usize {
        self.peak_used
    }

    /// Sequences preempted because a growth reservation found the arena
    /// exhausted (see `pallas_serve::coordinator::scheduler::Scheduler::step`).
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Count one preemption (called by the scheduler when it evicts).
    pub fn note_preemption(&mut self) {
        self.preemptions += 1;
    }

    /// Cumulative prompt tokens served out of the prefix index instead of
    /// being re-prefilled ([`KvArena::map_prefix`] hits).
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hit_tokens
    }

    /// Cumulative copy-on-write page splits (writes into shared pages).
    pub fn cow_splits(&self) -> u64 {
        self.cow_splits
    }

    /// Pages currently held by the prompt index (one per live trie node).
    pub fn prefix_index_pages(&self) -> usize {
        self.prefix.live_nodes()
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        ceil_div(tokens, self.page_tokens)
    }

    /// Can a sequence with this token demand be granted pages right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free_page_count()
    }

    /// Bytes of slab storage actually resident (minted pages only —
    /// grows to the peak working set, never to the unused budget).
    pub fn resident_bytes(&self) -> usize {
        self.k_slabs.iter().chain(self.v_slabs.iter()).map(Slab::byte_len).sum()
    }

    /// Bytes the full page budget would occupy if every page were minted.
    pub fn capacity_bytes(&self) -> usize {
        self.total_pages * self.page_bytes()
    }

    /// Bytes one page occupies across all layers (K and V).
    fn page_bytes(&self) -> usize {
        self.page_tokens * self.kv_dim * self.dtype.elem_bytes() * 2 * self.n_layers
    }

    /// Reserve pages for `seq` to cover `tokens` tokens total (idempotent
    /// growth: only the delta beyond current holdings is allocated).
    /// Returns false (no change) if the arena cannot satisfy the demand
    /// even after evicting index-only pages.
    pub fn reserve(&mut self, seq: u64, tokens: usize) -> bool {
        let want = self.pages_for(tokens);
        let have = self.tables.get(&seq).map_or(0, |v| v.len());
        if want <= have {
            return true;
        }
        let need = want - have;
        if !self.ensure_free(need) {
            return false;
        }
        let mut minted = Vec::with_capacity(need);
        for _ in 0..need {
            minted.push(self.alloc_page().expect("ensure_free checked above"));
        }
        self.tables.entry(seq).or_default().extend(minted);
        self.peak_used = self.peak_used.max(self.used_pages());
        true
    }

    /// [`KvArena::reserve`] plus eager copy-on-write: after covering
    /// `tokens`, every shared page overlapping the write range
    /// `write_from..tokens` is split to a private copy, so the upcoming
    /// prefill chunk / decode append can write without clobbering other
    /// referents. Atomic like `reserve`: fails without side effects when
    /// growth + splits can't all be satisfied.
    pub fn reserve_for_write(&mut self, seq: u64, tokens: usize, write_from: usize) -> bool {
        let want = self.pages_for(tokens);
        let have = self.tables.get(&seq).map_or(0, |v| v.len());
        let grow = want.saturating_sub(have);
        let mut splits = 0usize;
        if tokens > write_from {
            if let Some(table) = self.tables.get(&seq) {
                let first = write_from / self.page_tokens;
                let last = (tokens - 1) / self.page_tokens;
                for pi in first..=last.min(table.len().saturating_sub(1)) {
                    if self.refcounts[table[pi] as usize] > 1 {
                        splits += 1;
                    }
                }
            }
        }
        if !self.ensure_free(grow + splits) {
            return false;
        }
        for _ in 0..grow {
            let p = self.alloc_page().expect("ensure_free checked above");
            self.tables.entry(seq).or_default().push(p);
        }
        if tokens > write_from && self.tables.contains_key(&seq) {
            let first = write_from / self.page_tokens;
            let last = (tokens - 1) / self.page_tokens;
            for pi in first..=last {
                self.split_if_shared(seq, pi);
            }
        }
        self.peak_used = self.peak_used.max(self.used_pages());
        true
    }

    /// Map the longest indexed prefix of `prompt` into `seq`'s (empty)
    /// page table, sharing the physical pages (refcount++), and return
    /// how many prompt tokens are now cache-resident. Capped at
    /// `prompt.len() - 1` so at least one tail token is always prefilled
    /// (the engine needs the final position's logits — and an identical
    /// prompt resubmission therefore exercises a genuine COW split).
    /// Mapping never allocates, so it cannot fail.
    pub fn map_prefix(&mut self, seq: u64, prompt: &[u32]) -> usize {
        if prompt.len() <= 1 {
            return 0;
        }
        self.prefix.clock += 1;
        let clock = self.prefix.clock;
        let mut node = 0usize;
        let mut matched: Vec<u32> = Vec::new();
        for chunk in prompt.chunks_exact(self.page_tokens) {
            let Some(child) = self.prefix.child_matching(node, chunk) else { break };
            self.prefix.nodes[child].touch = clock;
            matched.push(self.prefix.nodes[child].page);
            node = child;
        }
        if matched.is_empty() {
            return 0;
        }
        let shared = (matched.len() * self.page_tokens).min(prompt.len() - 1);
        let need_pages = ceil_div(shared, self.page_tokens);
        let table = self.tables.entry(seq).or_default();
        debug_assert!(table.is_empty(), "map_prefix must run before any reservation for seq");
        for &p in &matched[..need_pages] {
            self.refcounts[p as usize] += 1;
            table.push(p);
        }
        self.prefix_hit_tokens += shared as u64;
        shared
    }

    /// Index `seq`'s prefilled prompt: insert one trie node per *full*
    /// page of `prompt` (partial tail pages keep being written by decode
    /// and are never shared), deduplicating against existing nodes. Each
    /// newly inserted node takes a refcount on the sequence's page, so
    /// the prefix outlives the sequence.
    pub fn register_prefix(&mut self, seq: u64, prompt: &[u32]) {
        let Some(table) = self.tables.get(&seq).cloned() else { return };
        self.prefix.clock += 1;
        let clock = self.prefix.clock;
        let mut node = 0usize;
        for (pi, chunk) in prompt.chunks_exact(self.page_tokens).enumerate() {
            if pi >= table.len() {
                break;
            }
            node = match self.prefix.child_matching(node, chunk) {
                Some(c) => {
                    self.prefix.nodes[c].touch = clock;
                    c
                }
                None => {
                    let page = table[pi];
                    self.refcounts[page as usize] += 1;
                    let fresh = self.prefix.alloc_node(TrieNode {
                        key: chunk.to_vec(),
                        page,
                        parent: node,
                        children: Vec::new(),
                        touch: clock,
                        live: true,
                    });
                    self.prefix.nodes[node].children.push(fresh);
                    fresh
                }
            };
        }
    }

    /// Free pages until `need` are allocatable, evicting LRU index-only
    /// leaves (refcount 1 ⇒ no live sequence maps the page). Interior
    /// nodes become leaves as their children go, so whole stale branches
    /// drain back-to-front. False ⇔ demand exceeds what eviction can
    /// reclaim.
    fn ensure_free(&mut self, need: usize) -> bool {
        while self.free_page_count() < need {
            if !self.evict_prefix_leaf() {
                return false;
            }
        }
        true
    }

    /// Evict the least-recently-touched index leaf whose page has no
    /// other referent, returning its page to the free list.
    fn evict_prefix_leaf(&mut self) -> bool {
        let mut best: Option<(usize, u64)> = None;
        for (i, n) in self.prefix.nodes.iter().enumerate().skip(1) {
            if !n.live || !n.children.is_empty() || self.refcounts[n.page as usize] != 1 {
                continue;
            }
            let older = match best {
                Some((_, t)) => n.touch < t,
                None => true,
            };
            if older {
                best = Some((i, n.touch));
            }
        }
        let Some((i, _)) = best else { return false };
        let parent = self.prefix.nodes[i].parent;
        let page = self.prefix.nodes[i].page;
        self.prefix.nodes[parent].children.retain(|&c| c != i);
        self.prefix.nodes[i].live = false;
        self.prefix.nodes[i].key = Vec::new();
        self.prefix.free_slots.push(i);
        self.dec_ref(page);
        true
    }

    fn alloc_page(&mut self) -> Option<u32> {
        if let Some(p) = self.free_pages.pop() {
            self.refcounts[p as usize] = 1;
            return Some(p);
        }
        if (self.next_page as usize) < self.total_pages {
            let p = self.next_page;
            self.next_page += 1;
            self.refcounts.push(1);
            self.mint_page_storage(p);
            Some(p)
        } else {
            None
        }
    }

    /// Allocate (and zero) page `p`'s backing stores across every layer's
    /// K and V slab. With placement installed, the zeroing runs on the
    /// owning node's thread so first-touch lands the bytes there.
    fn mint_page_storage(&mut self, p: u32) {
        let elems = self.page_tokens * self.kv_dim;
        let n_stores = 2 * self.n_layers;
        let dtype = self.dtype;
        let page_bytes = self.page_bytes();
        let mut fresh: Vec<PageStore> = Vec::with_capacity(n_stores);
        let node = match &self.placement {
            Some(pool) => {
                let node = p as usize % pool.n_nodes();
                pool.run_on_node(node, || {
                    for _ in 0..n_stores {
                        fresh.push(PageStore::zeroed(dtype, elems));
                    }
                });
                node
            }
            None => {
                for _ in 0..n_stores {
                    fresh.push(PageStore::zeroed(dtype, elems));
                }
                0
            }
        };
        if let Some(r) = self.node_resident.get_mut(node) {
            *r += page_bytes;
        }
        let mut it = fresh.into_iter();
        for slab in self.k_slabs.iter_mut().chain(self.v_slabs.iter_mut()) {
            slab.pages.push(it.next().expect("minted 2*n_layers stores"));
        }
    }

    /// Drop one referent of `page`; the last referent returns it to the
    /// free list (the slab memory stays minted for reuse).
    fn dec_ref(&mut self, page: u32) {
        let rc = &mut self.refcounts[page as usize];
        debug_assert!(*rc > 0, "double free of page {page}");
        *rc -= 1;
        if *rc == 0 {
            self.free_pages.push(page);
        }
    }

    /// If `seq`'s `pi`-th page is shared, split it: allocate a private
    /// page, copy the K/V bytes across every layer, and swap the table
    /// entry. The caller must have ensured a page is allocatable.
    fn split_if_shared(&mut self, seq: u64, pi: usize) {
        let old = self.tables[&seq][pi];
        if self.refcounts[old as usize] <= 1 {
            return;
        }
        let fresh = self.alloc_page().expect("caller reserves headroom for COW splits");
        for slab in self.k_slabs.iter_mut().chain(self.v_slabs.iter_mut()) {
            slab.copy_page(old, fresh);
        }
        self.refcounts[old as usize] -= 1;
        self.tables.get_mut(&seq).expect("table exists")[pi] = fresh;
        self.cow_splits += 1;
    }

    /// Release all pages held by `seq` (finish or preemption): each
    /// mapping drops one refcount; pages shared with other sequences or
    /// the prompt index stay live.
    pub fn release(&mut self, seq: u64) {
        if let Some(pages) = self.tables.remove(&seq) {
            for p in pages {
                self.dec_ref(p);
            }
        }
    }

    /// Pages held by `seq`.
    pub fn held_pages(&self, seq: u64) -> usize {
        self.tables.get(&seq).map_or(0, |v| v.len())
    }

    /// Bytes of KV storage backing `seq`'s held pages — what the
    /// sequence actually occupies, not its worst-case reservation.
    pub fn held_bytes(&self, seq: u64) -> usize {
        self.held_pages(seq) * self.page_bytes()
    }

    /// Write the K and V rows for token position `pos` of `seq` in
    /// `layer`. The covering page must already be reserved. Writes into a
    /// shared page split it first (lazy COW safety net — the serving
    /// scheduler splits eagerly via [`KvArena::reserve_for_write`], so
    /// this path allocating is the exception, not the rule).
    pub fn append(&mut self, seq: u64, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.kv_dim);
        debug_assert_eq!(v.len(), self.kv_dim);
        let page = self.page_of(seq, pos);
        if self.refcounts[page as usize] > 1 {
            assert!(self.ensure_free(1), "KV arena exhausted during COW split at pos {pos}");
            self.split_if_shared(seq, pos / self.page_tokens);
        }
        let page = self.page_of(seq, pos);
        let off = (pos % self.page_tokens) * self.kv_dim;
        self.k_slabs[layer].write_row(page, off, k);
        self.v_slabs[layer].write_row(page, off, v);
    }

    fn page_of(&self, seq: u64, pos: usize) -> u32 {
        let table = self.tables.get(&seq).expect("reserve pages before append/attend");
        *table.get(pos / self.page_tokens).unwrap_or_else(|| {
            panic!("KV arena: pos {pos} beyond {} reserved pages", table.len())
        })
    }

    /// K/V row for `pos` of `seq` in `layer`, decoded to f32 (debug/test
    /// accessor — the hot path reads page elements in place via
    /// [`KvArena::attend_with`]).
    pub fn kv_row(&self, seq: u64, layer: usize, pos: usize) -> (Vec<f32>, Vec<f32>) {
        let page = self.page_of(seq, pos);
        let off = (pos % self.page_tokens) * self.kv_dim;
        (
            self.k_slabs[layer].row_f32(page, off, self.kv_dim),
            self.v_slabs[layer].row_f32(page, off, self.kv_dim),
        )
    }

    /// [`KvArena::attend_with`] with a throwaway workspace and no pool —
    /// the convenience entry point for tests and one-off callers. Hot
    /// paths (`pallas_model::Session`) hold a persistent
    /// [`AttnWorkspace`] instead so steady-state decode never allocates.
    #[allow(clippy::too_many_arguments)]
    pub fn attend(
        &self,
        seq: u64,
        layer: usize,
        q: &[f32],
        ctx_len: usize,
        n_heads: usize,
        n_kv_heads: usize,
        head_dim: usize,
        scale: f32,
        out: &mut [f32],
    ) {
        let mut ws = AttnWorkspace::new();
        self.attend_with(
            &mut ws, seq, layer, q, ctx_len, n_heads, n_kv_heads, head_dim, scale, out, None,
        );
    }

    /// Scaled-dot-product attention for one query row against `seq`'s
    /// cache in `layer`: context positions `0..ctx_len`, grouped-query
    /// heads, accumulated into `out` (assumed zeroed, `n_heads *
    /// head_dim`).
    ///
    /// The gather is tiled per page so the inner loops always run over
    /// contiguous in-page slices; f16 pages decode **inside** the SIMD
    /// dot/axpy loops ([`crate::simd::ops`]) — no scratch
    /// materialization. Score and output element values are independent
    /// of head order and of whether a pool is passed, and every reduction
    /// uses the shared lane-blocked order, so results are bit-identical
    /// across scalar/AVX2/NEON tiers, across thread counts, and across
    /// page sizes (paged ≡ contiguous). The read is pure page-table
    /// indirection, so shared (COW) pages read identically to private
    /// ones.
    ///
    /// `ws` supplies the score buffer (allocation-free once warm); with
    /// `pool` set, heads run in parallel on the shared NUMA-placed pool.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_with(
        &self,
        ws: &mut AttnWorkspace,
        seq: u64,
        layer: usize,
        q: &[f32],
        ctx_len: usize,
        n_heads: usize,
        n_kv_heads: usize,
        head_dim: usize,
        scale: f32,
        out: &mut [f32],
        pool: Option<&ThreadPool>,
    ) {
        if ctx_len == 0 {
            return;
        }
        let kvd = self.kv_dim;
        let group = n_heads / n_kv_heads;
        let table = self.tables.get(&seq).expect("reserve pages before append/attend");
        let covered = table.len() * self.page_tokens;
        assert!(covered >= ctx_len, "attend: page table covers {covered} of {ctx_len} context tokens");
        let page_tokens = self.page_tokens;
        let k_slab = &self.k_slabs[layer];
        let v_slab = &self.v_slabs[layer];
        let scores = ws.ensure(n_heads * ctx_len);
        let per_head = |head: usize, srow: &mut [f32], orow: &mut [f32]| {
            let col = (head / group) * head_dim;
            let qh = &q[head * head_dim..(head + 1) * head_dim];
            let mut t0 = 0usize;
            for &page in table.iter() {
                if t0 >= ctx_len {
                    break;
                }
                let tn = page_tokens.min(ctx_len - t0);
                match &k_slab.pages[page as usize] {
                    PageStore::F32(kp) => {
                        for t in 0..tn {
                            let kt = &kp[t * kvd + col..t * kvd + col + head_dim];
                            srow[t0 + t] = ops::dot_f32(qh, kt) * scale;
                        }
                    }
                    PageStore::F16(kp) => {
                        for t in 0..tn {
                            let kt = &kp[t * kvd + col..t * kvd + col + head_dim];
                            srow[t0 + t] = ops::dot_f16(qh, kt) * scale;
                        }
                    }
                }
                t0 += tn;
            }
            crate::util::softmax(srow);
            let mut t0 = 0usize;
            for &page in table.iter() {
                if t0 >= ctx_len {
                    break;
                }
                let tn = page_tokens.min(ctx_len - t0);
                match &v_slab.pages[page as usize] {
                    PageStore::F32(vp) => {
                        for t in 0..tn {
                            let vt = &vp[t * kvd + col..t * kvd + col + head_dim];
                            ops::axpy_f32(srow[t0 + t], vt, orow);
                        }
                    }
                    PageStore::F16(vp) => {
                        for t in 0..tn {
                            let vt = &vp[t * kvd + col..t * kvd + col + head_dim];
                            ops::axpy_f16(srow[t0 + t], vt, orow);
                        }
                    }
                }
                t0 += tn;
            }
        };
        match pool {
            // Head-parallel only when the fan-out can pay for the fork-
            // join: a multi-thread pool and enough score work per job.
            Some(p) if p.size() > 1 && n_heads > 1 && n_heads * ctx_len >= 512 => {
                p.parallel_for_disjoint_rows2(
                    n_heads,
                    |h| p.topology().node_of_row(h, n_heads),
                    scores,
                    ctx_len,
                    out,
                    head_dim,
                    per_head,
                );
            }
            _ => {
                for (head, (srow, orow)) in
                    scores.chunks_mut(ctx_len).zip(out.chunks_mut(head_dim)).enumerate()
                {
                    per_head(head, srow, orow);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_for_rounds_up() {
        let arena = KvArena::accounting(0);
        assert_eq!(arena.pages_for(0), 0);
        assert_eq!(arena.pages_for(1), 1);
        assert_eq!(arena.pages_for(16), 1);
        assert_eq!(arena.pages_for(17), 2);
    }

    #[test]
    fn budget_rounds_up_not_down() {
        // 100 tokens needs 7 pages (112 tokens); flooring to 6 would
        // strand 4 tokens of paid-for budget.
        let mut arena = KvArena::accounting(100);
        assert_eq!(arena.total_pages(), 7);
        assert!(
            arena.total_pages() * PAGE_TOKENS >= 100,
            "invariant: page capacity covers the requested budget"
        );
        assert!(arena.can_admit(100));
        assert!(arena.reserve(1, 100), "the full paid-for budget is reservable");
        // Exact multiples and zero stay exact.
        assert_eq!(KvArena::accounting(160).total_pages(), 10);
        assert_eq!(KvArena::accounting(0).total_pages(), 0);
    }

    #[test]
    fn reserve_and_release_cycle() {
        let mut arena = KvArena::accounting(160); // 10 pages
        assert!(arena.reserve(1, 50)); // 4 pages
        assert_eq!(arena.held_pages(1), 4);
        assert_eq!(arena.free_page_count(), 6);
        assert!(arena.reserve(2, 96)); // 6 pages
        assert_eq!(arena.free_page_count(), 0);
        assert!(!arena.can_admit(1));
        arena.release(1);
        assert_eq!(arena.free_page_count(), 4);
        assert!(arena.can_admit(64));
        assert!(!arena.can_admit(65));
    }

    #[test]
    fn growth_is_incremental() {
        let mut arena = KvArena::accounting(160);
        assert!(arena.reserve(7, 16)); // 1 page
        assert!(arena.reserve(7, 17)); // grow to 2
        assert_eq!(arena.held_pages(7), 2);
        assert!(arena.reserve(7, 10)); // shrink requests are no-ops
        assert_eq!(arena.held_pages(7), 2);
    }

    #[test]
    fn reserve_fails_atomically() {
        let mut arena = KvArena::accounting(32); // 2 pages
        assert!(arena.reserve(1, 16));
        assert!(!arena.reserve(2, 32), "2 pages not available");
        assert_eq!(arena.held_pages(2), 0, "failed reserve must not leak");
        assert_eq!(arena.free_page_count(), 1);
    }

    #[test]
    fn peak_tracking() {
        let mut arena = KvArena::accounting(160);
        arena.reserve(1, 80);
        arena.release(1);
        arena.reserve(2, 16);
        assert_eq!(arena.peak_used_pages(), 5);
    }

    #[test]
    fn release_unknown_seq_is_noop() {
        let mut arena = KvArena::accounting(64);
        arena.release(99);
        assert_eq!(arena.free_page_count(), 4);
    }

    #[test]
    fn slabs_mint_lazily_and_recycle() {
        // 2 layers, kv_dim 4 → one page (16 tokens) costs
        // 16 tokens * 4 elems * 4 B * 2 (K+V) * 2 layers = 1024 B.
        let page_bytes = 16 * 4 * 4 * 2 * 2;
        let mut arena = KvArena::new(2, 4, 64, KvDtype::F32);
        assert_eq!(arena.total_pages(), 4);
        assert_eq!(arena.resident_bytes(), 0, "no pages minted up front");
        assert_eq!(arena.capacity_bytes(), 4 * page_bytes);
        assert!(arena.reserve(1, 10));
        assert_eq!(arena.resident_bytes(), page_bytes);
        assert_eq!(arena.held_bytes(1), page_bytes);
        assert!(arena.reserve(1, 30)); // second page
        assert_eq!(arena.resident_bytes(), 2 * page_bytes);
        arena.release(1);
        assert_eq!(arena.held_bytes(1), 0);
        // Recycled pages keep their storage: resident bytes don't move.
        assert!(arena.reserve(2, 32));
        assert_eq!(arena.resident_bytes(), 2 * page_bytes);
        assert!(arena.resident_bytes() <= arena.capacity_bytes());
    }

    #[test]
    fn balanced_churn_reuses_pages_before_minting() {
        // Preemption/on_stop churn regression: pages freed by one
        // sequence must be recycled by the next reservation, so resident
        // bytes stay flat when allocation and release are balanced.
        let page_bytes = 16 * 4 * 4 * 2 * 2;
        let mut arena = KvArena::new(2, 4, 16 * 64, KvDtype::F32); // 64-page budget
        for round in 0..20u64 {
            assert!(arena.reserve(round, 48)); // 3 pages
            arena.release(round);
            assert_eq!(
                arena.resident_bytes(),
                3 * page_bytes,
                "round {round}: churn must recycle, not mint"
            );
        }
        assert_eq!(arena.peak_used_pages(), 3);
        assert_eq!(arena.used_pages(), 0);
    }

    #[test]
    fn f16_pages_halve_resident_bytes() {
        let mut a32 = KvArena::new(2, 4, 64, KvDtype::F32);
        let mut a16 = KvArena::new(2, 4, 64, KvDtype::F16);
        assert!(a32.reserve(1, 32));
        assert!(a16.reserve(1, 32));
        assert_eq!(a16.resident_bytes() * 2, a32.resident_bytes());
        assert_eq!(a16.capacity_bytes() * 2, a32.capacity_bytes());
    }

    #[test]
    fn append_read_round_trip_across_page_boundary() {
        let kvd = 4;
        let mut arena = KvArena::new(1, kvd, 64, KvDtype::F32);
        assert!(arena.reserve(9, 20)); // 2 pages: positions 0..=19
        for pos in [0usize, 15, 16, 19] {
            let k: Vec<f32> = (0..kvd).map(|i| (pos * 10 + i) as f32).collect();
            let v: Vec<f32> = (0..kvd).map(|i| -((pos * 10 + i) as f32)).collect();
            arena.append(9, 0, pos, &k, &v);
            let (rk, rv) = arena.kv_row(9, 0, pos);
            assert_eq!(rk, k, "K row at pos {pos}");
            assert_eq!(rv, v, "V row at pos {pos}");
        }
    }

    #[test]
    fn f16_rows_round_trip_within_half_precision() {
        let kvd = 8;
        let mut arena = KvArena::new(1, kvd, 32, KvDtype::F16);
        assert!(arena.reserve(1, 17));
        let k: Vec<f32> = (0..kvd).map(|i| 0.37 * (i as f32 + 1.0)).collect();
        let v: Vec<f32> = (0..kvd).map(|i| -1.625 * (i as f32 + 1.0)).collect();
        arena.append(1, 0, 16, &k, &v);
        let (rk, rv) = arena.kv_row(1, 0, 16);
        for (a, b) in rk.iter().zip(k.iter()).chain(rv.iter().zip(v.iter())) {
            let ulp = (b.abs() / 1024.0).max(6e-8);
            assert!((a - b).abs() <= ulp, "{a} vs {b}");
        }
    }

    #[test]
    fn preemption_counter() {
        let mut arena = KvArena::accounting(16);
        assert_eq!(arena.preemptions(), 0);
        arena.note_preemption();
        arena.note_preemption();
        assert_eq!(arena.preemptions(), 2);
    }

    fn prompt(len: usize, salt: u32) -> Vec<u32> {
        (0..len as u32).map(|i| i * 3 + salt).collect()
    }

    #[test]
    fn register_then_map_shares_pages() {
        let mut arena = KvArena::accounting(160); // 10 pages
        let p = prompt(40, 0); // 2 full pages + 8-token tail
        assert!(arena.reserve(1, 40)); // 3 pages
        arena.register_prefix(1, &p);
        assert_eq!(arena.prefix_index_pages(), 2, "only full pages are indexed");
        assert_eq!(arena.used_pages(), 3);
        arena.release(1);
        // Index refs keep the two full pages live; the tail page freed.
        assert_eq!(arena.used_pages(), 2);
        let shared = arena.map_prefix(2, &p);
        assert_eq!(shared, 32, "both indexed pages map");
        assert_eq!(arena.held_pages(2), 2);
        assert_eq!(arena.used_pages(), 2, "mapping shares, it does not allocate");
        assert_eq!(arena.prefix_hit_tokens(), 32);
        // A divergent prompt shares only the matching chunk.
        let mut q = prompt(40, 0);
        q[20] = 9999; // second chunk differs
        let shared = arena.map_prefix(3, &q);
        assert_eq!(shared, 16);
        arena.release(2);
        arena.release(3);
        assert_eq!(arena.used_pages(), 2, "index still holds its pages");
    }

    #[test]
    fn map_prefix_caps_at_prompt_minus_one() {
        // Identical prompt resubmission: the final token must stay
        // prefillable, so one page stays partially shared → COW later.
        let mut arena = KvArena::accounting(160);
        let p = prompt(32, 5); // exactly 2 pages
        assert!(arena.reserve(1, 32));
        arena.register_prefix(1, &p);
        let shared = arena.map_prefix(2, &p);
        assert_eq!(shared, 31, "capped at prompt_len - 1");
        assert_eq!(arena.held_pages(2), 2, "the covering page still maps");
    }

    #[test]
    fn cow_split_preserves_shared_history() {
        let kvd = 4;
        let mut arena = KvArena::new(1, kvd, 16 * 8, KvDtype::F32);
        let p = prompt(32, 1);
        assert!(arena.reserve(1, 32));
        for pos in 0..32 {
            let k: Vec<f32> = (0..kvd).map(|i| (pos * 100 + i) as f32).collect();
            let v: Vec<f32> = (0..kvd).map(|i| -((pos * 100 + i) as f32)).collect();
            arena.append(1, 0, pos, &k, &v);
        }
        arena.register_prefix(1, &p);
        // Seq 2 maps 31 tokens shared; writing position 31 (same prompt's
        // last token) lands in shared page 1 → COW split.
        let shared = arena.map_prefix(2, &p);
        assert_eq!(shared, 31);
        assert!(arena.reserve_for_write(2, 33, 31));
        assert_eq!(arena.cow_splits(), 1, "the written shared page split");
        let k2: Vec<f32> = vec![7.0; kvd];
        let v2: Vec<f32> = vec![-7.0; kvd];
        arena.append(2, 0, 31, &k2, &v2);
        // Seq 1's history at pos 31 is untouched; seq 2 reads its own
        // write there but seq 1's data in the still-shared region.
        let (k1, _) = arena.kv_row(1, 0, 31);
        assert_eq!(k1[0], 3100.0, "donor page unchanged after the split");
        let (k2r, _) = arena.kv_row(2, 0, 31);
        assert_eq!(k2r, k2);
        let (kshared, _) = arena.kv_row(2, 0, 15);
        assert_eq!(kshared[0], 1500.0, "unsplit prefix pages read the donor bytes");
    }

    #[test]
    fn lazy_append_split_is_a_safety_net() {
        let kvd = 4;
        let mut arena = KvArena::new(1, kvd, 16 * 8, KvDtype::F32);
        let p = prompt(32, 2);
        assert!(arena.reserve(1, 32));
        for pos in 0..32 {
            let k: Vec<f32> = (0..kvd).map(|i| (pos + i) as f32).collect();
            arena.append(1, 0, pos, &k.clone(), &k);
        }
        arena.register_prefix(1, &p);
        let shared = arena.map_prefix(2, &p);
        assert_eq!(shared, 31);
        // Plain reserve (no eager split) then a direct append into the
        // shared page: the lazy path must split rather than clobber.
        assert!(arena.reserve(2, 32));
        let row = vec![42.0; kvd];
        arena.append(2, 0, 31, &row, &row);
        assert_eq!(arena.cow_splits(), 1);
        let (k1, _) = arena.kv_row(1, 0, 31);
        assert_eq!(k1[0], 31.0, "donor row survives the lazy split");
    }

    #[test]
    fn index_pages_evict_lru_under_pressure() {
        let mut arena = KvArena::accounting(16 * 4); // 4 pages
        let p = prompt(64, 3); // 4 full pages
        assert!(arena.reserve(1, 64));
        arena.register_prefix(1, &p);
        arena.release(1);
        assert_eq!(arena.used_pages(), 4, "index holds the whole arena");
        assert_eq!(arena.free_page_count(), 0);
        // A 2-page reservation must evict two LRU leaves (the chain
        // drains deepest-first) rather than fail.
        assert!(arena.reserve(2, 32));
        assert_eq!(arena.prefix_index_pages(), 2);
        // And the surviving prefix still maps.
        arena.release(2);
        let shared = arena.map_prefix(3, &p);
        assert_eq!(shared, 32, "the undrained half of the chain still hits");
    }

    #[test]
    fn placement_interleaves_pages_and_round_trips() {
        use crate::topology::Topology;
        let kvd = 4;
        let pool = Arc::new(ThreadPool::with_topology(4, Topology::mock(2)));
        let mut arena = KvArena::new(1, kvd, 16 * 4, KvDtype::F32);
        arena.set_placement(Arc::clone(&pool));
        assert!(arena.reserve(1, 64)); // 4 pages → 2 per node
        let by_node = arena.resident_bytes_by_node();
        assert_eq!(by_node.len(), 2);
        assert_eq!(by_node.iter().sum::<usize>(), arena.resident_bytes());
        assert!(by_node.iter().all(|&b| b > 0), "pages interleave across nodes: {by_node:?}");
        // Reads and writes through placed pages are the same bytes.
        for pos in [0usize, 17, 33, 63] {
            let k: Vec<f32> = (0..kvd).map(|i| (pos * 10 + i) as f32).collect();
            let v: Vec<f32> = (0..kvd).map(|i| -((pos * 10 + i) as f32)).collect();
            arena.append(1, 0, pos, &k, &v);
            let (rk, rv) = arena.kv_row(1, 0, pos);
            assert_eq!(rk, k, "K row at pos {pos}");
            assert_eq!(rv, v, "V row at pos {pos}");
        }
    }

    #[test]
    fn single_node_placement_is_inert() {
        let pool = Arc::new(ThreadPool::new(2));
        let mut arena = KvArena::new(1, 4, 64, KvDtype::F32);
        arena.set_placement(pool);
        assert!(arena.reserve(1, 32));
        assert_eq!(arena.resident_bytes_by_node().len(), 1);
        assert_eq!(arena.resident_bytes_by_node()[0], arena.resident_bytes());
    }

    #[test]
    fn attend_with_reuses_workspace_and_matches_attend() {
        use crate::util::Rng;
        let (n_heads, n_kv_heads, head_dim) = (4usize, 2usize, 8usize);
        let kvd = n_kv_heads * head_dim;
        for dtype in [KvDtype::F32, KvDtype::F16] {
            let mut arena = KvArena::new(1, kvd, 64, dtype);
            assert!(arena.reserve(1, 20)); // 2 pages
            let mut rng = Rng::new(11);
            for pos in 0..20 {
                let k: Vec<f32> = (0..kvd).map(|_| rng.next_gaussian()).collect();
                let v: Vec<f32> = (0..kvd).map(|_| rng.next_gaussian()).collect();
                arena.append(1, 0, pos, &k, &v);
            }
            let q: Vec<f32> = (0..n_heads * head_dim).map(|_| rng.next_gaussian()).collect();
            let scale = 1.0 / (head_dim as f32).sqrt();
            let mut legacy = vec![0f32; n_heads * head_dim];
            arena.attend(1, 0, &q, 20, n_heads, n_kv_heads, head_dim, scale, &mut legacy);
            let mut ws = AttnWorkspace::new();
            for round in 0..3 {
                let mut out = vec![0f32; n_heads * head_dim];
                arena.attend_with(
                    &mut ws, 1, 0, &q, 20, n_heads, n_kv_heads, head_dim, scale, &mut out, None,
                );
                assert_eq!(out, legacy, "{} round {round}", dtype.name());
            }
            assert_eq!(ws.allocs(), 1, "only the first call may allocate");
            assert_eq!(ws.reuses(), 2);
        }
    }

    #[test]
    fn eviction_cannot_reclaim_pages_mapped_by_live_sequences() {
        let mut arena = KvArena::accounting(16 * 2); // 2 pages
        let p = prompt(32, 4);
        assert!(arena.reserve(1, 32));
        arena.register_prefix(1, &p);
        // Seq 1 still live: its pages have refcount 2 (table + index) and
        // must not be reclaimable for seq 2.
        assert!(!arena.reserve(2, 32), "live sequences' pages are not evictable");
        arena.release(1);
        // Now the index is the sole referent → evictable.
        assert!(arena.reserve(2, 32));
        assert_eq!(arena.prefix_index_pages(), 0);
    }
}
