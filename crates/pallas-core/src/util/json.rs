//! A minimal JSON reader/writer (no external crates are available
//! offline) — the serialization substrate for the kernel-tuning profile
//! files (`kernels::tuner::TuningProfile`).
//!
//! Scope: the full JSON value model (null / bool / number / string /
//! array / object), f64 numbers, `\uXXXX` and single-character escapes.
//! Objects preserve insertion order (stored as a `Vec` of pairs), which
//! keeps written profiles diff-friendly and deterministic.

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (always carried as f64; integers up to 2^53 are
    /// exact, which covers every matrix dimension and counter here).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, insertion-ordered.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document (must contain exactly one value).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer value, if this is a whole number.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_number(out, *n),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; null is the conventional stand-in.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

/// Nesting bound: recursion on malformed input (e.g. a file of repeated
/// `[`) must return Err, not blow the stack.
const MAX_DEPTH: usize = 128;

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    if depth > MAX_DEPTH {
        bail!("nesting deeper than {MAX_DEPTH} at byte {pos}");
    }
    skip_ws(bytes, pos);
    let Some(&c) = bytes.get(*pos) else {
        bail!("unexpected end of input");
    };
    match c {
        b'n' => parse_keyword(bytes, pos, "null", Json::Null),
        b't' => parse_keyword(bytes, pos, "true", Json::Bool(true)),
        b'f' => parse_keyword(bytes, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(bytes, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos, depth + 1)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("expected ',' or ']' at byte {pos}"),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut pairs = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                if bytes.get(*pos) != Some(&b':') {
                    bail!("expected ':' at byte {pos}");
                }
                *pos += 1;
                let val = parse_value(bytes, pos, depth + 1)?;
                pairs.push((key, val));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(pairs));
                    }
                    _ => bail!("expected ',' or '}}' at byte {pos}"),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(bytes, pos),
        c => bail!("unexpected character {:?} at byte {pos}", c as char),
    }
}

fn parse_keyword(bytes: &[u8], pos: &mut usize, word: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(value)
    } else {
        bail!("invalid literal at byte {pos}");
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).expect("digits are ascii");
    match text.parse::<f64>() {
        Ok(n) => Ok(Json::Num(n)),
        Err(_) => bail!("invalid number {text:?} at byte {start}"),
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String> {
    if bytes.get(*pos) != Some(&b'"') {
        bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = bytes.get(*pos) else {
            bail!("unterminated string");
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = bytes.get(*pos) else {
                    bail!("unterminated escape");
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 > bytes.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&bytes[*pos..*pos + 4])
                            .ok()
                            .and_then(|h| u32::from_str_radix(h, 16).ok());
                        let Some(code) = hex else {
                            bail!("invalid \\u escape at byte {pos}");
                        };
                        *pos += 4;
                        // Surrogate pairs are not needed for profile files;
                        // map lone surrogates to the replacement character.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    e => bail!("invalid escape {:?}", e as char),
                }
            }
            c if c < 0x80 => out.push(c as char),
            _ => {
                // Multi-byte UTF-8: find the full char from the source.
                let s = std::str::from_utf8(&bytes[*pos - 1..])
                    .map_err(|_| anyhow::anyhow!("invalid utf8 in string"))?;
                let ch = s.chars().next().expect("non-empty");
                out.push(ch);
                *pos += ch.len_utf8() - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = Json::Str("line1\nline2\t\"quoted\" \\ end".into());
        let text = original.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), original);
        assert_eq!(
            Json::parse(r#""A\n""#).unwrap(),
            Json::Str("A\n".into())
        );
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::Obj(vec![
            ("version".into(), Json::Num(1.0)),
            ("name".into(), Json::Str("TL2_0".into())),
            ("rates".into(), Json::Arr(vec![Json::Num(1.25), Json::Num(3.0)])),
            ("empty".into(), Json::Arr(vec![])),
        ]);
        let text = v.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
        assert!(text.contains("\n  \"version\": 1,"), "{text}");
    }

    #[test]
    fn object_order_is_preserved() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        match &v {
            Json::Obj(pairs) => {
                assert_eq!(pairs[0].0, "z");
                assert_eq!(pairs[1].0, "a");
            }
            _ => panic!("not an object"),
        }
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(8.0).as_usize(), Some(8));
        assert_eq!(Json::Num(8.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn errors_on_malformed_input() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
        // Nesting under the bound still parses.
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
        let round = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(round, v);
    }
}
