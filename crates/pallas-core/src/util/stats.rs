//! Summary statistics over benchmark samples: mean, median, percentiles,
//! standard deviation. Used by the bench harness (`perf::bench`) and the
//! metrics layer instead of `criterion` (unavailable offline).

/// Summary of a set of f64 samples.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for empty input.
    pub fn from_samples(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p90: 0.0, p99: 0.0, max: 0.0 };
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::from_samples(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&sorted, 0.5), 50.0);
        assert_eq!(percentile(&sorted, 0.9), 90.0);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 100.0);
    }

    #[test]
    fn empty_is_zeroed() {
        let s = Summary::from_samples(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn mean_and_std() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }
}
