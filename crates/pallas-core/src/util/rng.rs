//! Deterministic PRNG (xoshiro256**) used for synthetic weights, workload
//! generation and sampling. Seeded, fast, and reproducible across runs —
//! every table in EXPERIMENTS.md is regenerable bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference constants).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [-1, 1).
    #[inline]
    pub fn next_f32_signed(&mut self) -> f32 {
        self.next_f32() * 2.0 - 1.0
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn next_gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// A ternary value in {-1, 0, 1} with roughly BitNet-like density
    /// (~50% zeros, ±1 split evenly), as f32.
    pub fn next_ternary(&mut self) -> f32 {
        match self.next_u64() % 4 {
            0 => -1.0,
            1 => 1.0,
            _ => 0.0,
        }
    }

    /// Fill a slice with Gaussian values scaled by `std`.
    pub fn fill_gaussian(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.next_gaussian() * std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments_are_sane() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn ternary_density() {
        let mut r = Rng::new(5);
        let n = 40_000;
        let zeros = (0..n).filter(|_| r.next_ternary() == 0.0).count();
        let frac = zeros as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.03, "zero fraction {frac}");
    }
}
