//! IEEE-754 binary16 ("half") conversion.
//!
//! llama.cpp stores block scales (and the Float16 baseline's weights) as
//! f16; the `half` crate is unavailable offline, so we implement the
//! conversions directly. Round-to-nearest-even on the f32→f16 path, exact
//! widening on the f16→f32 path.

/// Convert an f32 to its IEEE binary16 bit pattern (round-to-nearest-even).
pub fn f32_to_f16(value: f32) -> u16 {
    let bits = value.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let mut mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN. Preserve a NaN payload bit so NaN stays NaN.
        let nan_bit = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan_bit | ((mant >> 13) as u16);
    }

    // Re-bias from f32 (127) to f16 (15).
    exp -= 127 - 15;
    if exp >= 0x1f {
        // Overflow → infinity.
        return sign | 0x7c00;
    }
    if exp <= 0 {
        // Subnormal or underflow to zero.
        if exp < -10 {
            return sign;
        }
        // Add the implicit leading one, then shift into subnormal position.
        mant |= 0x0080_0000;
        let shift = (14 - exp) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (mant + half - 1 + ((mant >> shift) & 1)) >> shift;
        return sign | (rounded as u16);
    }

    // Normal range: round mantissa from 23 to 10 bits, nearest-even.
    let half = 0x0000_0fff + ((mant >> 13) & 1);
    mant += half;
    if mant & 0x0080_0000 != 0 {
        // Mantissa rounding carried out; bump the exponent.
        mant = 0;
        exp += 1;
        if exp >= 0x1f {
            return sign | 0x7c00;
        }
    }
    sign | ((exp as u16) << 10) | ((mant >> 13) as u16)
}

/// Convert an IEEE binary16 bit pattern to f32 (exact).
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = ((bits & 0x8000) as u32) << 16;
    let exp = (bits >> 10) & 0x1f;
    let mant = (bits & 0x03ff) as u32;

    let out = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: normalize by shifting the mantissa up.
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03ff;
            let exp32 = ((127 - 15 + e + 2) as u32) << 23;
            sign | exp32 | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // Inf/NaN
    } else {
        let exp32 = ((exp as u32) + 127 - 15) << 23;
        sign | exp32 | (mant << 13)
    };
    f32::from_bits(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_exact_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.099975586] {
            let rt = f16_to_f32(f32_to_f16(v));
            assert_eq!(rt, v, "round trip of {v}");
        }
    }

    #[test]
    fn near_values_round_correctly() {
        // 1.0009765625 is the successor of 1.0 in f16.
        assert_eq!(f16_to_f32(f32_to_f16(1.0004f32)), 1.0);
        assert_eq!(f16_to_f32(f32_to_f16(1.0007f32)), 1.0009765625);
    }

    #[test]
    fn overflow_to_infinity() {
        assert!(f16_to_f32(f32_to_f16(1.0e6)).is_infinite());
        assert!(f16_to_f32(f32_to_f16(-1.0e6)).is_infinite());
    }

    #[test]
    fn nan_stays_nan() {
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn subnormals_round_trip() {
        let tiny = 5.96e-8f32; // smallest positive f16 subnormal ≈ 5.96e-8
        let rt = f16_to_f32(f32_to_f16(tiny));
        assert!(rt > 0.0 && rt < 1.0e-7);
    }

    #[test]
    fn exhaustive_f16_to_f32_to_f16_identity() {
        // Every finite, non-NaN half value must survive the round trip.
        for bits in 0u16..=0xffff {
            let f = f16_to_f32(bits);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16(f), bits, "bits {bits:#06x} -> {f}");
        }
    }
}

// ---- Hot-path table-driven decode --------------------------------------
//
// §Perf: the branchy `f16_to_f32` costs ~40 cycles in the F16 GEMV inner
// loop (6.7ms/GEMV at 1024²). A 64K-entry table (256 KiB, built once)
// makes the decode a single indexed load — llama.cpp ships the same
// `ggml_table_f32_f16`.

use std::sync::OnceLock;

static F16_TABLE: OnceLock<Vec<f32>> = OnceLock::new();

/// Table-driven f16→f32 for inner loops. First call builds the table.
#[inline]
pub fn f16_to_f32_fast(bits: u16) -> f32 {
    let table = F16_TABLE.get_or_init(|| (0..=u16::MAX).map(f16_to_f32).collect());
    // SAFETY: table has exactly 65536 entries.
    unsafe { *table.get_unchecked(bits as usize) }
}

/// Force table construction (call before timing loops).
pub fn warm_f16_table() {
    let _ = f16_to_f32_fast(0);
}

#[cfg(test)]
mod fast_tests {
    use super::*;

    #[test]
    fn fast_matches_exact_for_all_finite() {
        for bits in 0u16..=0xffff {
            let a = f16_to_f32(bits);
            let b = f16_to_f32_fast(bits);
            if a.is_nan() {
                assert!(b.is_nan());
            } else {
                assert_eq!(a, b, "bits {bits:#06x}");
            }
        }
    }
}
