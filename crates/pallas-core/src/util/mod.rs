//! Small shared substrates: IEEE-754 half-precision conversion, a seedable
//! PRNG, summary statistics and a minimal JSON reader/writer (the build
//! runs offline with no registry access, so these are built from scratch;
//! the only external crate is the vendored `anyhow` stand-in).

pub mod f16;
pub mod json;
pub mod rng;
pub mod stats;

pub use f16::{f16_to_f32, f32_to_f16};
pub use json::Json;
pub use rng::Rng;
pub use stats::Summary;

/// Ceiling division for usize.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// Numerically-stable in-place softmax. Shared by the transformer's
/// attention ops (`model::ops` re-exports it) and the KV arena's fused
/// attend — one implementation, so the two paths stay bit-identical.
///
/// Built from the [`crate::simd::ops`] primitives: vector max, scalar
/// libm `exp` (a vector polynomial would change bits), lane-blocked sum,
/// vector scale — so the result is bit-identical across SIMD tiers.
pub fn softmax(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = crate::simd::ops::max_val(x);
    for v in x.iter_mut() {
        *v = (*v - max).exp();
    }
    let inv = 1.0 / crate::simd::ops::sum(x);
    crate::simd::ops::scale(x, inv);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 32), 0);
        assert_eq!(round_up(1, 32), 32);
        assert_eq!(round_up(32, 32), 32);
        assert_eq!(round_up(33, 32), 64);
    }
}
