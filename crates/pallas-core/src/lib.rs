//! # pallas-core — foundation layer of the Bitnet.cpp reproduction
//!
//! The bottom crate of the `rust_pallas` workspace: small utilities
//! ([`util`]: f16 conversion, JSON, RNG, stats), the process-wide
//! [`simd`] dispatch plus the lane-blocked vector float primitives the
//! attention/ops hot paths run on, the fork-join [`threadpool`] with
//! NUMA-aware per-node chunk queues, the [`topology`] module that
//! discovers (or mocks) the host's NUMA layout, and the paged KV
//! [`arena`] that both the model layer (`pallas-model::Session`) and the
//! serving scheduler (`pallas-serve::coordinator`) allocate from.
//!
//! Nothing here depends on kernels, the model, or the serving stack —
//! the workspace dependency graph is strictly acyclic:
//! `pallas-core ← pallas-kernels ← pallas-model ← pallas-serve`,
//! with the `rust_pallas` facade (lib name `bitnet`) re-exporting
//! every layer under its historical paths.

#![warn(clippy::undocumented_unsafe_blocks)]

#[deny(unsafe_code)]
pub mod arena;
pub mod simd;
pub mod threadpool;
pub mod topology;
#[deny(unsafe_code)]
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
