//! Persistent worker pool with a fork-join `parallel_for`, modeled on
//! ggml's compute threadpool: the same fixed set of threads executes every
//! mpGEMM row-range, so the thread-sweep experiments (paper Fig. 8 / Fig.
//! 10) measure kernel scaling rather than thread-spawn overhead.
//!
//! Design: N-1 parked workers plus the caller. A job is a closure over
//! chunk indices plus per-node chunk queues drained by atomic cursors
//! (work stealing by atomic fetch_add), so uneven rows still balance.
//! The caller participates, then waits on a completion latch.
//!
//! NUMA layering (see [`crate::topology`]): a pool built with
//! [`ThreadPool::with_topology`] splits its threads into per-node worker
//! groups, pinned to their node's CPUs on real (non-mock) topologies.
//! [`ThreadPool::parallel_for`] keeps a single shared queue — every
//! thread pulls from one cursor exactly as before the NUMA work, so
//! existing callers see identical scheduling. Placement-aware callers use
//! [`ThreadPool::parallel_for_placed`], which routes each chunk to the
//! queue of the node that owns it; a worker crosses node boundaries only
//! after its own queue drains (counted in [`NumaStats::steals`]).
//! [`ThreadPool::run_on_node`] runs a closure on a thread of a specific
//! node so slab allocations are first-touched by their owner. None of
//! this changes what any chunk computes — placement only decides *where*
//! a chunk runs — so results are bit-identical to a single-node pool.
//!
//! Re-entrancy: a `parallel_for` issued from inside a pool job (same
//! thread already executing a chunk) runs the nested job inline on the
//! calling thread instead of deadlocking on the submission lock. This
//! used to be a `debug_assert` only — release builds deadlocked.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::topology::{self, Topology};

thread_local! {
    /// Set while this thread is executing chunks of a pool job; nested
    /// `parallel_for` / `run_on_node` calls detect it and run inline.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

/// One node's share of a job: chunk ids plus a claim cursor.
struct ChunkQueue {
    /// Explicit chunk ids (placed jobs); `None` means the identity
    /// mapping `0..len` (plain jobs, which use a single shared queue).
    ids: Option<Vec<usize>>,
    len: usize,
    cursor: AtomicUsize,
}

impl ChunkQueue {
    fn identity(len: usize) -> ChunkQueue {
        ChunkQueue { ids: None, len, cursor: AtomicUsize::new(0) }
    }

    fn explicit(ids: Vec<usize>) -> ChunkQueue {
        let len = ids.len();
        ChunkQueue { ids: Some(ids), len, cursor: AtomicUsize::new(0) }
    }

    /// Claim the next chunk, or `None` when the queue is drained.
    fn next(&self) -> Option<usize> {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= self.len {
            return None;
        }
        Some(match &self.ids {
            Some(v) => v[i],
            None => i,
        })
    }
}

/// An in-flight job. The `'static` on `f` is a lifetime erasure upheld by
/// the submitter, which blocks until every chunk completes before
/// returning (so the borrowed closure outlives all uses).
struct JobData {
    f: &'static (dyn Fn(usize) + Send + Sync),
    /// One queue per node (plain jobs: a single queue shared by all).
    queues: Vec<ChunkQueue>,
    total: usize,
    /// Placed jobs allow cross-node stealing once a worker's own queue
    /// drains; strict jobs ([`ThreadPool::run_on_node`]) do not.
    steal: bool,
}

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    work_done: Condvar,
    /// Chunks executed by threads of each node (all jobs).
    node_chunks: Vec<AtomicU64>,
    /// Chunks a thread executed from another node's queue.
    steals: AtomicU64,
    /// Node of each thread slot; slot 0 is the caller.
    node_of_worker: Vec<usize>,
    /// Whether any thread slot (including the caller) belongs to node g.
    has_worker: Vec<bool>,
}

struct State {
    job: Option<Arc<JobData>>,
    /// Monotonic id so workers can tell jobs apart.
    epoch: u64,
    /// Chunks finished so far in the current job.
    finished: usize,
    shutdown: bool,
}

/// Per-node execution counters, surfaced in the engine summary and the
/// bench JSON `numa` section.
#[derive(Clone, Debug)]
pub struct NumaStats {
    /// Number of NUMA nodes the pool was built over.
    pub nodes: usize,
    /// Whether the topology is a `RUST_PALLAS_NUMA_MOCK` mock.
    pub mocked: bool,
    /// Chunks executed by each node's threads since pool creation.
    pub chunks: Vec<u64>,
    /// Chunks executed from a foreign node's queue (placed jobs only).
    pub steals: u64,
}

/// A fixed-size pool. `size` counts the caller: `ThreadPool::new(1)` runs
/// everything inline with zero synchronization.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    topo: Arc<Topology>,
    /// Serializes submitters (engine thread vs. tuner thread): held for
    /// the full submit-participate-wait span of one job.
    submit: Mutex<()>,
}

impl ThreadPool {
    /// Create a single-node pool that uses `size` threads in total
    /// (including the caller's thread). `size` is clamped to at least 1.
    pub fn new(size: usize) -> ThreadPool {
        ThreadPool::with_topology(size, Topology::single())
    }

    /// Create a pool whose threads are split into per-node worker groups
    /// over `topo` (contiguous balanced split, caller = slot 0). On real
    /// multi-node topologies each group is pinned to its node's CPUs;
    /// mock topologies place but never pin.
    pub fn with_topology(size: usize, topo: Arc<Topology>) -> ThreadPool {
        let size = size.max(1);
        let n_nodes = topo.n_nodes();
        let node_of_worker: Vec<usize> =
            (0..size).map(|i| topo.node_of_row(i, size)).collect();
        let mut has_worker = vec![false; n_nodes];
        for &g in &node_of_worker {
            has_worker[g] = true;
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, epoch: 0, finished: 0, shutdown: false }),
            work_ready: Condvar::new(),
            work_done: Condvar::new(),
            node_chunks: (0..n_nodes).map(|_| AtomicU64::new(0)).collect(),
            steals: AtomicU64::new(0),
            node_of_worker,
            has_worker,
        });
        let workers = (1..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let node = shared.node_of_worker[i];
                let pin = if !topo.is_mocked() && n_nodes > 1 {
                    Some(topo.cpus(node).to_vec())
                } else {
                    None
                };
                std::thread::spawn(move || {
                    if let Some(cpus) = pin {
                        topology::pin_current_thread(&cpus);
                    }
                    worker_loop(shared, node)
                })
            })
            .collect();
        ThreadPool { shared, workers, size, topo, submit: Mutex::new(()) }
    }

    /// Number of threads (including the caller).
    pub fn size(&self) -> usize {
        self.size
    }

    /// The topology this pool was built over.
    pub fn topology(&self) -> &Arc<Topology> {
        &self.topo
    }

    /// Number of NUMA nodes the pool spans (1 for plain pools).
    pub fn n_nodes(&self) -> usize {
        self.topo.n_nodes()
    }

    /// Snapshot of the per-node execution counters.
    pub fn numa_stats(&self) -> NumaStats {
        NumaStats {
            nodes: self.topo.n_nodes(),
            mocked: self.topo.is_mocked(),
            chunks: self.shared.node_chunks.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            steals: self.shared.steals.load(Ordering::Relaxed),
        }
    }

    /// Run `f(chunk)` for every `chunk in 0..n_chunks`, distributing chunks
    /// across all threads; returns when every chunk has completed. A single
    /// queue feeds every thread regardless of node — scheduling is
    /// identical to the pre-NUMA pool. Re-entrant calls (from inside a
    /// pool job, on any pool) run inline on the calling thread.
    pub fn parallel_for<F>(&self, n_chunks: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n_chunks == 0 {
            return;
        }
        if IN_POOL_JOB.with(Cell::get) || self.size == 1 || n_chunks == 1 {
            for c in 0..n_chunks {
                f(c);
            }
            return;
        }
        self.execute(&f, vec![ChunkQueue::identity(n_chunks)], n_chunks, true);
    }

    /// Placement-aware `parallel_for`: chunk `c` is queued on node
    /// `node_of(c) % n_nodes`, and each node's threads drain their own
    /// queue before stealing from others (steals are counted). Chunk
    /// results are identical to [`ThreadPool::parallel_for`] — only the
    /// executing thread (and thus memory locality) changes. Degenerates
    /// to the plain path on single-node pools.
    pub fn parallel_for_placed<F, N>(&self, n_chunks: usize, node_of: N, f: F)
    where
        F: Fn(usize) + Send + Sync,
        N: Fn(usize) -> usize,
    {
        let n_nodes = self.topo.n_nodes();
        if n_nodes <= 1 {
            return self.parallel_for(n_chunks, f);
        }
        if n_chunks == 0 {
            return;
        }
        if IN_POOL_JOB.with(Cell::get) || self.size == 1 || n_chunks == 1 {
            for c in 0..n_chunks {
                f(c);
            }
            return;
        }
        let mut ids: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
        for c in 0..n_chunks {
            ids[node_of(c) % n_nodes].push(c);
        }
        let queues: Vec<ChunkQueue> = ids.into_iter().map(ChunkQueue::explicit).collect();
        self.execute(&f, queues, n_chunks, true);
    }

    /// Fork-join over `n_rows` row pairs of two output buffers: row `r`
    /// gets exclusive `&mut` access to `a[r * a_stride..][..a_stride]`
    /// and `b[r * b_stride..][..b_stride]`. This is the safe
    /// disjoint-write shim the `#[deny(unsafe_code)]` KV arena uses for
    /// head-parallel attention (per-head score rows + per-head output
    /// slices). Placement-aware like [`ThreadPool::parallel_for_placed`];
    /// like it, what each row computes is placement-independent.
    pub fn parallel_for_disjoint_rows2<N, F>(
        &self,
        n_rows: usize,
        node_of: N,
        a: &mut [f32],
        a_stride: usize,
        b: &mut [f32],
        b_stride: usize,
        f: F,
    ) where
        N: Fn(usize) -> usize,
        F: Fn(usize, &mut [f32], &mut [f32]) + Send + Sync,
    {
        assert!(a.len() >= n_rows * a_stride, "rows2: a holds {} < {n_rows} x {a_stride}", a.len());
        assert!(b.len() >= n_rows * b_stride, "rows2: b holds {} < {n_rows} x {b_stride}", b.len());
        #[derive(Clone, Copy)]
        struct SendPtr(*mut f32);
        // SAFETY: every access through the pointer targets a distinct row
        // (the pool claims each row id exactly once), so threads never
        // alias each other's elements.
        unsafe impl Send for SendPtr {}
        // SAFETY: as above — concurrent uses touch disjoint rows only.
        unsafe impl Sync for SendPtr {}
        let ap = SendPtr(a.as_mut_ptr());
        let bp = SendPtr(b.as_mut_ptr());
        self.parallel_for_placed(n_rows, node_of, |r| {
            // SAFETY: row `r` is claimed by exactly one thread per job,
            // rows are disjoint by construction (stride-sized, in-bounds
            // by the asserts above), and the submitter blocks until every
            // row completes — so each `&mut` is exclusive and the borrows
            // of `a`/`b` outlive all uses.
            let ar = unsafe { std::slice::from_raw_parts_mut(ap.0.add(r * a_stride), a_stride) };
            // SAFETY: as above, for `b`'s row `r`.
            let br = unsafe { std::slice::from_raw_parts_mut(bp.0.add(r * b_stride), b_stride) };
            f(r, ar, br);
        });
    }

    /// Run `f` once on a thread belonging to `node` (modulo the node
    /// count) and wait for it — used to first-touch weight and KV slabs
    /// from their owning node. Runs inline on the caller when the pool is
    /// single-node, the target is the caller's node, the target has no
    /// worker threads, or we are already inside a pool job.
    pub fn run_on_node<F>(&self, node: usize, f: F)
    where
        F: FnOnce() + Send,
    {
        let n_nodes = self.topo.n_nodes();
        let node = node % n_nodes.max(1);
        let inline = IN_POOL_JOB.with(Cell::get)
            || self.size == 1
            || n_nodes <= 1
            || node == self.shared.node_of_worker[0]
            || !self.shared.has_worker[node];
        if inline {
            f();
            return;
        }
        let slot = Mutex::new(Some(f));
        let call = |_c: usize| {
            if let Some(g) = slot.lock().unwrap().take() {
                g();
            }
        };
        let mut ids: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
        ids[node].push(0);
        let queues: Vec<ChunkQueue> = ids.into_iter().map(ChunkQueue::explicit).collect();
        // Strict (no-steal) single-chunk job: only `node`'s workers can
        // claim it, so the closure runs — and first-touches — there.
        self.execute(&call, queues, 1, false);
    }

    /// Submit a job, participate as slot 0, and wait for completion.
    fn execute(&self, f: &(dyn Fn(usize) + Send + Sync), queues: Vec<ChunkQueue>, total: usize, steal: bool) {
        // SAFETY: the lifetime is erased only for the duration of this
        // call; the completion wait below blocks until every chunk has
        // run, so workers never touch the closure after `f` is dropped.
        let f_static: &'static (dyn Fn(usize) + Send + Sync) =
            unsafe { std::mem::transmute(f) };
        let job = Arc::new(JobData { f: f_static, queues, total, steal });
        let _submit = self.submit.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(Arc::clone(&job));
            st.finished = 0;
            st.epoch += 1;
            self.shared.work_ready.notify_all();
        }
        // The caller participates in the same job.
        IN_POOL_JOB.with(|b| b.set(true));
        let done = run_participant(&self.shared, &job, self.shared.node_of_worker[0], true);
        IN_POOL_JOB.with(|b| b.set(false));
        // Credit the caller's chunks and wait for the stragglers.
        let mut st = self.shared.state.lock().unwrap();
        st.finished += done;
        while st.finished < job.total {
            st = self.shared.work_done.wait(st).unwrap();
        }
        st.job = None;
    }
}

/// Global pool shared by the engine, the tuner and ad-hoc callers, so one
/// process never layers competing worker sets (satellite of the NUMA
/// work: `tune` used to spawn a fresh pool per invocation while each
/// `Transformer` held its own). The first caller's thread count sizes it;
/// later callers receive the same pool regardless of their argument. The
/// topology is resolved once via [`topology::resolved_mode`] /
/// [`Topology::detect`].
pub fn shared_pool(threads: usize) -> Arc<ThreadPool> {
    static SHARED_POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    Arc::clone(SHARED_POOL.get_or_init(|| {
        let topo = Topology::detect(topology::resolved_mode());
        Arc::new(ThreadPool::with_topology(threads.max(1), topo))
    }))
}

/// Execute one thread slot's share of `job`: drain the slot's own queue,
/// then (caller only) queues of nodes with no threads, then steal from
/// other nodes if the job allows. Returns chunks executed.
fn run_participant(shared: &Shared, job: &JobData, node: usize, is_caller: bool) -> usize {
    let nq = job.queues.len();
    let my_q = if node < nq { node } else { 0 };
    let mut done = 0usize;
    while let Some(c) = job.queues[my_q].next() {
        (job.f)(c);
        done += 1;
    }
    if is_caller {
        // Strict jobs must still complete if a queue's node has no
        // threads (more nodes than threads): the submitter adopts those
        // orphan queues. Not counted as steals — no owner lost work.
        for (g, q) in job.queues.iter().enumerate() {
            if g == my_q || shared.has_worker.get(g).copied().unwrap_or(false) {
                continue;
            }
            while let Some(c) = q.next() {
                (job.f)(c);
                done += 1;
            }
        }
    }
    if job.steal && nq > 1 {
        for off in 1..nq {
            let g = (my_q + off) % nq;
            let mut stolen = 0usize;
            while let Some(c) = job.queues[g].next() {
                (job.f)(c);
                stolen += 1;
            }
            if stolen > 0 {
                shared.steals.fetch_add(stolen as u64, Ordering::Relaxed);
                done += stolen;
            }
        }
    }
    if done > 0 {
        shared.node_chunks[node].fetch_add(done as u64, Ordering::Relaxed);
    }
    done
}

fn worker_loop(shared: Arc<Shared>, node: usize) {
    let mut last_epoch = 0u64;
    loop {
        // Wait for a new job (or shutdown).
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = st.job.clone() {
                    if st.epoch != last_epoch {
                        last_epoch = st.epoch;
                        break job;
                    }
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        IN_POOL_JOB.with(|b| b.set(true));
        let done = run_participant(&shared, &job, node, false);
        IN_POOL_JOB.with(|b| b.set(false));
        let mut st = shared.state.lock().unwrap();
        st.finished += done;
        if st.finished >= job.total {
            shared.work_done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_ready.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(hits.len(), |c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i}");
        }
    }

    #[test]
    fn single_thread_pool_is_inline() {
        let pool = ThreadPool::new(1);
        let mut sum = 0u64;
        // Mutable capture works because size-1 pools run inline; use a cell
        // via atomics to keep the closure Fn.
        let total = AtomicU64::new(0);
        pool.parallel_for(10, |c| {
            total.fetch_add(c as u64, Ordering::SeqCst);
        });
        sum += total.load(Ordering::SeqCst);
        assert_eq!(sum, 45);
    }

    #[test]
    fn reusable_across_jobs() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let total = AtomicU64::new(0);
            pool.parallel_for(64, |c| {
                total.fetch_add((c + round) as u64, Ordering::SeqCst);
            });
            let expect: u64 = (0..64).map(|c| (c + round) as u64).sum();
            assert_eq!(total.load(Ordering::SeqCst), expect);
        }
    }

    #[test]
    fn zero_chunks_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn more_threads_than_chunks() {
        let pool = ThreadPool::new(8);
        let total = AtomicU64::new(0);
        pool.parallel_for(3, |c| {
            total.fetch_add(c as u64 + 1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = ThreadPool::new(4);
        let data: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
        let chunks = 16;
        let partial: Vec<Mutex<f64>> = (0..chunks).map(|_| Mutex::new(0.0)).collect();
        let per = data.len() / chunks;
        pool.parallel_for(chunks, |c| {
            let s: f64 = data[c * per..(c + 1) * per].iter().sum();
            *partial[c].lock().unwrap() = s;
        });
        let total: f64 = partial.iter().map(|m| *m.lock().unwrap()).sum();
        assert_eq!(total, data.iter().sum::<f64>());
    }

    #[test]
    fn nested_parallel_for_runs_inline() {
        // Regression: a parallel_for issued from inside a pool job used to
        // trip a debug_assert (and deadlock in release) — now it runs the
        // nested job inline on the calling thread.
        let pool = ThreadPool::new(4);
        let inner_hits = AtomicU64::new(0);
        let outer_hits = AtomicU64::new(0);
        pool.parallel_for(8, |_| {
            outer_hits.fetch_add(1, Ordering::SeqCst);
            pool.parallel_for(4, |_| {
                inner_hits.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(outer_hits.load(Ordering::SeqCst), 8);
        assert_eq!(inner_hits.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn placed_runs_every_chunk_exactly_once() {
        let pool = ThreadPool::with_topology(4, Topology::mock(2));
        assert_eq!(pool.n_nodes(), 2);
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for_placed(hits.len(), |c| c / 32, |c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i}");
        }
        let stats = pool.numa_stats();
        assert_eq!(stats.nodes, 2);
        assert!(stats.mocked);
        assert_eq!(stats.chunks.iter().sum::<u64>(), 64);
    }

    #[test]
    fn placed_skewed_queue_completes_via_stealing() {
        // All chunks on node 1: node 0's threads drain nothing of their
        // own, then steal — the job must still complete exactly once per
        // chunk and any cross-node execution is counted.
        let pool = ThreadPool::with_topology(4, Topology::mock(2));
        let hits: Vec<AtomicU64> = (0..32).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for_placed(hits.len(), |_| 1, |c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i}");
        }
        let stats = pool.numa_stats();
        assert_eq!(stats.chunks.iter().sum::<u64>(), 32);
    }

    #[test]
    fn placed_balanced_pairs_run_on_their_own_nodes() {
        // One chunk per node, each spinning until both have started: the
        // two chunks must run concurrently on distinct threads, so each
        // node executes exactly its own chunk and nothing is stolen.
        let pool = ThreadPool::with_topology(2, Topology::mock(2));
        let started = AtomicU64::new(0);
        pool.parallel_for_placed(2, |c| c, |_| {
            started.fetch_add(1, Ordering::SeqCst);
            let mut spins = 0u64;
            while started.load(Ordering::SeqCst) < 2 && spins < 1_000_000_000 {
                std::hint::spin_loop();
                spins += 1;
            }
        });
        let stats = pool.numa_stats();
        assert_eq!(stats.chunks, vec![1, 1]);
        assert_eq!(stats.steals, 0);
    }

    #[test]
    fn disjoint_rows_pass_exclusive_row_slices() {
        for threads in [1, 4] {
            let pool = ThreadPool::new(threads);
            let mut a = vec![0f32; 8 * 16];
            let mut b = vec![0f32; 8 * 4];
            pool.parallel_for_disjoint_rows2(8, |r| r, &mut a, 16, &mut b, 4, |r, ar, br| {
                assert_eq!(ar.len(), 16);
                assert_eq!(br.len(), 4);
                for v in ar.iter_mut() {
                    *v += 1.0 + r as f32;
                }
                for v in br.iter_mut() {
                    *v -= 1.0 + r as f32;
                }
            });
            for r in 0..8 {
                assert!(a[r * 16..(r + 1) * 16].iter().all(|&v| v == 1.0 + r as f32));
                assert!(b[r * 4..(r + 1) * 4].iter().all(|&v| v == -1.0 - r as f32));
            }
        }
    }

    #[test]
    fn run_on_node_executes_exactly_once() {
        let pool = ThreadPool::with_topology(4, Topology::mock(2));
        for node in 0..4 {
            let ran = AtomicU64::new(0);
            pool.run_on_node(node, || {
                ran.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(ran.load(Ordering::SeqCst), 1, "node {node}");
        }
        // Inline fallbacks: single-thread pool and single-node topology.
        let inline_pool = ThreadPool::new(1);
        let ran = AtomicU64::new(0);
        inline_pool.run_on_node(7, || {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn run_on_node_moves_off_caller_for_foreign_nodes() {
        let pool = ThreadPool::with_topology(4, Topology::mock(2));
        let caller = std::thread::current().id();
        let same = Mutex::new(None);
        pool.run_on_node(1, || {
            *same.lock().unwrap() = Some(std::thread::current().id() == caller);
        });
        assert_eq!(*same.lock().unwrap(), Some(false));
    }

    #[test]
    fn shared_pool_returns_one_instance() {
        let a = shared_pool(2);
        let b = shared_pool(5);
        assert!(Arc::ptr_eq(&a, &b));
        let total = AtomicU64::new(0);
        a.parallel_for(16, |c| {
            total.fetch_add(c as u64, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 120);
    }
}
