//! Process-wide runtime SIMD dispatch, shared by every vectorized layer.
//!
//! Historically this state lived in `pallas-kernels` (the mpGEMM library
//! was the only vectorized code); since the attention/ops vector layer it
//! sits here in the foundation crate so the KV arena's fused attend, the
//! model ops and the kernels all dispatch on **one** process-wide level.
//! `pallas-kernels` re-exports everything under its historical paths.
//!
//! * [`SimdLevel`] names the tiers; [`detect`] probes the CPU at run
//!   time (`is_x86_feature_detected!` / `is_aarch64_feature_detected!`).
//! * The active level initializes lazily from the `RUST_PALLAS_SIMD`
//!   environment variable (`auto`/`scalar`/`avx2`/`neon`), defaulting
//!   to the best detected tier; the CLI `--simd` flag calls
//!   [`set_level`]. Requests the host cannot honor clamp to [`detect`].
//! * Every vectorized kernel's `gemv_rows` reports through
//!   [`note_call`], so `Engine::summary` can show per-level call counts.
//! * Tests and the tuner force a level for a scoped region with
//!   [`with_level`]; a process-wide mutex serializes forcing so
//!   concurrent tests cannot observe each other's override.
//!
//! The vector paths are **bit-identical** to the scalar ones by
//! construction: integer accumulation is reassociation-safe, and every
//! ordered float reduction shares one lane-blocked accumulation order
//! between the scalar and vector implementations (see [`ops`] and
//! `rust/tests/simd_identity.rs`).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;

pub mod ops;

/// A SIMD implementation tier. `Scalar` is always available; the vector
/// tiers require both compile-target support and runtime CPU detection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SimdLevel {
    /// Portable scalar loops (the reference implementation).
    Scalar = 0,
    /// x86-64 AVX2: `_mm_shuffle_epi8` LUT gathers, `maddubs` MADs,
    /// 8-wide float attention/ops loops (F16C page decode when present).
    Avx2 = 1,
    /// AArch64 NEON: `vqtbl1q_u8` LUT gathers, 4-wide float loops.
    Neon = 2,
}

impl SimdLevel {
    /// Every tier, scalar first.
    pub const ALL: [SimdLevel; 3] = [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Neon];

    /// Stable lowercase name (used in profiles, metrics and the CLI).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Parse a [`name`](Self::name); `None` for unknown strings
    /// (callers treat `"auto"` separately).
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(SimdLevel::Scalar),
            "avx2" => Some(SimdLevel::Avx2),
            "neon" => Some(SimdLevel::Neon),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> SimdLevel {
        match v {
            1 => SimdLevel::Avx2,
            2 => SimdLevel::Neon,
            _ => SimdLevel::Scalar,
        }
    }
}

/// Probe the CPU for the best tier this binary can use.
pub fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdLevel::Neon;
        }
    }
    SimdLevel::Scalar
}

/// Clamp a requested level to what this host actually supports:
/// unsatisfiable requests (e.g. `avx2` on a non-AVX2 machine, `neon`
/// on x86) degrade to [`detect`]'s answer, never the other way around.
pub fn clamp(level: SimdLevel) -> SimdLevel {
    if level == SimdLevel::Scalar || level == detect() {
        level
    } else {
        detect()
    }
}

const UNSET: u8 = 0xff;
static ACTIVE: AtomicU8 = AtomicU8::new(UNSET);
static FORCE_LOCK: Mutex<()> = Mutex::new(());
static CALLS: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

fn init_from_env() -> SimdLevel {
    match std::env::var("RUST_PALLAS_SIMD") {
        Ok(s) => match SimdLevel::parse(&s) {
            Some(level) => clamp(level),
            None => detect(), // "auto" and unknown values alike
        },
        Err(_) => detect(),
    }
}

/// The level the kernels dispatch on right now. Lazily initialized from
/// `RUST_PALLAS_SIMD` (or CPU detection) on first use.
pub fn active_level() -> SimdLevel {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != UNSET {
        return SimdLevel::from_u8(v);
    }
    let init = init_from_env();
    // Keep whatever a racing set_level installed first.
    let _ = ACTIVE.compare_exchange(UNSET, init as u8, Ordering::Relaxed, Ordering::Relaxed);
    SimdLevel::from_u8(ACTIVE.load(Ordering::Relaxed))
}

/// Set the process-wide dispatch level (the CLI `--simd` flag). Returns
/// the level actually installed after host clamping.
pub fn set_level(level: SimdLevel) -> SimdLevel {
    let applied = clamp(level);
    ACTIVE.store(applied as u8, Ordering::Relaxed);
    applied
}

/// Whether `level` can run under the *current* dispatch state: scalar
/// always can; a vector tier only when it is the active level. A forced
/// scalar override (env/CLI) therefore makes vector tiers unusable —
/// exactly the semantics profile degradation needs.
pub fn usable(level: SimdLevel) -> bool {
    level == SimdLevel::Scalar || level == active_level()
}

/// The levels worth measuring on this host right now: scalar, plus the
/// active vector tier when one is enabled.
pub fn available_levels() -> Vec<SimdLevel> {
    let active = active_level();
    if active == SimdLevel::Scalar {
        vec![SimdLevel::Scalar]
    } else {
        vec![SimdLevel::Scalar, active]
    }
}

/// Run `f` with the dispatch level forced to `level` (host-clamped),
/// restoring the previous level afterwards — panic-safe, and serialized
/// process-wide so concurrent forcing callers cannot interleave.
pub fn with_level<R>(level: SimdLevel, f: impl FnOnce() -> R) -> R {
    let _guard = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(u8);
    impl Drop for Restore {
        fn drop(&mut self) {
            ACTIVE.store(self.0, Ordering::Relaxed);
        }
    }
    let _restore = Restore(active_level() as u8);
    ACTIVE.store(clamp(level) as u8, Ordering::Relaxed);
    f()
}

/// Record one `gemv_rows` dispatch at `level` (vectorized kernels only).
#[inline]
pub fn note_call(level: SimdLevel) {
    CALLS[level as usize].fetch_add(1, Ordering::Relaxed);
}

/// Cumulative `gemv_rows` dispatch counts, indexed `[scalar, avx2, neon]`.
pub fn call_counts() -> [u64; 3] {
    [
        CALLS[0].load(Ordering::Relaxed),
        CALLS[1].load(Ordering::Relaxed),
        CALLS[2].load(Ordering::Relaxed),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for level in SimdLevel::ALL {
            assert_eq!(SimdLevel::parse(level.name()), Some(level));
        }
        assert_eq!(SimdLevel::parse("AVX2"), Some(SimdLevel::Avx2));
        assert_eq!(SimdLevel::parse("auto"), None);
        assert_eq!(SimdLevel::parse("sse9"), None);
    }

    #[test]
    fn clamp_never_exceeds_host() {
        // Whatever the host, clamping the detected level is the identity
        // and clamping Scalar is the identity.
        assert_eq!(clamp(SimdLevel::Scalar), SimdLevel::Scalar);
        assert_eq!(clamp(detect()), detect());
        // Any request either sticks or degrades to the detected level.
        for level in SimdLevel::ALL {
            let c = clamp(level);
            assert!(c == level || c == detect(), "{level:?} clamped to {c:?}");
        }
    }

    #[test]
    fn with_level_forces_and_restores() {
        let before = active_level();
        with_level(SimdLevel::Scalar, || {
            assert_eq!(active_level(), SimdLevel::Scalar);
            assert!(usable(SimdLevel::Scalar));
            assert_eq!(available_levels(), vec![SimdLevel::Scalar]);
        });
        assert_eq!(active_level(), before);
    }

    #[test]
    fn note_call_counts_monotonically() {
        let before = call_counts();
        note_call(SimdLevel::Scalar);
        note_call(SimdLevel::Scalar);
        let after = call_counts();
        assert!(after[0] >= before[0] + 2);
    }
}
