//! Vectorized float primitives for the attention and model-op hot paths,
//! bit-identical across tiers by a shared lane-blocked accumulation order.
//!
//! Float addition does not reassociate, so a naive sequential scalar sum
//! and an 8-wide vector sum produce different bits. Every reduction here
//! therefore uses **one** accumulation order in all tiers: element `i`
//! lands in lane `i % LANES`, lanes are spilled to an array, and
//! [`reduce_lanes`] folds the array in a fixed pairwise order. The scalar
//! path runs that exact scheme with a `[f32; LANES]` accumulator; AVX2
//! holds the lanes in one `__m256` (separate `mul`/`add` — never FMA,
//! which would fuse the rounding step the scalar path performs); NEON
//! holds them in two `float32x4_t`s covering lanes 0–3 and 4–7.
//! Elementwise ops (axpy, scaling, rotation, SwiGLU) are bit-identical as
//! long as each output element is computed by the same expression tree,
//! which the per-tier implementations mirror operation for operation.
//!
//! f16 operands decode **inside** the loop: AVX2 uses the hardware
//! `_mm256_cvtph_ps` widening when the CPU has F16C, else the 64K
//! `f16_to_f32_fast` table — both are exact IEEE widenings, so the choice
//! affects speed only, never bits. This is what lets the KV arena's
//! attend read f16 pages without materializing an f32 scratch copy.
//!
//! Transcendentals (`exp`, `sin`, `cos`) always run scalar libm — a
//! vector polynomial would change results — so softmax/SwiGLU/RoPE
//! vectorize the arithmetic around them.

use crate::util::f16::f16_to_f32_fast;

use super::{active_level, SimdLevel};

/// Accumulation lanes every reduction is blocked over (AVX2 register
/// width; two NEON registers; a `[f32; 8]` in the scalar reference).
pub const LANES: usize = 8;

/// Fold the lane accumulators in a fixed pairwise order. Every tier ends
/// its reductions here, so the final rounding sequence is shared.
#[inline]
fn reduce_lanes(acc: &[f32; LANES]) -> f32 {
    let a = (acc[0] + acc[4]) + (acc[1] + acc[5]);
    let b = (acc[2] + acc[6]) + (acc[3] + acc[7]);
    a + b
}

/// Dot product of two f32 slices (lane-blocked order).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::dot_f32(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::dot_f32(a, b) },
        _ => scalar::dot_f32(a, b),
    }
}

/// Dot product of an f32 slice with an f16 (bit-pattern) slice, decode
/// fused into the loop.
#[inline]
pub fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::dot_f16(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::dot_f16(a, b) },
        _ => scalar::dot_f16(a, b),
    }
}

/// Dot product of little-endian f32 weight bytes with f32 activations
/// (the F32 baseline kernel's inner loop).
#[inline]
pub fn dot_f32_le(w: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len() * 4);
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::dot_f32_le(w, x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::dot_f32_le(w, x) },
        _ => scalar::dot_f32_le(w, x),
    }
}

/// Dot product of little-endian f16 weight bytes with f32 activations
/// (the F16 baseline kernel's and the LM head's inner loop).
#[inline]
pub fn dot_f16_le(w: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len() * 2);
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::dot_f16_le(w, x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::dot_f16_le(w, x) },
        _ => scalar::dot_f16_le(w, x),
    }
}

/// `y[i] += alpha * x[i]` (elementwise — bit-identical across tiers).
#[inline]
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::axpy_f32(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::axpy_f32(alpha, x, y) },
        _ => scalar::axpy_f32(alpha, x, y),
    }
}

/// `y[i] += alpha * f16_decode(x[i])`, decode fused into the loop.
#[inline]
pub fn axpy_f16(alpha: f32, x: &[u16], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::axpy_f16(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::axpy_f16(alpha, x, y) },
        _ => scalar::axpy_f16(alpha, x, y),
    }
}

/// Sum of squares (lane-blocked order) — the RMSNorm reduction.
#[inline]
pub fn sum_squares(x: &[f32]) -> f32 {
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::sum_squares(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::sum_squares(x) },
        _ => scalar::sum_squares(x),
    }
}

/// Maximum element (`NEG_INFINITY` when empty). Max is order-free over
/// the finite values attention produces, so tiers agree bit-for-bit.
#[inline]
pub fn max_val(x: &[f32]) -> f32 {
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::max_val(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::max_val(x) },
        _ => scalar::max_val(x),
    }
}

/// Sum of elements (lane-blocked order) — the softmax normalizer.
#[inline]
pub fn sum(x: &[f32]) -> f32 {
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::sum(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::sum(x) },
        _ => scalar::sum(x),
    }
}

/// `x[i] *= s` in place (elementwise).
#[inline]
pub fn scale(x: &mut [f32], s: f32) {
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::scale(x, s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::scale(x, s) },
        _ => scalar::scale(x, s),
    }
}

/// `out[i] = (x[i] * inv) * gain[i]` (the RMSNorm apply step; the
/// parenthesization is part of the bit-identity contract).
#[inline]
pub fn scale_gain(x: &[f32], inv: f32, gain: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    debug_assert_eq!(x.len(), out.len());
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::scale_gain(x, inv, gain, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::scale_gain(x, inv, gain, out) },
        _ => scalar::scale_gain(x, inv, gain, out),
    }
}

/// Rotate interleaved `(even, odd)` pairs: for pair `p` of `head`,
/// `even' = even*cos[p] - odd*sin[p]`, `odd' = even*sin[p] + odd*cos[p]`
/// (the RoPE inner step; `head.len() == 2 * sin.len()`).
#[inline]
pub fn rope_rotate(head: &mut [f32], sin: &[f32], cos: &[f32]) {
    debug_assert_eq!(head.len(), 2 * sin.len());
    debug_assert_eq!(sin.len(), cos.len());
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::rope_rotate(head, sin, cos) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::rope_rotate(head, sin, cos) },
        _ => scalar::rope_rotate(head, sin, cos),
    }
}

/// SwiGLU combine: `out[i] = (gate[i] / (1 + exp(-gate[i]))) * up[i]`.
/// `exp` stays scalar libm in every tier; the divide/add/multiply around
/// it vectorize.
#[inline]
pub fn silu_mul(gate: &[f32], up: &[f32], out: &mut [f32]) {
    debug_assert_eq!(gate.len(), up.len());
    debug_assert_eq!(gate.len(), out.len());
    match active_level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_level() == Avx2 only after runtime AVX2 detection.
        SimdLevel::Avx2 => unsafe { avx2::silu_mul(gate, up, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::silu_mul(gate, up, out) },
        _ => scalar::silu_mul(gate, up, out),
    }
}

// ---- Scalar reference tier ---------------------------------------------
//
// The reference implementations every vector tier must match bit-for-bit.
// Reductions run the same lane-blocked scheme the registers impose; the
// elementwise loops spell out the exact expression trees the vector code
// evaluates per element.

mod scalar {
    use super::{f16_to_f32_fast, reduce_lanes, LANES};

    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = [0f32; LANES];
        for (i, (&av, &bv)) in a.iter().zip(b.iter()).enumerate() {
            acc[i & (LANES - 1)] += av * bv;
        }
        reduce_lanes(&acc)
    }

    pub fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
        let mut acc = [0f32; LANES];
        for (i, (&av, &bv)) in a.iter().zip(b.iter()).enumerate() {
            acc[i & (LANES - 1)] += av * f16_to_f32_fast(bv);
        }
        reduce_lanes(&acc)
    }

    pub fn dot_f32_le(w: &[u8], x: &[f32]) -> f32 {
        let mut acc = [0f32; LANES];
        for (i, c) in w.chunks_exact(4).enumerate() {
            let wv = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            acc[i & (LANES - 1)] += wv * x[i];
        }
        reduce_lanes(&acc)
    }

    pub fn dot_f16_le(w: &[u8], x: &[f32]) -> f32 {
        let mut acc = [0f32; LANES];
        for (i, c) in w.chunks_exact(2).enumerate() {
            let wv = f16_to_f32_fast(u16::from_le_bytes([c[0], c[1]]));
            acc[i & (LANES - 1)] += wv * x[i];
        }
        reduce_lanes(&acc)
    }

    pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
        for (yo, &xv) in y.iter_mut().zip(x.iter()) {
            *yo += alpha * xv;
        }
    }

    pub fn axpy_f16(alpha: f32, x: &[u16], y: &mut [f32]) {
        for (yo, &xv) in y.iter_mut().zip(x.iter()) {
            *yo += alpha * f16_to_f32_fast(xv);
        }
    }

    pub fn sum_squares(x: &[f32]) -> f32 {
        let mut acc = [0f32; LANES];
        for (i, &v) in x.iter().enumerate() {
            acc[i & (LANES - 1)] += v * v;
        }
        reduce_lanes(&acc)
    }

    pub fn max_val(x: &[f32]) -> f32 {
        x.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn sum(x: &[f32]) -> f32 {
        let mut acc = [0f32; LANES];
        for (i, &v) in x.iter().enumerate() {
            acc[i & (LANES - 1)] += v;
        }
        reduce_lanes(&acc)
    }

    pub fn scale(x: &mut [f32], s: f32) {
        for v in x.iter_mut() {
            *v *= s;
        }
    }

    pub fn scale_gain(x: &[f32], inv: f32, gain: &[f32], out: &mut [f32]) {
        for ((o, &xv), &g) in out.iter_mut().zip(x.iter()).zip(gain.iter()) {
            *o = (xv * inv) * g;
        }
    }

    pub fn rope_rotate(head: &mut [f32], sin: &[f32], cos: &[f32]) {
        for (pair, (&s, &c)) in head.chunks_exact_mut(2).zip(sin.iter().zip(cos.iter())) {
            let (a, b) = (pair[0], pair[1]);
            // The vector tiers compute `a*c + b*(-s)` / `b*c + a*s`; both
            // are IEEE-identical to these expressions (negation is exact,
            // addition commutes bitwise).
            pair[0] = a * c - b * s;
            pair[1] = a * s + b * c;
        }
    }

    pub fn silu_mul(gate: &[f32], up: &[f32], out: &mut [f32]) {
        for ((o, &g), &u) in out.iter_mut().zip(gate.iter()).zip(up.iter()) {
            *o = (g / (1.0 + (-g).exp())) * u;
        }
    }
}

// ---- AVX2 tier ---------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{f16_to_f32_fast, reduce_lanes, LANES};
    use core::arch::x86_64::*;
    use std::sync::atomic::{AtomicU8, Ordering};

    /// Whether the CPU has F16C (`vcvtph2ps`). A separate feature bit
    /// from AVX2 — detected once, cached. Absence only costs speed: the
    /// table decode below produces identical bits.
    fn have_f16c() -> bool {
        static STATE: AtomicU8 = AtomicU8::new(0);
        match STATE.load(Ordering::Relaxed) {
            1 => true,
            2 => false,
            _ => {
                let have = std::arch::is_x86_feature_detected!("f16c");
                STATE.store(if have { 1 } else { 2 }, Ordering::Relaxed);
                have
            }
        }
    }

    /// Spill an 8-lane accumulator, fold the ≤7-element tail into its
    /// lanes (element `full + j` belongs to lane `j` since `full` is a
    /// multiple of [`LANES`]), and reduce in the shared order.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn finish(acc: __m256, tail: impl Fn(usize) -> f32, full: usize, n: usize) -> f32 {
        let mut lanes = [0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for j in full..n {
            lanes[j - full] += tail(j);
        }
        reduce_lanes(&lanes)
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch on runtime detection).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < full {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
            i += LANES;
        }
        finish(acc, |j| a[j] * b[j], full, n)
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
        if have_f16c() {
            // SAFETY: F16C verified by have_f16c().
            return dot_f16_f16c(a, b);
        }
        let n = a.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < full {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let bv = decode8(b.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
            i += LANES;
        }
        finish(acc, |j| a[j] * f16_to_f32_fast(b[j]), full, n)
    }

    /// Table-decode 8 consecutive f16 words into a vector (the F16C-less
    /// fallback; exact, like the hardware widening).
    ///
    /// # Safety
    /// Requires AVX2; `p` must point at 8 readable `u16`s.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn decode8(p: *const u16) -> __m256 {
        let mut tmp = [0f32; LANES];
        for (j, t) in tmp.iter_mut().enumerate() {
            *t = f16_to_f32_fast(*p.add(j));
        }
        _mm256_loadu_ps(tmp.as_ptr())
    }

    /// # Safety
    /// Requires AVX2 and F16C.
    #[target_feature(enable = "avx2", enable = "f16c")]
    unsafe fn dot_f16_f16c(a: &[f32], b: &[u16]) -> f32 {
        let n = a.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < full {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let hv = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            let bv = _mm256_cvtph_ps(hv);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
            i += LANES;
        }
        finish(acc, |j| a[j] * f16_to_f32_fast(b[j]), full, n)
    }

    /// # Safety
    /// Requires AVX2; `w.len() == x.len() * 4`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32_le(w: &[u8], x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < full {
            // x86-64 is little-endian: the byte stream *is* the f32 array.
            let wv = _mm256_loadu_ps(w.as_ptr().add(i * 4) as *const f32);
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, xv));
            i += LANES;
        }
        finish(
            acc,
            |j| {
                let c = &w[j * 4..j * 4 + 4];
                f32::from_le_bytes([c[0], c[1], c[2], c[3]]) * x[j]
            },
            full,
            n,
        )
    }

    /// # Safety
    /// Requires AVX2; `w.len() == x.len() * 2`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f16_le(w: &[u8], x: &[f32]) -> f32 {
        // Little-endian byte pairs are exactly the u16 stream; the loads
        // below are unaligned, so no u16 alignment requirement exists.
        if have_f16c() {
            // SAFETY: F16C verified by have_f16c().
            return dot_f16_le_f16c(w, x);
        }
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < full {
            let mut tmp = [0f32; LANES];
            for (j, t) in tmp.iter_mut().enumerate() {
                let c = &w[(i + j) * 2..(i + j) * 2 + 2];
                *t = f16_to_f32_fast(u16::from_le_bytes([c[0], c[1]]));
            }
            let wv = _mm256_loadu_ps(tmp.as_ptr());
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, xv));
            i += LANES;
        }
        finish(
            acc,
            |j| {
                let c = &w[j * 2..j * 2 + 2];
                f16_to_f32_fast(u16::from_le_bytes([c[0], c[1]])) * x[j]
            },
            full,
            n,
        )
    }

    /// # Safety
    /// Requires AVX2 and F16C; `w.len() == x.len() * 2`.
    #[target_feature(enable = "avx2", enable = "f16c")]
    unsafe fn dot_f16_le_f16c(w: &[u8], x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < full {
            let hv = _mm_loadu_si128(w.as_ptr().add(i * 2) as *const __m128i);
            let wv = _mm256_cvtph_ps(hv);
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, xv));
            i += LANES;
        }
        finish(
            acc,
            |j| {
                let c = &w[j * 2..j * 2 + 2];
                f16_to_f32_fast(u16::from_le_bytes([c[0], c[1]])) * x[j]
            },
            full,
            n,
        )
    }

    /// # Safety
    /// Requires AVX2; `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let full = n & !(LANES - 1);
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i < full {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
            i += LANES;
        }
        for j in full..n {
            y[j] += alpha * x[j];
        }
    }

    /// # Safety
    /// Requires AVX2; `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f16(alpha: f32, x: &[u16], y: &mut [f32]) {
        if have_f16c() {
            // SAFETY: F16C verified by have_f16c().
            return axpy_f16_f16c(alpha, x, y);
        }
        let n = x.len();
        let full = n & !(LANES - 1);
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i < full {
            let xv = decode8(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
            i += LANES;
        }
        for j in full..n {
            y[j] += alpha * f16_to_f32_fast(x[j]);
        }
    }

    /// # Safety
    /// Requires AVX2 and F16C; `x.len() == y.len()`.
    #[target_feature(enable = "avx2", enable = "f16c")]
    unsafe fn axpy_f16_f16c(alpha: f32, x: &[u16], y: &mut [f32]) {
        let n = x.len();
        let full = n & !(LANES - 1);
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i < full {
            let hv = _mm_loadu_si128(x.as_ptr().add(i) as *const __m128i);
            let xv = _mm256_cvtph_ps(hv);
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
            i += LANES;
        }
        for j in full..n {
            y[j] += alpha * f16_to_f32_fast(x[j]);
        }
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sum_squares(x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < full {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, xv));
            i += LANES;
        }
        finish(acc, |j| x[j] * x[j], full, n)
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn max_val(x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut i = 0;
        while i < full {
            acc = _mm256_max_ps(acc, _mm256_loadu_ps(x.as_ptr().add(i)));
            i += LANES;
        }
        let mut lanes = [0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut m = lanes.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for &v in &x[full..] {
            m = m.max(v);
        }
        m
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < full {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(x.as_ptr().add(i)));
            i += LANES;
        }
        finish(acc, |j| x[j], full, n)
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(x: &mut [f32], s: f32) {
        let n = x.len();
        let full = n & !(LANES - 1);
        let sv = _mm256_set1_ps(s);
        let mut i = 0;
        while i < full {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_mul_ps(xv, sv));
            i += LANES;
        }
        for v in &mut x[full..] {
            *v *= s;
        }
    }

    /// # Safety
    /// Requires AVX2; slices share one length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_gain(x: &[f32], inv: f32, gain: &[f32], out: &mut [f32]) {
        let n = x.len();
        let full = n & !(LANES - 1);
        let iv = _mm256_set1_ps(inv);
        let mut i = 0;
        while i < full {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let gv = _mm256_loadu_ps(gain.as_ptr().add(i));
            let r = _mm256_mul_ps(_mm256_mul_ps(xv, iv), gv);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += LANES;
        }
        for j in full..n {
            out[j] = (x[j] * inv) * gain[j];
        }
    }

    /// # Safety
    /// Requires AVX2; `head.len() == 2 * sin.len() == 2 * cos.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn rope_rotate(head: &mut [f32], sin: &[f32], cos: &[f32]) {
        let np = sin.len();
        let full_pairs = np & !3; // 4 pairs = 8 floats per iteration
        let mut p = 0;
        while p < full_pairs {
            // Duplicate each pair's sin/cos across its two lanes; negate
            // the even lane's sin so one add computes both rotations:
            //   even: a*c + b*(-s)   odd: b*c + a*s
            let mut cd = [0f32; LANES];
            let mut sd = [0f32; LANES];
            for j in 0..4 {
                cd[2 * j] = cos[p + j];
                cd[2 * j + 1] = cos[p + j];
                sd[2 * j] = -sin[p + j];
                sd[2 * j + 1] = sin[p + j];
            }
            let xv = _mm256_loadu_ps(head.as_ptr().add(2 * p));
            let swapped = _mm256_permute_ps::<0b1011_0001>(xv); // [b0 a0 b1 a1 ...]
            let cv = _mm256_loadu_ps(cd.as_ptr());
            let sv = _mm256_loadu_ps(sd.as_ptr());
            let r = _mm256_add_ps(_mm256_mul_ps(xv, cv), _mm256_mul_ps(swapped, sv));
            _mm256_storeu_ps(head.as_mut_ptr().add(2 * p), r);
            p += 4;
        }
        for j in full_pairs..np {
            let (a, b) = (head[2 * j], head[2 * j + 1]);
            head[2 * j] = a * cos[j] - b * sin[j];
            head[2 * j + 1] = a * sin[j] + b * cos[j];
        }
    }

    /// # Safety
    /// Requires AVX2; slices share one length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn silu_mul(gate: &[f32], up: &[f32], out: &mut [f32]) {
        let n = gate.len();
        let full = n & !(LANES - 1);
        let one = _mm256_set1_ps(1.0);
        let mut i = 0;
        while i < full {
            // exp stays scalar libm (vectorizing it would change bits).
            let mut e = [0f32; LANES];
            for (j, ev) in e.iter_mut().enumerate() {
                *ev = (-gate[i + j]).exp();
            }
            let gv = _mm256_loadu_ps(gate.as_ptr().add(i));
            let uv = _mm256_loadu_ps(up.as_ptr().add(i));
            let ev = _mm256_loadu_ps(e.as_ptr());
            let den = _mm256_add_ps(one, ev);
            let r = _mm256_mul_ps(_mm256_div_ps(gv, den), uv);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += LANES;
        }
        for j in full..n {
            out[j] = (gate[j] / (1.0 + (-gate[j]).exp())) * up[j];
        }
    }
}

// ---- NEON tier ---------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{f16_to_f32_fast, reduce_lanes, LANES};
    use core::arch::aarch64::*;

    /// Spill the two 4-lane accumulators (lanes 0–3 / 4–7), fold the tail
    /// into its lanes, and reduce in the shared order.
    ///
    /// # Safety
    /// Requires NEON.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn finish(
        lo: float32x4_t,
        hi: float32x4_t,
        tail: impl Fn(usize) -> f32,
        full: usize,
        n: usize,
    ) -> f32 {
        let mut lanes = [0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        for j in full..n {
            lanes[j - full] += tail(j);
        }
        reduce_lanes(&lanes)
    }

    /// # Safety
    /// Requires NEON (callers dispatch on runtime detection).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let full = n & !(LANES - 1);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < full {
            // Separate mul + add (never vfmaq: FMA would skip the
            // intermediate rounding the scalar path performs).
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i))));
            hi = vaddq_f32(
                hi,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i + 4)), vld1q_f32(b.as_ptr().add(i + 4))),
            );
            i += LANES;
        }
        finish(lo, hi, |j| a[j] * b[j], full, n)
    }

    /// Table-decode 4 consecutive f16 words into a vector (exact IEEE
    /// widening, same bits as the scalar path).
    ///
    /// # Safety
    /// Requires NEON; `p` must point at 4 readable `u16`s.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn decode4(p: *const u16) -> float32x4_t {
        let tmp = [
            f16_to_f32_fast(*p),
            f16_to_f32_fast(*p.add(1)),
            f16_to_f32_fast(*p.add(2)),
            f16_to_f32_fast(*p.add(3)),
        ];
        vld1q_f32(tmp.as_ptr())
    }

    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
        let n = a.len();
        let full = n & !(LANES - 1);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < full {
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(a.as_ptr().add(i)), decode4(b.as_ptr().add(i))));
            hi = vaddq_f32(
                hi,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i + 4)), decode4(b.as_ptr().add(i + 4))),
            );
            i += LANES;
        }
        finish(lo, hi, |j| a[j] * f16_to_f32_fast(b[j]), full, n)
    }

    /// # Safety
    /// Requires NEON; `w.len() == x.len() * 4`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32_le(w: &[u8], x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < full {
            // AArch64 is little-endian: the byte stream is the f32 array
            // (vld1q_f32 has no alignment requirement).
            let w0 = vld1q_f32(w.as_ptr().add(i * 4) as *const f32);
            let w1 = vld1q_f32(w.as_ptr().add((i + 4) * 4) as *const f32);
            lo = vaddq_f32(lo, vmulq_f32(w0, vld1q_f32(x.as_ptr().add(i))));
            hi = vaddq_f32(hi, vmulq_f32(w1, vld1q_f32(x.as_ptr().add(i + 4))));
            i += LANES;
        }
        finish(
            lo,
            hi,
            |j| {
                let c = &w[j * 4..j * 4 + 4];
                f32::from_le_bytes([c[0], c[1], c[2], c[3]]) * x[j]
            },
            full,
            n,
        )
    }

    /// # Safety
    /// Requires NEON; `w.len() == x.len() * 2`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f16_le(w: &[u8], x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let decode_at = |j: usize| {
            let c = &w[j * 2..j * 2 + 2];
            f16_to_f32_fast(u16::from_le_bytes([c[0], c[1]]))
        };
        let mut i = 0;
        while i < full {
            let w0 = [decode_at(i), decode_at(i + 1), decode_at(i + 2), decode_at(i + 3)];
            let w1 = [decode_at(i + 4), decode_at(i + 5), decode_at(i + 6), decode_at(i + 7)];
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(w0.as_ptr()), vld1q_f32(x.as_ptr().add(i))));
            hi = vaddq_f32(hi, vmulq_f32(vld1q_f32(w1.as_ptr()), vld1q_f32(x.as_ptr().add(i + 4))));
            i += LANES;
        }
        finish(lo, hi, |j| decode_at(j) * x[j], full, n)
    }

    /// # Safety
    /// Requires NEON; `x.len() == y.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let full = n & !3;
        let av = vdupq_n_f32(alpha);
        let mut i = 0;
        while i < full {
            let yv = vld1q_f32(y.as_ptr().add(i));
            let xv = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, vmulq_f32(av, xv)));
            i += 4;
        }
        for j in full..n {
            y[j] += alpha * x[j];
        }
    }

    /// # Safety
    /// Requires NEON; `x.len() == y.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f16(alpha: f32, x: &[u16], y: &mut [f32]) {
        let n = x.len();
        let full = n & !3;
        let av = vdupq_n_f32(alpha);
        let mut i = 0;
        while i < full {
            let yv = vld1q_f32(y.as_ptr().add(i));
            let xv = decode4(x.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, vmulq_f32(av, xv)));
            i += 4;
        }
        for j in full..n {
            y[j] += alpha * f16_to_f32_fast(x[j]);
        }
    }

    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn sum_squares(x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < full {
            let x0 = vld1q_f32(x.as_ptr().add(i));
            let x1 = vld1q_f32(x.as_ptr().add(i + 4));
            lo = vaddq_f32(lo, vmulq_f32(x0, x0));
            hi = vaddq_f32(hi, vmulq_f32(x1, x1));
            i += LANES;
        }
        finish(lo, hi, |j| x[j] * x[j], full, n)
    }

    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn max_val(x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !3;
        let mut acc = vdupq_n_f32(f32::NEG_INFINITY);
        let mut i = 0;
        while i < full {
            acc = vmaxq_f32(acc, vld1q_f32(x.as_ptr().add(i)));
            i += 4;
        }
        let mut lanes = [0f32; 4];
        vst1q_f32(lanes.as_mut_ptr(), acc);
        let mut m = lanes.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for &v in &x[full..] {
            m = m.max(v);
        }
        m
    }

    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let full = n & !(LANES - 1);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < full {
            lo = vaddq_f32(lo, vld1q_f32(x.as_ptr().add(i)));
            hi = vaddq_f32(hi, vld1q_f32(x.as_ptr().add(i + 4)));
            i += LANES;
        }
        finish(lo, hi, |j| x[j], full, n)
    }

    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale(x: &mut [f32], s: f32) {
        let n = x.len();
        let full = n & !3;
        let sv = vdupq_n_f32(s);
        let mut i = 0;
        while i < full {
            let xv = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(x.as_mut_ptr().add(i), vmulq_f32(xv, sv));
            i += 4;
        }
        for v in &mut x[full..] {
            *v *= s;
        }
    }

    /// # Safety
    /// Requires NEON; slices share one length.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale_gain(x: &[f32], inv: f32, gain: &[f32], out: &mut [f32]) {
        let n = x.len();
        let full = n & !3;
        let iv = vdupq_n_f32(inv);
        let mut i = 0;
        while i < full {
            let xv = vld1q_f32(x.as_ptr().add(i));
            let gv = vld1q_f32(gain.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(vmulq_f32(xv, iv), gv));
            i += 4;
        }
        for j in full..n {
            out[j] = (x[j] * inv) * gain[j];
        }
    }

    /// # Safety
    /// Requires NEON; `head.len() == 2 * sin.len() == 2 * cos.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn rope_rotate(head: &mut [f32], sin: &[f32], cos: &[f32]) {
        let np = sin.len();
        let full_pairs = np & !1; // 2 pairs = 4 floats per iteration
        let mut p = 0;
        while p < full_pairs {
            // even: a*c + b*(-s)   odd: b*c + a*s (see the AVX2 tier).
            let cd = [cos[p], cos[p], cos[p + 1], cos[p + 1]];
            let sd = [-sin[p], sin[p], -sin[p + 1], sin[p + 1]];
            let xv = vld1q_f32(head.as_ptr().add(2 * p));
            let swapped = vrev64q_f32(xv); // [b0 a0 b1 a1]
            let r = vaddq_f32(vmulq_f32(xv, vld1q_f32(cd.as_ptr())), vmulq_f32(swapped, vld1q_f32(sd.as_ptr())));
            vst1q_f32(head.as_mut_ptr().add(2 * p), r);
            p += 2;
        }
        for j in full_pairs..np {
            let (a, b) = (head[2 * j], head[2 * j + 1]);
            head[2 * j] = a * cos[j] - b * sin[j];
            head[2 * j + 1] = a * sin[j] + b * cos[j];
        }
    }

    /// # Safety
    /// Requires NEON; slices share one length.
    #[target_feature(enable = "neon")]
    pub unsafe fn silu_mul(gate: &[f32], up: &[f32], out: &mut [f32]) {
        let n = gate.len();
        let full = n & !3;
        let one = vdupq_n_f32(1.0);
        let mut i = 0;
        while i < full {
            // exp stays scalar libm (vectorizing it would change bits).
            let e = [
                (-gate[i]).exp(),
                (-gate[i + 1]).exp(),
                (-gate[i + 2]).exp(),
                (-gate[i + 3]).exp(),
            ];
            let gv = vld1q_f32(gate.as_ptr().add(i));
            let uv = vld1q_f32(up.as_ptr().add(i));
            let den = vaddq_f32(one, vld1q_f32(e.as_ptr()));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(vdivq_f32(gv, den), uv));
            i += 4;
        }
        for j in full..n {
            out[j] = (gate[j] / (1.0 + (-gate[j]).exp())) * up[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{available_levels, with_level, SimdLevel};
    use super::*;
    use crate::util::{f32_to_f16, Rng};

    fn vecf(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    /// Every primitive, every available level, across ragged lengths:
    /// vector output must equal the scalar reference bit-for-bit.
    #[test]
    fn all_primitives_match_scalar_bitwise() {
        let mut rng = Rng::new(42);
        for n in [1usize, 7, 8, 9, 16, 63, 64, 65, 200] {
            let a = vecf(&mut rng, n);
            let b = vecf(&mut rng, n);
            let h: Vec<u16> = b.iter().map(|&v| f32_to_f16(v)).collect();
            let wb: Vec<u8> = b.iter().flat_map(|v| v.to_le_bytes()).collect();
            let hb: Vec<u8> = h.iter().flat_map(|v| v.to_le_bytes()).collect();
            let gain = vecf(&mut rng, n);
            let np = n / 2;
            let sin: Vec<f32> = (0..np).map(|i| (i as f32 * 0.37).sin()).collect();
            let cos: Vec<f32> = (0..np).map(|i| (i as f32 * 0.37).cos()).collect();

            let reference = with_level(SimdLevel::Scalar, || {
                let mut y = gain.clone();
                axpy_f32(0.7, &a, &mut y);
                let mut y16 = gain.clone();
                axpy_f16(0.7, &h, &mut y16);
                let mut sc = a.clone();
                scale(&mut sc, 1.25);
                let mut sg = vec![0f32; n];
                scale_gain(&a, 0.5, &gain, &mut sg);
                let mut rot = a[..2 * np].to_vec();
                rope_rotate(&mut rot, &sin, &cos);
                let mut sm = vec![0f32; n];
                silu_mul(&a, &b, &mut sm);
                // Nested tuples: std only implements Eq/Debug up to arity 12.
                (
                    (
                        dot_f32(&a, &b),
                        dot_f16(&a, &h),
                        dot_f32_le(&wb, &a),
                        dot_f16_le(&hb, &a),
                        sum_squares(&a),
                        max_val(&a),
                        sum(&a),
                    ),
                    (y, y16, sc, sg, rot, sm),
                )
            });
            for level in available_levels() {
                let got = with_level(level, || {
                    let mut y = gain.clone();
                    axpy_f32(0.7, &a, &mut y);
                    let mut y16 = gain.clone();
                    axpy_f16(0.7, &h, &mut y16);
                    let mut sc = a.clone();
                    scale(&mut sc, 1.25);
                    let mut sg = vec![0f32; n];
                    scale_gain(&a, 0.5, &gain, &mut sg);
                    let mut rot = a[..2 * np].to_vec();
                    rope_rotate(&mut rot, &sin, &cos);
                    let mut sm = vec![0f32; n];
                    silu_mul(&a, &b, &mut sm);
                    (
                        (
                            dot_f32(&a, &b),
                            dot_f16(&a, &h),
                            dot_f32_le(&wb, &a),
                            dot_f16_le(&hb, &a),
                            sum_squares(&a),
                            max_val(&a),
                            sum(&a),
                        ),
                        (y, y16, sc, sg, rot, sm),
                    )
                });
                assert_eq!(got, reference, "n={n} level={}", level.name());
            }
        }
    }

    #[test]
    fn dot_matches_f64_reference_closely() {
        let mut rng = Rng::new(7);
        let a = vecf(&mut rng, 301);
        let b = vecf(&mut rng, 301);
        let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert!((dot_f32(&a, &b) as f64 - want).abs() < 1e-3);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(dot_f32(&[], &[]), 0.0);
        assert_eq!(sum(&[]), 0.0);
        assert_eq!(max_val(&[]), f32::NEG_INFINITY);
        assert_eq!(dot_f32(&[2.0], &[3.0]), 6.0);
    }
}
