//! Bandwidth roofline model for decode tokens/s — the analytic engine
//! behind paper Fig. 9 (ELUT potential vs bandwidth) and the Table 7
//! layer-composition estimates for model sizes that do not fit in RAM.
//!
//! Decode is memory-bound: a token cannot be produced faster than the
//! packed weights (plus LUT traffic) can be streamed, nor faster than the
//! compute side can consume them:
//!
//! `t_token = max(bytes / BW, ops / throughput) + overhead`

/// One kernel's per-token cost model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Bytes streamed per token (weights + tables).
    pub bytes_per_token: f64,
    /// Scalar-equivalent compute ops per token.
    pub ops_per_token: f64,
    /// Fixed per-token overhead seconds (attention, norms, sampling).
    pub overhead_s: f64,
}

impl CostModel {
    /// Tokens/s under the roofline with `bw_gbps` memory bandwidth and
    /// `gops` compute throughput (giga-ops/s).
    pub fn tokens_per_second(&self, bw_gbps: f64, gops: f64) -> f64 {
        let t_mem = self.bytes_per_token / (bw_gbps * 1e9);
        let t_cmp = self.ops_per_token / (gops * 1e9);
        1.0 / (t_mem.max(t_cmp) + self.overhead_s)
    }

    /// The bandwidth (GB/s) beyond which this kernel turns compute-bound —
    /// the knee of the Fig. 9 curve.
    pub fn memory_bound_knee_gbps(&self, gops: f64) -> f64 {
        if self.ops_per_token <= 0.0 {
            return f64::INFINITY;
        }
        self.bytes_per_token * gops / self.ops_per_token
    }
}

/// Build a decode cost model from a model's ternary parameter count and a
/// kernel's bpw + per-weight op cost.
pub fn decode_cost_model(
    ternary_params: f64,
    head_params: f64,
    bpw: f64,
    ops_per_weight: f64,
    lut_bytes_per_weight: f64,
    overhead_s: f64,
) -> CostModel {
    CostModel {
        bytes_per_token: ternary_params * (bpw / 8.0 + lut_bytes_per_weight)
            + head_params * 2.0, // f16 LM head
        ops_per_token: (ternary_params + head_params) * ops_per_weight,
        overhead_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_1b(bpw: f64, opw: f64) -> CostModel {
        decode_cost_model(1e9, 5e7, bpw, opw, 0.0, 0.0)
    }

    #[test]
    fn lower_bpw_is_faster_when_memory_bound() {
        let tl2 = model_1b(1.67, 1.0 / 3.0);
        let tmac = model_1b(2.0, 0.5);
        let bw = 50.0; // GB/s, low-bandwidth edge CPU
        let gops = 100.0;
        assert!(tl2.tokens_per_second(bw, gops) > tmac.tokens_per_second(bw, gops));
    }

    #[test]
    fn bandwidth_scaling_saturates_at_compute() {
        let m = model_1b(1.67, 1.0 / 3.0);
        let low = m.tokens_per_second(10.0, 100.0);
        let mid = m.tokens_per_second(100.0, 100.0);
        let hi = m.tokens_per_second(10_000.0, 100.0);
        let hi2 = m.tokens_per_second(100_000.0, 100.0);
        assert!(mid > low * 5.0, "linear region");
        assert!(hi2 / hi < 1.01, "saturated past the knee");
    }

    #[test]
    fn knee_moves_with_compute_cost() {
        // MAD (1 op/weight) goes compute-bound at lower bandwidth than
        // ELUT (1/3 op/weight): that's the ELUT headroom argument (Fig. 9).
        let mad = model_1b(2.0, 1.0);
        let elut = model_1b(1.67, 1.0 / 3.0);
        assert!(elut.memory_bound_knee_gbps(100.0) > mad.memory_bound_knee_gbps(100.0));
    }

    #[test]
    fn float16_vs_ternary_ratio_matches_paper_scale() {
        // Paper Fig. 1: I2_S ~6x over Float16 at equal bandwidth — byte
        // ratio 16/2 = 8 bounds it; overheads bring it to ~6. Check the
        // model reproduces the bytes-driven ordering.
        let f16 = model_1b(16.0, 1.0);
        let i2s = model_1b(2.0, 1.0);
        let bw = 60.0;
        let ratio = i2s.tokens_per_second(bw, 200.0) / f16.tokens_per_second(bw, 200.0);
        assert!(ratio > 4.0 && ratio <= 8.5, "ratio {ratio}");
    }
}
