//! SIMD-primitive emulations for the instruction-level studies:
//!
//! * paper Table 4 — the LUT path's core op (`vpshufb`-style 16-byte
//!   shuffle) vs the MAD path's (`maddubs`-style multiply-add), plus the
//!   full TBL+ADD+CVT sequence whose extra latency the paper measures;
//! * paper Fig. 11 — "what if registers were wider": shuffle emulations at
//!   16/32/64/128-byte widths, showing latency grows sub-linearly while
//!   the covered group size g grows, so wider registers pay off until
//!   `C^g ≈ M`.
//!
//! These are written as fixed-width array ops that LLVM vectorizes; they
//! measure *relative* costs on this CPU, standing in for the paper's
//! AVX2/NEON microbenchmarks.

/// 16-byte table shuffle: `out[i] = table[idx[i] & 0x0f]` — the exact
/// semantics of AVX2 `vpshufb` (restricted to the low nibble).
#[inline]
pub fn shuffle16(table: &[i8; 16], idx: &[u8; 16]) -> [i8; 16] {
    let mut out = [0i8; 16];
    for i in 0..16 {
        out[i] = table[(idx[i] & 0x0f) as usize];
    }
    out
}

/// Generic-width shuffle over W-byte lanes of 16-entry tables (each lane
/// has its own table) — the Fig. 11 "longer register" emulation: a
/// hypothetical W-byte `vpshufb` doing W parallel lookups.
#[inline]
pub fn shuffle_w<const W: usize>(tables: &[i8], idx: &[u8; W]) -> [i8; W] {
    debug_assert_eq!(tables.len(), W);
    let mut out = [0i8; W];
    for i in 0..W {
        // Lane-local 16-entry table: lane i reads tables[(i/16)*16 + nib].
        out[i] = tables[(i & !0x0f) | (idx[i] & 0x0f) as usize];
    }
    out
}

/// `maddubs`-style MAD: 16 u8×i8 products, pairwise-added into 8 i16 —
/// the MAD path's core instruction (AVX2 `_mm256_maddubs_epi16` halved to
/// 128-bit for symmetry with the 128-bit TBL).
#[inline]
pub fn maddubs16(a: &[u8; 16], b: &[i8; 16]) -> [i16; 8] {
    let mut out = [0i16; 8];
    for i in 0..8 {
        out[i] = (a[2 * i] as i16 * b[2 * i] as i16)
            .wrapping_add(a[2 * i + 1] as i16 * b[2 * i + 1] as i16);
    }
    out
}

/// 8-lane i16 add (the ADD of the TBL+ADD+CVT sequence).
#[inline]
pub fn add16(a: &[i16; 8], b: &[i16; 8]) -> [i16; 8] {
    let mut out = [0i16; 8];
    for i in 0..8 {
        out[i] = a[i].wrapping_add(b[i]);
    }
    out
}

/// Sign-extend conversion i8→i16 of the low 8 lanes (the CVT step).
#[inline]
pub fn cvt_i8_i16(a: &[i8; 16]) -> [i16; 8] {
    let mut out = [0i16; 8];
    for i in 0..8 {
        out[i] = a[i] as i16;
    }
    out
}

/// The full LUT-path primitive the paper times as TBL+ADD+CVT: one
/// shuffle, widen, accumulate.
#[inline]
pub fn tbl_add_cvt(table: &[i8; 16], idx: &[u8; 16], acc: &[i16; 8]) -> [i16; 8] {
    let looked = shuffle16(table, idx);
    let widened = cvt_i8_i16(&looked);
    add16(acc, &widened)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_semantics() {
        let mut table = [0i8; 16];
        for (i, t) in table.iter_mut().enumerate() {
            *t = (i as i8) * 3 - 8;
        }
        let idx: [u8; 16] = [0, 15, 3, 7, 1, 2, 4, 5, 6, 8, 9, 10, 11, 12, 13, 14];
        let out = shuffle16(&table, &idx);
        for i in 0..16 {
            assert_eq!(out[i], table[idx[i] as usize]);
        }
    }

    #[test]
    fn shuffle_masks_high_bits() {
        let table: [i8; 16] = core::array::from_fn(|i| i as i8);
        let idx = [0xf3u8; 16];
        assert!(shuffle16(&table, &idx).iter().all(|&v| v == 3));
    }

    #[test]
    fn shuffle_w_matches_shuffle16_at_w16() {
        let table: [i8; 16] = core::array::from_fn(|i| (i as i8) - 5);
        let idx: [u8; 16] = core::array::from_fn(|i| (i * 7 % 16) as u8);
        assert_eq!(shuffle_w::<16>(&table, &idx), shuffle16(&table, &idx));
    }

    #[test]
    fn maddubs_matches_scalar() {
        let a: [u8; 16] = core::array::from_fn(|i| (i * 3) as u8);
        let b: [i8; 16] = core::array::from_fn(|i| (i as i8) - 7);
        let out = maddubs16(&a, &b);
        for i in 0..8 {
            let want =
                a[2 * i] as i16 * b[2 * i] as i16 + a[2 * i + 1] as i16 * b[2 * i + 1] as i16;
            assert_eq!(out[i], want);
        }
    }

    #[test]
    fn tbl_add_cvt_accumulates() {
        let table: [i8; 16] = core::array::from_fn(|i| i as i8);
        let idx: [u8; 16] = core::array::from_fn(|i| i as u8);
        let acc = [100i16; 8];
        let out = tbl_add_cvt(&table, &idx, &acc);
        for i in 0..8 {
            assert_eq!(out[i], 100 + i as i16);
        }
    }
}
