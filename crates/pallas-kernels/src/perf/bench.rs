//! Mini-criterion: warmup + timed iterations + summary statistics, with a
//! `black_box` to defeat dead-code elimination. All paper-table benches
//! are built on this.

use pallas_core::util::Summary;
use std::time::{Duration, Instant};

/// One benchmark's outcome.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time summary (seconds).
    pub seconds: Summary,
    pub iterations: usize,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.seconds.mean
    }
    pub fn p50_s(&self) -> f64 {
        self.seconds.p50
    }
    /// Iterations per second at the mean.
    pub fn throughput(&self) -> f64 {
        if self.seconds.mean > 0.0 {
            1.0 / self.seconds.mean
        } else {
            0.0
        }
    }
    pub fn report(&self) -> String {
        let m = self.seconds.mean;
        let (scale, unit) = if m >= 1.0 {
            (1.0, "s")
        } else if m >= 1e-3 {
            (1e3, "ms")
        } else if m >= 1e-6 {
            (1e6, "µs")
        } else {
            (1e9, "ns")
        };
        format!(
            "{:<32} {:>9.3}{} ±{:>6.1}% (n={})",
            self.name,
            m * scale,
            unit,
            if m > 0.0 { self.seconds.std / m * 100.0 } else { 0.0 },
            self.iterations
        )
    }
}

/// Prevent the optimizer from eliding a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Run `f` repeatedly: warm up for `warmup`, then time iterations until
/// `measure` wall time has elapsed (at least 3 iterations).
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    // Warmup.
    let t0 = Instant::now();
    while t0.elapsed() < warmup {
        f();
    }
    // Measure.
    let mut samples = Vec::new();
    let t1 = Instant::now();
    while t1.elapsed() < measure || samples.len() < 3 {
        let s = Instant::now();
        f();
        samples.push(s.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        seconds: Summary::from_samples(&samples),
        iterations: samples.len(),
    }
}

/// Convenience: short bench with default budgets (50ms warmup / 300ms
/// measure) — the profile used by the paper-table benches so a full sweep
/// stays in CI budget.
pub fn bench_quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, Duration::from_millis(50), Duration::from_millis(300), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_sleep() {
        let r = bench(
            "sleep1ms",
            Duration::from_millis(5),
            Duration::from_millis(60),
            || std::thread::sleep(Duration::from_millis(1)),
        );
        assert!(r.seconds.mean >= 0.001, "mean {}", r.seconds.mean);
        assert!(r.seconds.mean < 0.01, "mean {}", r.seconds.mean);
        assert!(r.iterations >= 3);
    }

    #[test]
    fn throughput_inverts_mean() {
        let r = bench_quick("noop", || {
            black_box(1 + 1);
        });
        assert!(r.throughput() > 1000.0);
    }

    #[test]
    fn report_formats() {
        let r = bench_quick("fmt", || {
            black_box(0);
        });
        let s = r.report();
        assert!(s.contains("fmt"));
    }
}
