//! Kernel calibration: measured GEMV throughput on an out-of-cache
//! working set, used to compose the Table 7 / Figure 1 estimates for
//! model sizes that cannot be hosted dense (see DESIGN.md
//! §Substitutions — the paper's own N/A entries are the same phenomenon).

use crate::kernels::quant::TernaryWeights;
use crate::kernels::{kernel_for, matmul_prepared, PreparedActivations, QuantType};
use pallas_core::threadpool::ThreadPool;
use pallas_core::util::Rng;
use std::time::Instant;

/// How many accumulation passes one preparation is amortized over in the
/// micro-benchmark. Billing the full prepare cost to every matmul would
/// over-charge LUT kernels relative to how the model actually runs them
/// (the tuner would pick the wrong winners); billing qkv's 3-way sharing
/// everywhere would under-charge the roles that never share (o, down).
/// The model's per-layer average is 7 matmuls per 4 preparations
/// (qkv: 3 matmuls / 1 prepare, gate+up: 2/1, o: 1/1, down: 1/1) ≈ 2.
pub const PREPARE_REUSE: usize = 2;

/// Measured per-kernel GEMV throughput.
#[derive(Clone, Copy, Debug)]
pub struct KernelRate {
    pub qtype: QuantType,
    /// Packed weight bytes consumed per second of GEMV.
    pub weight_bytes_per_s: f64,
    /// Weights (elements) consumed per second.
    pub weights_per_s: f64,
    /// Achieved bits per weight of the packed tensor.
    pub bpw: f64,
}

impl KernelRate {
    /// Measured wall time of one `m`×`k` matmul (any batch), derived from
    /// the weight-streaming rate.
    pub fn secs_per_matmul(&self, m: usize, k: usize) -> f64 {
        (m * k) as f64 / self.weights_per_s
    }
}

/// Calibrate one kernel on an `m`×`k` GEMV with `pool` threads.
/// The working set should exceed LLC so rates are memory-realistic
/// (default shape 8192×8192 ≈ 17–134 MB depending on bpw).
pub fn calibrate_kernel(
    qtype: QuantType,
    m: usize,
    k: usize,
    pool: &ThreadPool,
    min_iters: usize,
) -> KernelRate {
    calibrate_kernel_shape(qtype, m, k, 1, pool, min_iters, 0.2)
}

/// Calibrate one kernel on an `m`×`k` matmul over an `n`-row activation
/// batch — the generalized entry point the auto-tuner
/// ([`crate::kernels::tuner`]) sweeps over (m, k, batch, threads) shapes.
///
/// Rates are *per matmul* regardless of `n`: weights stream once per call,
/// so `weights_per_s = m·k / secs_per_call`. Batched calls amortize that
/// stream over `n` rows, which is exactly the effect batch-aware tuning
/// needs to observe.
///
/// Preprocessing is billed **amortized**, matching the model's
/// prepare-once pipeline: each timed iteration runs one preparation and
/// [`PREPARE_REUSE`] accumulation passes over it (the per-layer average
/// sharing factor), with the prepare workspace reused across iterations
/// so the measurement captures the allocation-free steady state. Measures at
/// least `min_iters` iterations and at least `min_seconds` of wall time
/// (capped at 10k iterations).
pub fn calibrate_kernel_shape(
    qtype: QuantType,
    m: usize,
    k: usize,
    n: usize,
    pool: &ThreadPool,
    min_iters: usize,
    min_seconds: f64,
) -> KernelRate {
    let mut rng = Rng::new(0xCA11);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    calibrate_with_weights(qtype, q, m, k, n, pool, min_iters, min_seconds)
}

/// [`calibrate_kernel_shape`] on a *block-sparse* synthetic tensor: whole
/// column stripes are zeroed (the same columns across every row, ~60% of
/// the columns) so the kernel's block-skip layout has real blocks to
/// elide — iid ternary essentially never forms a whole zero block, so
/// the dense calibration tensor measures only the sparse path's
/// overhead, never its savings. Stripes are 384 columns wide where `k`
/// allows (384 is a common multiple of every kernel's block span: 64 for
/// TL1/ELUT, 128 for I2_S, 96 for TL2's three-weight region), narrowing
/// for small `k` so the pattern still alternates. The caller decides the
/// packing mode (the tuner forces [`crate::kernels::sparse::SparseMode::On`]
/// around this call).
pub fn calibrate_kernel_shape_sparse(
    qtype: QuantType,
    m: usize,
    k: usize,
    n: usize,
    pool: &ThreadPool,
    min_iters: usize,
    min_seconds: f64,
) -> KernelRate {
    let mut rng = Rng::new(0xCA11);
    let stripe = [384usize, 128, 64].into_iter().find(|&s| k >= 5 * s).unwrap_or(64);
    let q: Vec<i8> = (0..m * k)
        .map(|i| {
            // Stripe s is zeroed when s*3 mod 5 < 3: a period-5 pattern
            // zeroing 3 of every 5 stripes (60%), interleaved so zero
            // and nonzero stripes alternate rather than clump.
            let s = (i % k) / stripe;
            if s * 3 % 5 < 3 {
                0
            } else {
                rng.next_ternary() as i8
            }
        })
        .collect();
    calibrate_with_weights(qtype, q, m, k, n, pool, min_iters, min_seconds)
}

/// Shared measurement body: pack `q` (an `m`×`k` ternary tensor) with
/// `qtype` under the ambient sparse mode and time the prepare-amortized
/// matmul loop.
#[allow(clippy::too_many_arguments)]
fn calibrate_with_weights(
    qtype: QuantType,
    q: Vec<i8>,
    m: usize,
    k: usize,
    n: usize,
    pool: &ThreadPool,
    min_iters: usize,
    min_seconds: f64,
) -> KernelRate {
    let kern = kernel_for(qtype);
    let mut rng = Rng::new(0xAC71);
    let t = TernaryWeights::from_ternary(q, m, k, 0.05);
    let packed = kern.quantize(&t);
    let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
    let mut out = vec![0f32; n * m];
    let mut acts = PreparedActivations::new();
    // Warm (also sizes the reusable prepare buffers).
    acts.begin_input();
    {
        let batch = acts.get_or_prepare(kern, &x, k, n, pool);
        matmul_prepared(kern, &packed, batch, &x, n, &mut out, pool);
    }
    // Measure at least `min_iters` and at least `min_seconds` — but
    // always at least one iteration: with `min_iters == 0` and a tiny
    // `min_seconds` the loop could exit untaken, and the resulting 0/0
    // rate (NaN `weights_per_s`) would silently poison every downstream
    // comparison (NaN never sorts as a winner, NaN never loses one).
    let min_iters = min_iters.max(1);
    let t0 = Instant::now();
    let mut iters = 0usize;
    while iters < min_iters || t0.elapsed().as_secs_f64() < min_seconds {
        acts.begin_input();
        for _ in 0..PREPARE_REUSE {
            let batch = acts.get_or_prepare(kern, &x, k, n, pool);
            matmul_prepared(kern, &packed, batch, &x, n, &mut out, pool);
        }
        iters += 1;
        if iters > 10_000 {
            break;
        }
    }
    let secs = t0.elapsed().as_secs_f64() / (iters * PREPARE_REUSE) as f64;
    let bytes = packed.weight_bytes() as f64;
    KernelRate {
        qtype,
        weight_bytes_per_s: bytes / secs,
        weights_per_s: (m * k) as f64 / secs,
        bpw: packed.bits_per_weight(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_calibration_produces_sane_rates() {
        let pool = ThreadPool::new(1);
        let r = calibrate_kernel_shape(QuantType::I2S, 128, 256, 4, &pool, 2, 0.01);
        assert!(r.weights_per_s > 0.0, "{:?}", r);
        assert!(r.secs_per_matmul(128, 256) > 0.0);
        assert!((r.bpw - 2.0).abs() < 0.01);
    }

    #[test]
    fn zero_iteration_budget_still_measures_once() {
        // Regression: min_iters = 0 with a zero time budget used to exit
        // the timing loop untaken, dividing by zero iterations and
        // handing the tuner NaN rates.
        let pool = ThreadPool::new(1);
        let r = calibrate_kernel_shape(QuantType::I2S, 16, 128, 1, &pool, 0, 0.0);
        assert!(r.weights_per_s.is_finite() && r.weights_per_s > 0.0, "{:?}", r);
        assert!(r.weight_bytes_per_s.is_finite() && r.weight_bytes_per_s > 0.0, "{:?}", r);
        assert!(r.secs_per_matmul(16, 128).is_finite());
    }

    #[test]
    fn sparse_calibration_produces_sane_rates() {
        use crate::kernels::sparse::{self, SparseMode};
        let pool = ThreadPool::new(1);
        // k = 1920 is the smallest k that keeps the full 384-column
        // stripes; the mode is forced exactly as the tuner forces it.
        let r = sparse::with_mode(SparseMode::On, || {
            calibrate_kernel_shape_sparse(QuantType::I2S, 32, 1920, 1, &pool, 1, 0.0)
        });
        assert!(r.weights_per_s.is_finite() && r.weights_per_s > 0.0, "{:?}", r);
        assert!((r.bpw - 2.0).abs() < 0.25, "{:?}", r);
        // The forced-dense variant of the same tensor also measures.
        let d = sparse::with_mode(SparseMode::Off, || {
            calibrate_kernel_shape_sparse(QuantType::I2S, 32, 1920, 1, &pool, 1, 0.0)
        });
        assert!(d.weights_per_s.is_finite() && d.weights_per_s > 0.0, "{:?}", d);
    }

    #[test]
    fn calibration_produces_sane_rates() {
        let pool = ThreadPool::new(2);
        let r = calibrate_kernel(QuantType::I2S, 512, 1024, &pool, 3);
        assert!(r.weight_bytes_per_s > 1e6, "{:?}", r);
        assert!((r.bpw - 2.0).abs() < 0.01);
    }

}
