//! Memory-bandwidth meter — the PCM stand-in for the paper's Fig. 10
//! (token throughput vs measured bandwidth as threads scale).
//!
//! The paper reads bandwidth counters from Intel PCM; no such counters are
//! available here, so we measure two quantities ourselves:
//! * [`stream_read_gbps`] — achievable read bandwidth at a given thread
//!   count (a STREAM-like triad over a buffer ≫ LLC);
//! * [`KernelTraffic`] — the bytes a kernel *must* move per token
//!   (weights + LUT/activation traffic), which, divided by measured step
//!   time, gives the achieved-bandwidth curve plotted side-by-side with
//!   tokens/s.

use pallas_core::threadpool::ThreadPool;
use std::time::Instant;

/// Measure sustained read bandwidth (GB/s) with `pool`'s threads, reading
/// `mb` megabytes per pass, `passes` times.
pub fn stream_read_gbps(pool: &ThreadPool, mb: usize, passes: usize) -> f64 {
    let n = mb * 1024 * 1024 / 8;
    let buf: Vec<u64> = (0..n as u64).collect();
    let chunks = pool.size() * 4;
    let per = n / chunks;
    // One warm pass.
    run_pass(pool, &buf, chunks, per);
    let t0 = Instant::now();
    for _ in 0..passes {
        run_pass(pool, &buf, chunks, per);
    }
    let secs = t0.elapsed().as_secs_f64();
    (mb * passes) as f64 / 1024.0 / secs
}

fn run_pass(pool: &ThreadPool, buf: &[u64], chunks: usize, per: usize) {
    use std::sync::atomic::{AtomicU64, Ordering};
    let sink = AtomicU64::new(0);
    pool.parallel_for(chunks, |c| {
        let lo = c * per;
        let hi = ((c + 1) * per).min(buf.len());
        let mut acc = 0u64;
        for &v in &buf[lo..hi] {
            acc = acc.wrapping_add(v);
        }
        sink.fetch_xor(acc, Ordering::Relaxed);
    });
    std::hint::black_box(sink.into_inner());
}

/// Byte traffic of one decode step under a kernel (per-token bound).
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelTraffic {
    /// Packed weight bytes streamed.
    pub weight_bytes: u64,
    /// Activation / LUT bytes touched.
    pub act_bytes: u64,
}

impl KernelTraffic {
    pub fn total(&self) -> u64 {
        self.weight_bytes + self.act_bytes
    }

    /// Achieved bandwidth (GB/s) given a measured per-token time.
    pub fn achieved_gbps(&self, token_seconds: f64) -> f64 {
        if token_seconds <= 0.0 {
            return 0.0;
        }
        self.total() as f64 / 1e9 / token_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_is_positive_and_sane() {
        let pool = ThreadPool::new(2);
        let gbps = stream_read_gbps(&pool, 32, 2);
        assert!(gbps > 0.5, "gbps {gbps}");
        assert!(gbps < 10_000.0, "gbps {gbps}");
    }

    #[test]
    fn traffic_math() {
        let t = KernelTraffic { weight_bytes: 1_000_000_000, act_bytes: 0 };
        assert!((t.achieved_gbps(0.5) - 2.0).abs() < 1e-9);
        assert_eq!(t.achieved_gbps(0.0), 0.0);
    }
}
