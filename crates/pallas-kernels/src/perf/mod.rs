//! Performance measurement substrate: a criterion-like bench harness
//! (criterion is unavailable offline), a memory-bandwidth meter, SIMD
//! primitive emulations for the instruction-level studies (paper Table 4,
//! Fig. 11) and the bandwidth roofline model behind Fig. 9.

pub mod bandwidth;
pub mod calibrate;
pub mod bench;
pub mod roofline;
pub mod simd;

pub use bench::{bench, BenchResult};
