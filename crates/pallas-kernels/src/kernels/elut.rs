//! **ELUT** — the element-wise lookup-table mpGEMM generalized beyond
//! ternary weights (paper Appendix A–C): arbitrary weight cardinality C,
//! group size g, with mirror consolidation applied whenever the full code
//! space `C^g` exceeds the 16-entry shuffle width but the half space fits.
//!
//! Two instantiations ship as kernels:
//!
//! * **ELUT_C4** — C=4 (alphabet −2,−1,0,1), g=2 → full 16-entry table,
//!   2.0 bpw (paper Table 3 row C=4).
//! * **ELUT_C5** — C=5 (alphabet −2..2), g=2 → mirror-consolidated
//!   13-entry table + sign plane, 2.5 bpw (paper Table 3 row C=5).
//!
//! Ternary weights embed exactly into both alphabets, so these kernels are
//! drop-in (and, with int16 tables, training-scheme exact) on BitNet
//! models — empirical backing for the appendix claim that ELUT extends to
//! low-bit LLMs in general.

use super::lut::{code_count, decode_code, mirror_join, mirror_split, sign_apply_i32};
use super::quant::{quantize_act_int8_into, TernaryWeights};
use super::simd::{self, SimdLevel};
use super::sparse;
use super::tl1::{LUT_W, SPARSE_BLOCK_WEIGHTS};
use super::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};

/// Generic element-wise LUT kernel over a symmetric integer alphabet.
pub struct ElutKernel {
    pub qtype: QuantType,
    pub name: &'static str,
    /// Weight cardinality C.
    pub c: usize,
    /// Group size g.
    pub g: usize,
    /// The weight alphabet, ascending, `alphabet[i] = -alphabet[c-1-i]`
    /// when `mirror` is set.
    pub alphabet: &'static [i8],
    /// Mirror consolidation (sign plane + half table).
    pub mirror: bool,
}

/// C=4 instantiation (full table, no mirror).
pub static ELUT4: ElutKernel = ElutKernel {
    qtype: QuantType::Elut4,
    name: "ELUT_C4",
    c: 4,
    g: 2,
    alphabet: &[-2, -1, 0, 1],
    mirror: false,
};

/// C=5 instantiation (mirror-consolidated).
pub static ELUT5: ElutKernel = ElutKernel {
    qtype: QuantType::Elut5,
    name: "ELUT_C5",
    c: 5,
    g: 2,
    alphabet: &[-2, -1, 0, 1, 2],
    mirror: true,
};

impl ElutKernel {
    fn weights_per_byte_checks(&self) {
        debug_assert_eq!(self.g, 2, "shipped instantiations use g=2");
    }

    /// Bytes per row: nibble plane (+ sign plane when mirrored).
    fn row_bytes(&self, k: usize) -> usize {
        let groups = k / self.g;
        let idx = groups / 2; // 2 nibbles per byte
        if self.mirror {
            idx + groups / 8
        } else {
            idx
        }
    }
}

impl Kernel for ElutKernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: self.qtype,
            name: self.name,
            class: KernelClass::LutBased,
            element_wise: true,
            bpw: super::lut::elementwise_bpw(self.c, self.g),
            // int16 tables + per-tensor int8 activations ⇒ training-scheme
            // exact on any weights the alphabet represents (incl. ternary).
            lossless: true,
            k_multiple: if self.mirror { 16 } else { 4 },
            ternary_native: true,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        self.weights_per_byte_checks();
        let (m, k) = (w.m, w.k);
        assert_eq!(k % self.info().k_multiple, 0, "{} K alignment", self.name);
        let row_bytes = self.row_bytes(k);
        let groups = k / self.g;
        let mut data = vec![0u8; m * row_bytes];
        for r in 0..m {
            let row = w.row(r);
            let out = &mut data[r * row_bytes..(r + 1) * row_bytes];
            let (idx_plane, sign_plane) = out.split_at_mut(groups / 2);
            for (gi, pair) in row.chunks_exact(self.g).enumerate() {
                let code = super::lut::encode_code(pair, self.c, self.alphabet);
                let (sign, idx) = if self.mirror {
                    mirror_split(code, self.c, self.g)
                } else {
                    (0, code)
                };
                debug_assert!(idx < 16);
                if gi % 2 == 0 {
                    idx_plane[gi / 2] = idx as u8;
                } else {
                    idx_plane[gi / 2] |= (idx as u8) << 4;
                }
                if self.mirror {
                    sign_plane[gi / 8] |= sign << (gi % 8);
                }
            }
        }
        let bounds = sparse::uniform_bounds(k, SPARSE_BLOCK_WEIGHTS);
        let sparse = sparse::maybe_index(&w.q, m, k, &bounds);
        QTensor { qtype: self.qtype, m, k, data, scale: w.scale, sparse }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let groups = t.k / self.g;
        let row_bytes = self.row_bytes(t.k);
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
            let (idx_plane, sign_plane) = row.split_at(groups / 2);
            for gi in 0..groups {
                let nib = if gi % 2 == 0 { idx_plane[gi / 2] & 0xf } else { idx_plane[gi / 2] >> 4 };
                let code = if self.mirror {
                    let sign = (sign_plane[gi / 8] >> (gi % 8)) & 1;
                    mirror_join(sign, nib as usize, self.c, self.g)
                } else {
                    nib as usize
                };
                for w in decode_code(code, self.c, self.g, self.alphabet) {
                    out.push(w as f32 * t.scale);
                }
            }
        }
        out
    }

    fn prepare_kind(&self, k: usize) -> PrepareKind {
        PrepareKind::LutI16 { groups: k / self.g }
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        self.weights_per_byte_checks();
        let PreparedRowMut::LutI16 { aq, tables, scale } = dst else {
            panic!("ELUT expects a LutI16 destination");
        };
        let (s, _) = quantize_act_int8_into(x, aq);
        *scale = s;
        let groups = k / self.g;
        let entries = if self.mirror {
            super::lut::half_code_count(self.c, self.g)
        } else {
            code_count(self.c, self.g)
        };
        // Per-slot weight patterns (padding slots stay zero), decoded
        // once per call and shared by the scalar loop and the vector
        // builders so every tier tabulates the same enumeration.
        let mut w0 = [0i16; LUT_W];
        let mut w1 = [0i16; LUT_W];
        for slot_i in 0..entries {
            let code = if self.mirror { mirror_join(0, slot_i, self.c, self.g) } else { slot_i };
            let w = decode_code(code, self.c, self.g, self.alphabet);
            w0[slot_i] = w[0] as i16;
            w1[slot_i] = w[1] as i16;
        }
        #[cfg(target_arch = "x86_64")]
        if simd::active_level() == SimdLevel::Avx2 {
            // SAFETY: AVX2 verified by the active dispatch level; `aq`
            // holds g=2 quants per group and `tables` one LUT_W-entry
            // table per group.
            unsafe { simd::avx2::build_lut16_pair_tables(aq, &w0, &w1, tables) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if simd::active_level() == SimdLevel::Neon {
            // SAFETY: NEON verified by the active dispatch level; `aq`
            // holds g=2 quants per group and `tables` one LUT_W-entry
            // table per group.
            unsafe { simd::neon::build_lut16_pair_tables(aq, &w0, &w1, tables) };
            return;
        }
        tables.fill(0);
        for gi in 0..groups {
            let a0 = aq[self.g * gi] as i16;
            let a1 = aq[self.g * gi + 1] as i16;
            let t = &mut tables[gi * LUT_W..gi * LUT_W + entries];
            for (slot_i, slot) in t.iter_mut().enumerate() {
                *slot = a0 * w0[slot_i] + a1 * w1[slot_i];
            }
        }
    }

    fn simd_levels(&self) -> &'static [SimdLevel] {
        simd::KERNEL_LEVELS
    }

    fn sparse_capable(&self) -> bool {
        true
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let (tables, scale) = match p {
            PreparedRow::LutI16 { tables, scale } => (tables, scale),
            _ => panic!("ELUT expects LutI16 activations"),
        };
        let groups = t.k / self.g;
        let row_bytes = self.row_bytes(t.k);
        let combined = t.scale / scale;
        let level = simd::active_level();
        simd::note_call(level);
        if self.mirror {
            let idx_bytes = groups / 2;
            if let Some(idx) = &t.sparse {
                #[cfg(target_arch = "x86_64")]
                if level == SimdLevel::Avx2 {
                    // SAFETY: AVX2 verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::avx2::gemv_rows_elut5_sparse(
                            &t.data, idx_bytes, tables, combined, out, rows, idx,
                        );
                    }
                    return;
                }
                #[cfg(target_arch = "aarch64")]
                if level == SimdLevel::Neon {
                    // SAFETY: NEON verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::neon::gemv_rows_elut5_sparse(
                            &t.data, idx_bytes, tables, combined, out, rows, idx,
                        );
                    }
                    return;
                }
                let mut elided = 0u64;
                for (o, r) in out.iter_mut().zip(rows) {
                    let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
                    *o = gemv_row_elut5_sparse(row, idx_bytes, tables, idx, r, &mut elided) as f32
                        * combined;
                }
                sparse::note_elided(level, elided);
                return;
            }
            #[cfg(target_arch = "x86_64")]
            if level == SimdLevel::Avx2 {
                // SAFETY: AVX2 verified by the active dispatch level;
                // buffer shapes are guaranteed by quantize/prepare.
                unsafe {
                    simd::avx2::gemv_rows_elut5(&t.data, idx_bytes, tables, combined, out, rows);
                }
                return;
            }
            #[cfg(target_arch = "aarch64")]
            if level == SimdLevel::Neon {
                // SAFETY: NEON verified by the active dispatch level;
                // buffer shapes are guaranteed by quantize/prepare.
                unsafe {
                    simd::neon::gemv_rows_elut5(&t.data, idx_bytes, tables, combined, out, rows);
                }
                return;
            }
            for (o, r) in out.iter_mut().zip(rows) {
                let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
                *o = gemv_row_elut5(row, idx_bytes, tables) as f32 * combined;
            }
        } else {
            // Non-mirrored rows are one nibble plane with a full 16-entry
            // table per group — byte-for-byte the TL1 lossless loop.
            if let Some(idx) = &t.sparse {
                #[cfg(target_arch = "x86_64")]
                if level == SimdLevel::Avx2 {
                    // SAFETY: AVX2 verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::avx2::gemv_rows_lut16_sparse(
                            &t.data, row_bytes, tables, combined, out, rows, idx,
                        );
                    }
                    return;
                }
                #[cfg(target_arch = "aarch64")]
                if level == SimdLevel::Neon {
                    // SAFETY: NEON verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::neon::gemv_rows_lut16_sparse(
                            &t.data, row_bytes, tables, combined, out, rows, idx,
                        );
                    }
                    return;
                }
                let mut elided = 0u64;
                for (o, r) in out.iter_mut().zip(rows) {
                    let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
                    *o = super::tl1::gemv_row_lut16_sparse(row, tables, idx, r, &mut elided) as f32
                        * combined;
                }
                sparse::note_elided(level, elided);
                return;
            }
            #[cfg(target_arch = "x86_64")]
            if level == SimdLevel::Avx2 {
                // SAFETY: AVX2 verified by the active dispatch level;
                // buffer shapes are guaranteed by quantize/prepare.
                unsafe {
                    simd::avx2::gemv_rows_lut16(&t.data, row_bytes, tables, combined, out, rows);
                }
                return;
            }
            #[cfg(target_arch = "aarch64")]
            if level == SimdLevel::Neon {
                // SAFETY: NEON verified by the active dispatch level;
                // buffer shapes are guaranteed by quantize/prepare.
                unsafe {
                    simd::neon::gemv_rows_lut16(&t.data, row_bytes, tables, combined, out, rows);
                }
                return;
            }
            for (o, r) in out.iter_mut().zip(rows) {
                let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
                *o = super::tl1::gemv_row_lut16(row, tables) as f32 * combined;
            }
        }
    }
}

/// Scalar accumulation for one mirror-consolidated ELUT row (ELUT_C5):
/// `idx_bytes` nibble bytes followed by `idx_bytes / 4` sign bytes, one
/// group per nibble, 1 sign bit per group.
#[inline]
pub fn gemv_row_elut5(row: &[u8], idx_bytes: usize, tables: &[i16]) -> i32 {
    let (idx_plane, sign_plane) = row.split_at(idx_bytes);
    let groups = idx_bytes * 2;
    let mut acc = 0i32;
    for gi in 0..groups {
        // SAFETY: the planes hold groups/2 index bytes and groups/8 sign
        // bytes, tables holds one LUT_W-entry table per group, and nibble
        // codes are < LUT_W.
        let byte = unsafe { *idx_plane.get_unchecked(gi / 2) };
        let nib = if gi % 2 == 0 { byte & 0xf } else { byte >> 4 };
        // SAFETY: as above.
        let sign = (unsafe { *sign_plane.get_unchecked(gi / 8) } >> (gi % 8)) & 1;
        // SAFETY: as above.
        let v = unsafe { *tables.get_unchecked(gi * LUT_W + nib as usize) } as i32;
        acc += sign_apply_i32(v, sign);
    }
    acc
}

/// Sparse [`gemv_row_elut5`]: blocks are [`SPARSE_BLOCK_WEIGHTS`] weights
/// = 32 groups; K % 16 == 0 keeps every block's sign bits byte-aligned.
/// A zero block's groups all carry the zero-pair code, whose table entry
/// is exactly 0 (and `sign_apply_i32(0, s)` is 0), so skipping them
/// leaves the i32 accumulator bit-identical.
#[inline]
pub fn gemv_row_elut5_sparse(
    row: &[u8],
    idx_bytes: usize,
    tables: &[i16],
    sidx: &sparse::SparseIndex,
    wr: usize,
    elided: &mut u64,
) -> i32 {
    const BLOCK_GROUPS: usize = SPARSE_BLOCK_WEIGHTS / 2;
    let (idx_plane, sign_plane) = row.split_at(idx_bytes);
    let groups = idx_bytes * 2;
    let mut acc = 0i32;
    for blk in 0..sidx.blocks_per_row() {
        if !sidx.is_nonzero(wr, blk) {
            *elided += 1;
            continue;
        }
        let g0 = blk * BLOCK_GROUPS;
        let g1 = (g0 + BLOCK_GROUPS).min(groups);
        for gi in g0..g1 {
            // SAFETY: the planes hold groups/2 index bytes and groups/8
            // sign bytes, tables holds one LUT_W-entry table per group,
            // and nibble codes are < LUT_W.
            let byte = unsafe { *idx_plane.get_unchecked(gi / 2) };
            let nib = if gi % 2 == 0 { byte & 0xf } else { byte >> 4 };
            // SAFETY: as above.
            let sign = (unsafe { *sign_plane.get_unchecked(gi / 8) } >> (gi % 8)) & 1;
            // SAFETY: as above.
            let v = unsafe { *tables.get_unchecked(gi * LUT_W + nib as usize) } as i32;
            acc += sign_apply_i32(v, sign);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::quant::{quantize_act_int8, training_scheme_ref_row};
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.033)
    }

    #[test]
    fn bpw_matches_table3() {
        let t = random_ternary(4, 1024, 1);
        let p4 = ELUT4.quantize(&t);
        assert_eq!(p4.bits_per_weight(), 2.0);
        let p5 = ELUT5.quantize(&t);
        assert_eq!(p5.bits_per_weight(), 2.5);
    }

    #[test]
    fn ternary_embeds_exactly() {
        let t = random_ternary(4, 256, 2);
        for kern in [&ELUT4, &ELUT5] {
            let packed = kern.quantize(&t);
            assert_eq!(kern.dequantize(&packed), t.dequantize(), "{}", kern.name);
        }
    }

    #[test]
    fn training_scheme_exact_on_ternary() {
        let (m, k) = (8, 512);
        let t = random_ternary(m, k, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let act = quantize_act_int8(&x);
        for kern in [&ELUT4, &ELUT5] {
            let packed = kern.quantize(&t);
            let p = kern.prepare(&x, k);
            let mut out = vec![0f32; m];
            kern.gemv(&packed, &p, &mut out);
            for r in 0..m {
                assert_eq!(
                    out[r],
                    training_scheme_ref_row(t.row(r), t.scale, &act),
                    "{} row {r}",
                    kern.name
                );
            }
        }
    }

    #[test]
    fn mirror_table_is_half_size() {
        use crate::kernels::lut::half_code_count;
        assert_eq!(half_code_count(5, 2), 13);
        assert!(half_code_count(5, 2) <= 16, "fits one shuffle register");
        assert_eq!(code_count(4, 2), 16);
    }

    /// C=5 can represent a 2-bit-symmetric model that ternary cannot;
    /// exercise non-ternary alphabet values through the full path.
    #[test]
    fn wider_alphabet_round_trip() {
        let mut rng = Rng::new(5);
        let k = 64;
        let q: Vec<i8> = (0..4 * k).map(|_| (rng.next_below(5) as i8) - 2).collect();
        // Bypass TernaryWeights' debug assertion by building the struct
        // directly (alphabet values -2..2 are legal for ELUT5).
        let t = TernaryWeights { q: q.clone(), m: 4, k, scale: 0.1 };
        let packed = ELUT5.quantize(&t);
        let back = ELUT5.dequantize(&packed);
        for (i, (&want, got)) in q.iter().zip(back.iter()).enumerate() {
            assert_eq!(*got, want as f32 * 0.1, "idx {i}");
        }
    }
}
