//! Sparsity-aware zero-block elision for the ternary kernels.
//!
//! BitNet b1.58 weights are ternary, so roughly a third of all weights
//! are exact zeros — and a zero weight contributes exactly nothing to
//! any of this library's integer accumulators (the LUT tables map the
//! all-zero code to entry 0; I2_S folds the `code − 1` offset so a zero
//! weight multiplies by 0). TENET (PAPERS.md) shows that skipping that
//! sparsity inside LUT-centric kernels is a first-order win. This
//! module makes the skip a *packing* decision:
//!
//! * At pack time every ternary kernel measures its per-row-block zero
//!   fraction and, when the tensor clears [`SPARSE_THRESHOLD`] (or the
//!   mode forces it), attaches a [`SparseIndex`] — one bit per
//!   scale-block-aligned weight block per row — to the packed
//!   [`super::QTensor`]. The dense packed bytes are unchanged; the
//!   index is purely additive, so dequantize and every dense consumer
//!   are untouched.
//! * `gemv_rows` consults the index and elides zero blocks entirely: no
//!   LUT gather, no accumulate, no per-block scale fold. Because a zero
//!   block's integer block sum is exactly 0 (and the `_0` variants'
//!   float fold of `0 · block_scale` adds `+0.0`, which can never
//!   change an accumulator that is itself never `-0.0` — block scales
//!   are non-negative and integer zero converts to `+0.0`), the sparse
//!   path is **bit-identical** to the dense path by construction.
//!   `rust/tests/simd_identity.rs` locks the claim down across kernel ×
//!   SIMD tier × adversarial shapes.
//! * The block granularity equals the kernel's scale-block granularity
//!   (32 LUT groups for the TL family — 64 weights at g=2, the unified
//!   trio/pair group sequence for TL2 — and one 128-weight alignment
//!   unit for I2_S), so a skipped block skips a whole scale fold too.
//!
//! Process-wide mode plumbing mirrors [`super::simd`]: the
//! `RUST_PALLAS_SPARSE` environment variable (`auto`/`on`/`off`) and
//! the CLI `--sparse` flag pick the [`SparseMode`]; tests and the tuner
//! force a mode for a scoped region with [`with_mode`]. When nesting
//! with [`super::simd::with_level`], always take [`with_mode`] as the
//! *outer* scope — both serialize on process-wide locks and a
//! consistent order keeps concurrent forcing callers deadlock-free.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;

use super::simd::SimdLevel;

/// Minimum zero-*block* fraction (not zero-weight fraction) a tensor
/// must measure at pack time for [`SparseMode::Auto`] to emit the
/// block-skip layout. Below it, the bitmap scan would cost more than
/// the elided work saves; iid ternary tensors (zero blocks ≈ never)
/// stay dense automatically.
pub const SPARSE_THRESHOLD: f64 = 0.5;

/// Whether the ternary kernels emit the block-skip layout at pack time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SparseMode {
    /// Measure per-tensor sparsity and decide by [`SPARSE_THRESHOLD`].
    Auto = 0,
    /// Always emit the block-skip layout (tests, tuner measurements).
    On = 1,
    /// Never emit it — every tensor packs dense (the forced-dense CI
    /// lane, and the degrade target for sparse-tuned profiles).
    Off = 2,
}

impl SparseMode {
    /// Every mode.
    pub const ALL: [SparseMode; 3] = [SparseMode::Auto, SparseMode::On, SparseMode::Off];

    /// Stable lowercase name (used in metrics, plan summaries, the CLI).
    pub fn name(self) -> &'static str {
        match self {
            SparseMode::Auto => "auto",
            SparseMode::On => "on",
            SparseMode::Off => "off",
        }
    }

    /// Parse a [`name`](Self::name); `None` for unknown strings.
    pub fn parse(s: &str) -> Option<SparseMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(SparseMode::Auto),
            "on" => Some(SparseMode::On),
            "off" => Some(SparseMode::Off),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> SparseMode {
        match v {
            1 => SparseMode::On,
            2 => SparseMode::Off,
            _ => SparseMode::Auto,
        }
    }
}

const UNSET: u8 = 0xff;
static ACTIVE: AtomicU8 = AtomicU8::new(UNSET);
static FORCE_LOCK: Mutex<()> = Mutex::new(());
/// Blocks elided by `gemv_rows`, indexed `[scalar, avx2, neon]` like
/// [`super::simd::call_counts`].
static ELIDED: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

fn init_from_env() -> SparseMode {
    match std::env::var("RUST_PALLAS_SPARSE") {
        Ok(s) => SparseMode::parse(&s).unwrap_or(SparseMode::Auto),
        Err(_) => SparseMode::Auto,
    }
}

/// The mode pack-time decisions consult right now. Lazily initialized
/// from `RUST_PALLAS_SPARSE` on first use.
pub fn mode() -> SparseMode {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != UNSET {
        return SparseMode::from_u8(v);
    }
    let init = init_from_env();
    // Keep whatever a racing set_mode installed first.
    let _ = ACTIVE.compare_exchange(UNSET, init as u8, Ordering::Relaxed, Ordering::Relaxed);
    SparseMode::from_u8(ACTIVE.load(Ordering::Relaxed))
}

/// Set the process-wide mode (the CLI `--sparse` flag).
pub fn set_mode(m: SparseMode) {
    ACTIVE.store(m as u8, Ordering::Relaxed);
}

/// Whether sparse packing is permitted at all under the current mode —
/// false exactly under a forced `off`, which is what profile
/// degradation checks (a sparse-tuned winner cannot be honored when
/// every tensor packs dense).
pub fn enabled() -> bool {
    mode() != SparseMode::Off
}

/// Run `f` with the mode forced to `m`, restoring the previous mode
/// afterwards — panic-safe, serialized process-wide. Take this *outside*
/// [`super::simd::with_level`] when nesting (see module docs).
pub fn with_mode<R>(m: SparseMode, f: impl FnOnce() -> R) -> R {
    let _guard = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(u8);
    impl Drop for Restore {
        fn drop(&mut self) {
            ACTIVE.store(self.0, Ordering::Relaxed);
        }
    }
    let _restore = Restore(mode() as u8);
    ACTIVE.store(m as u8, Ordering::Relaxed);
    f()
}

/// Record `n` weight blocks elided by a `gemv_rows` call at `level`.
#[inline]
pub fn note_elided(level: SimdLevel, n: u64) {
    if n > 0 {
        ELIDED[level as usize].fetch_add(n, Ordering::Relaxed);
    }
}

/// Cumulative elided-block counts, indexed `[scalar, avx2, neon]`.
pub fn elided_counts() -> [u64; 3] {
    [
        ELIDED[0].load(Ordering::Relaxed),
        ELIDED[1].load(Ordering::Relaxed),
        ELIDED[2].load(Ordering::Relaxed),
    ]
}

/// The block-skip layout: one bit per (row, weight block), set when the
/// block holds at least one nonzero weight. Blocks are the kernel's
/// scale blocks, described at build time as per-row weight ranges, so
/// `gemv_rows` can skip gather + accumulate + scale fold for clear bits.
/// Rows are stored as consecutive little-endian `u64` words (bit `b` of
/// word `b / 64`), sized identically for every row.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseIndex {
    blocks_per_row: usize,
    words_per_row: usize,
    words: Vec<u64>,
    nonzero_blocks: usize,
}

impl SparseIndex {
    /// Scan the `m`×`k` ternary matrix `q` (row-major) and build the
    /// bitmap. `bounds[b]` is the in-row weight range of block `b`; the
    /// ranges must tile `0..k` in order (the kernel's scale-block
    /// schedule).
    pub fn build(q: &[i8], m: usize, k: usize, bounds: &[Range<usize>]) -> SparseIndex {
        assert_eq!(q.len(), m * k);
        debug_assert!(bounds.last().map_or(k == 0, |r| r.end == k));
        let blocks_per_row = bounds.len();
        let words_per_row = blocks_per_row.div_ceil(64).max(1);
        let mut words = vec![0u64; m * words_per_row];
        let mut nonzero_blocks = 0usize;
        for r in 0..m {
            let row = &q[r * k..(r + 1) * k];
            let w = &mut words[r * words_per_row..(r + 1) * words_per_row];
            for (b, range) in bounds.iter().enumerate() {
                if row[range.clone()].iter().any(|&v| v != 0) {
                    w[b / 64] |= 1u64 << (b % 64);
                    nonzero_blocks += 1;
                }
            }
        }
        SparseIndex { blocks_per_row, words_per_row, words, nonzero_blocks }
    }

    /// Blocks per weight row.
    pub fn blocks_per_row(&self) -> usize {
        self.blocks_per_row
    }

    /// Total blocks with at least one nonzero weight.
    pub fn nonzero_blocks(&self) -> usize {
        self.nonzero_blocks
    }

    /// Total blocks across all rows.
    pub fn total_blocks(&self) -> usize {
        if self.words_per_row == 0 {
            return 0;
        }
        (self.words.len() / self.words_per_row) * self.blocks_per_row
    }

    /// Fraction of blocks that are entirely zero (what the pack-time
    /// threshold compares).
    pub fn zero_block_fraction(&self) -> f64 {
        let total = self.total_blocks();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.nonzero_blocks as f64 / total as f64
    }

    /// Bytes of bitmap storage (observability; not counted in
    /// [`super::QTensor::weight_bytes`] — the accumulate phase reads it
    /// once per row, not per block).
    pub fn index_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Whether block `blk` of row `row` holds any nonzero weight.
    #[inline]
    pub fn is_nonzero(&self, row: usize, blk: usize) -> bool {
        let w = self.words[row * self.words_per_row + blk / 64];
        (w >> (blk % 64)) & 1 != 0
    }

    /// One bitmap word of one row (`wi` indexes 64-block word groups).
    #[inline]
    pub fn row_word(&self, row: usize, wi: usize) -> u64 {
        self.words[row * self.words_per_row + wi]
    }

    /// OR of bitmap word `wi` across `rows` consecutive rows starting at
    /// `r0` — the vector tile's skip test: a block elides for the whole
    /// tile only when every row's bit is clear.
    #[inline]
    pub fn tile_or_word(&self, r0: usize, rows: usize, wi: usize) -> u64 {
        let mut or = 0u64;
        for r in r0..r0 + rows {
            or |= self.words[r * self.words_per_row + wi];
        }
        or
    }
}

/// Lazily-computed OR of a row tile's bitmap words — the vector paths'
/// skip test. A block elides for a whole 16-row tile only when every
/// row's bit is clear; the OR word is recomputed only when the block's
/// word index changes, so the hot loop stays allocation-free and reads
/// each bitmap word once per tile.
pub struct TileBits<'a> {
    idx: &'a SparseIndex,
    r0: usize,
    rows: usize,
    cur_wi: usize,
    cur_or: u64,
}

impl<'a> TileBits<'a> {
    /// Skip test over `rows` consecutive weight rows starting at `r0`.
    pub fn new(idx: &'a SparseIndex, r0: usize, rows: usize) -> TileBits<'a> {
        TileBits { idx, r0, rows, cur_wi: usize::MAX, cur_or: 0 }
    }

    /// Whether any of the tile's rows has a nonzero block `blk`.
    #[inline]
    pub fn any_nonzero(&mut self, blk: usize) -> bool {
        let wi = blk / 64;
        if wi != self.cur_wi {
            self.cur_wi = wi;
            self.cur_or = self.idx.tile_or_word(self.r0, self.rows, wi);
        }
        (self.cur_or >> (blk % 64)) & 1 != 0
    }
}

/// Uniform block bounds: `k` split into `block_weights`-sized chunks
/// (last chunk possibly short) — the schedule of every kernel except
/// TL2, whose unified trio/pair group sequence computes its own bounds.
pub fn uniform_bounds(k: usize, block_weights: usize) -> Vec<Range<usize>> {
    let mut bounds = Vec::with_capacity(k.div_ceil(block_weights));
    let mut start = 0usize;
    while start < k {
        let end = (start + block_weights).min(k);
        bounds.push(start..end);
        start = end;
    }
    bounds
}

/// Pack-time decision: build the index and attach it when the current
/// [`mode`] says so — always under `On`, never under `Off`, and only
/// past [`SPARSE_THRESHOLD`] under `Auto`. The ternary kernels call
/// this from `quantize`.
pub fn maybe_index(q: &[i8], m: usize, k: usize, bounds: &[Range<usize>]) -> Option<SparseIndex> {
    match mode() {
        SparseMode::Off => None,
        SparseMode::On => Some(SparseIndex::build(q, m, k, bounds)),
        SparseMode::Auto => {
            let idx = SparseIndex::build(q, m, k, bounds);
            (idx.zero_block_fraction() >= SPARSE_THRESHOLD).then_some(idx)
        }
    }
}

/// Measured zero-weight fraction of a ternary matrix (observability:
/// `BitLinear` records it for `plan_summary`; the *block* fraction in
/// [`SparseIndex::zero_block_fraction`] is what gates the layout).
pub fn zero_fraction(q: &[i8]) -> f64 {
    if q.is_empty() {
        return 0.0;
    }
    q.iter().filter(|&&v| v == 0).count() as f64 / q.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_names_round_trip() {
        for m in SparseMode::ALL {
            assert_eq!(SparseMode::parse(m.name()), Some(m));
        }
        assert_eq!(SparseMode::parse("ON"), Some(SparseMode::On));
        assert_eq!(SparseMode::parse("dense"), None);
    }

    #[test]
    fn with_mode_forces_and_restores() {
        let before = mode();
        with_mode(SparseMode::Off, || {
            assert_eq!(mode(), SparseMode::Off);
            assert!(!enabled());
        });
        assert_eq!(mode(), before);
    }

    #[test]
    fn index_tracks_zero_blocks_exactly() {
        // 2 rows × 8 weights, blocks of 4: row 0 = [zeros | nonzero],
        // row 1 = [nonzero | zeros].
        let q: Vec<i8> = vec![0, 0, 0, 0, 1, 0, -1, 0, 0, 1, 0, 0, 0, 0, 0, 0];
        let idx = SparseIndex::build(&q, 2, 8, &uniform_bounds(8, 4));
        assert_eq!(idx.blocks_per_row(), 2);
        assert_eq!(idx.total_blocks(), 4);
        assert_eq!(idx.nonzero_blocks(), 2);
        assert!((idx.zero_block_fraction() - 0.5).abs() < 1e-12);
        assert!(!idx.is_nonzero(0, 0));
        assert!(idx.is_nonzero(0, 1));
        assert!(idx.is_nonzero(1, 0));
        assert!(!idx.is_nonzero(1, 1));
        // Tile OR: block 0 nonzero somewhere in rows 0..2, block 1 too.
        assert_eq!(idx.tile_or_word(0, 2, 0) & 0b11, 0b11);
    }

    #[test]
    fn index_handles_many_blocks_across_words() {
        // 130 blocks of 1 weight → 3 bitmap words per row.
        let mut q = vec![0i8; 130];
        q[0] = 1;
        q[64] = -1;
        q[129] = 1;
        let idx = SparseIndex::build(&q, 1, 130, &uniform_bounds(130, 1));
        assert_eq!(idx.nonzero_blocks(), 3);
        assert!(idx.is_nonzero(0, 0));
        assert!(idx.is_nonzero(0, 64));
        assert!(idx.is_nonzero(0, 129));
        assert!(!idx.is_nonzero(0, 1));
        assert!(!idx.is_nonzero(0, 128));
    }

    #[test]
    fn maybe_index_obeys_mode_and_threshold() {
        // 75% zero blocks: clears Auto's 0.5 threshold.
        let sparse_q: Vec<i8> = vec![1, 0, 0, 0, 0, 0, 0, 0];
        // 0% zero blocks: stays dense under Auto.
        let dense_q: Vec<i8> = vec![1, -1, 1, -1, 1, -1, 1, -1];
        let bounds = uniform_bounds(8, 2);
        with_mode(SparseMode::Auto, || {
            assert!(maybe_index(&sparse_q, 1, 8, &bounds).is_some());
            assert!(maybe_index(&dense_q, 1, 8, &bounds).is_none());
        });
        with_mode(SparseMode::On, || {
            assert!(maybe_index(&dense_q, 1, 8, &bounds).is_some());
        });
        with_mode(SparseMode::Off, || {
            assert!(maybe_index(&sparse_q, 1, 8, &bounds).is_none());
        });
    }

    #[test]
    fn elided_counter_accumulates() {
        let before = elided_counts();
        note_elided(SimdLevel::Scalar, 5);
        note_elided(SimdLevel::Scalar, 0); // no-op
        let after = elided_counts();
        assert!(after[0] >= before[0] + 5);
    }

    #[test]
    fn zero_fraction_measures_weights() {
        assert_eq!(zero_fraction(&[]), 0.0);
        assert!((zero_fraction(&[0, 1, 0, -1]) - 0.5).abs() < 1e-12);
    }
}
