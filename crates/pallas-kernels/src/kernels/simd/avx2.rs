//! AVX2 vector paths for the ternary mpGEMM kernels.
//!
//! Two instruction families carry the speedup (paper §3.1.2, Table 4):
//!
//! * **LUT gathers** — `_mm_shuffle_epi8` performs 16 parallel lookups
//!   into one register-resident 16-entry table. The tables are laid out
//!   per group (the register-length tiling of §3.1.2), so a single
//!   shuffle cannot serve two groups; instead the accumulation is tiled
//!   over **16 output rows at a time**: for each packed byte position
//!   the 16 rows' code bytes are gathered into one vector, and the two
//!   groups that byte covers are resolved with two shuffles. int16
//!   (lossless) tables are split on the fly into low/high byte planes —
//!   the pack-and-unpack technique of §3.2.1 — so each half is again
//!   one shuffle wide.
//! * **Widening MADs** — I2_S expands 2-bit codes to unsigned bytes and
//!   feeds `_mm256_maddubs_epi16` (u8×i8 → pairwise i16; products are
//!   ≤ 3·127 so the pairwise sum cannot saturate), then widens through
//!   `_mm256_madd_epi16` into i32 accumulators.
//!
//! **Bit-identity contract**: every function here returns exactly what
//! the scalar path returns. All integer accumulation is
//! reassociation-free by construction; the only floating-point folds
//! (per-block scales in the `_0` variants, the final `combined` factor)
//! happen in the same order, with the same `as f32` conversions and
//! separate mul/add (Rust does not contract into FMA), as the scalar
//! code. `rust/tests/simd_identity.rs` enforces the contract.
//!
//! Row tiles smaller than 16 fall back to the scalar per-row routines,
//! which keeps every (m, k, n) shape exact without padded loads.

use std::ops::Range;

use crate::kernels::simd::SimdLevel;
use crate::kernels::sparse::{self, SparseIndex, TileBits};
use crate::kernels::tl1::{self, LUT_W};
use crate::kernels::tl2::{self, Tl2Layout};

#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

/// Rows processed per vector pass: one `pshufb` lane per output row.
pub const ROW_TILE: usize = 16;

/// Gather the byte at packed-row offset `b` from 16 consecutive weight
/// rows starting at `r0`.
///
/// # Safety
/// `data` must hold at least `(r0 + 16) * row_bytes` bytes and
/// `b < row_bytes`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn gather16(data: &[u8], row_bytes: usize, r0: usize, b: usize) -> [u8; 16] {
    debug_assert!((r0 + ROW_TILE) * row_bytes <= data.len());
    let mut idx = [0u8; 16];
    for (r, slot) in idx.iter_mut().enumerate() {
        *slot = *data.get_unchecked((r0 + r) * row_bytes + b);
    }
    idx
}

/// Split 16 packed code bytes into their low and high nibbles.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn nibbles(bytes: &[u8; 16]) -> (__m128i, __m128i) {
    let v = _mm_loadu_si128(bytes.as_ptr() as *const __m128i);
    let mask = _mm_set1_epi8(0x0f);
    let lo = _mm_and_si128(v, mask);
    let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), mask);
    (lo, hi)
}

/// 16 parallel lookups into a 16-entry int8 table (one `vpshufb`).
/// Codes are < 16, so the shuffle's sign-bit zeroing never triggers.
///
/// # Safety
/// Requires AVX2; `table` must point at 16 readable `i8` values.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn lut16_i8(table: *const i8, nib: __m128i) -> [i8; 16] {
    let t = _mm_loadu_si128(table as *const __m128i);
    let mut out = [0i8; 16];
    _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, _mm_shuffle_epi8(t, nib));
    out
}

/// 16 parallel lookups into a 16-entry int16 table: the table is split
/// into low/high byte planes (pack), each plane is one shuffle, and the
/// bytes are re-interleaved (unpack) into the 16-bit entries.
///
/// # Safety
/// Requires AVX2; `table` must point at 16 readable `i16` values.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn lut16_i16(table: *const i16, nib: __m128i) -> [i16; 16] {
    let a = _mm_loadu_si128(table as *const __m128i); // entries 0..8
    let b = _mm_loadu_si128((table as *const __m128i).add(1)); // entries 8..16
    let ff = _mm_set1_epi16(0x00ff);
    // Low/high byte planes; values are masked to 0..=255 before the
    // unsigned-saturating pack, so the pack is exact.
    let lo_plane = _mm_packus_epi16(_mm_and_si128(a, ff), _mm_and_si128(b, ff));
    let hi_plane = _mm_packus_epi16(_mm_srli_epi16::<8>(a), _mm_srli_epi16::<8>(b));
    let lo = _mm_shuffle_epi8(lo_plane, nib);
    let hi = _mm_shuffle_epi8(hi_plane, nib);
    let mut out = [0i16; 16];
    let p = out.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(p, _mm_unpacklo_epi8(lo, hi));
    _mm_storeu_si128(p.add(1), _mm_unpackhi_epi8(lo, hi));
    out
}

/// Pair lookup for one packed byte: low nibble into `tables[g]`, high
/// nibble into `tables[g+1]`, for 16 rows at once (int16 tables).
///
/// # Safety
/// Requires AVX2; `t0` and `t1` must each point at 16 readable `i16`s.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn lut_pair_i16(t0: *const i16, t1: *const i16, bytes: &[u8; 16]) -> ([i16; 16], [i16; 16]) {
    let (lo, hi) = nibbles(bytes);
    (lut16_i16(t0, lo), lut16_i16(t1, hi))
}

/// Pair lookup for one packed byte (int8 tables).
///
/// # Safety
/// Requires AVX2; `t0` and `t1` must each point at 16 readable `i8`s.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn lut_pair_i8(t0: *const i8, t1: *const i8, bytes: &[u8; 16]) -> ([i8; 16], [i8; 16]) {
    let (lo, hi) = nibbles(bytes);
    (lut16_i8(t0, lo), lut16_i8(t1, hi))
}

/// AVX2 accumulation over int16 LUTs with two groups per byte — the
/// shared hot loop of TL1_1 and ELUT_C4.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `data` must hold
/// `rows.end` packed rows of `row_bytes` bytes; `tables` must hold
/// `2 * row_bytes` tables of [`LUT_W`] `i16` entries; `out.len()` must
/// equal `rows.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_lut16(
    data: &[u8],
    row_bytes: usize,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    debug_assert!(tables.len() >= 2 * row_bytes * LUT_W);
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut acc = [0i32; ROW_TILE];
        for b in 0..row_bytes {
            let idx = gather16(data, row_bytes, base, b);
            let t0 = tables.as_ptr().add(2 * b * LUT_W);
            let t1 = tables.as_ptr().add((2 * b + 1) * LUT_W);
            let (v0, v1) = lut_pair_i16(t0, t1, &idx);
            for r in 0..ROW_TILE {
                acc[r] += v0[r] as i32 + v1[r] as i32;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl1::gemv_row_lut16(wrow, tables) as f32 * combined;
    }
}

/// AVX2 accumulation over int8 LUTs with per-block scales — TL1_0's hot
/// loop. Block flush order matches the scalar path exactly.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `data` must hold
/// `rows.end` packed rows of `row_bytes` bytes; `tables`/`block_scales`
/// must match `row_bytes` and `block_groups` as produced by the TL1
/// prepare path; `out.len()` must equal `rows.len()`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_lut8(
    data: &[u8],
    row_bytes: usize,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    let bytes_per_block = block_groups / 2;
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut facc = [0f32; ROW_TILE];
        let mut b = 0usize;
        let mut blk = 0usize;
        while b < row_bytes {
            let blk_bytes = bytes_per_block.min(row_bytes - b);
            let tbase = blk * block_groups * LUT_W;
            let mut acc = [0i32; ROW_TILE];
            for bb in 0..blk_bytes {
                let idx = gather16(data, row_bytes, base, b + bb);
                let t0 = tables.as_ptr().add(tbase + 2 * bb * LUT_W);
                let t1 = tables.as_ptr().add(tbase + (2 * bb + 1) * LUT_W);
                let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
            }
            let bs = block_scales[blk];
            for r in 0..ROW_TILE {
                facc[r] += acc[r] as f32 * bs;
            }
            b += blk_bytes;
            blk += 1;
        }
        for r in 0..ROW_TILE {
            out[i + r] = facc[r] * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl1::gemv_row_lut8(wrow, tables, block_scales, block_groups) * combined;
    }
}

/// AVX2 TL2 lossless accumulation: g=3 region with the mirror sign
/// plane (conditional negate under a mask — integer-equal to the scalar
/// dual-accumulator form), then the TL1 g=2 tail.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `data` must hold
/// `rows.end` packed TL2 rows matching `layout`; `tables` must hold
/// `(n3 + n2) * LUT_W` `i16` entries; `out.len()` must equal
/// `rows.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_tl2_i16(
    data: &[u8],
    layout: &Tl2Layout,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    let row_bytes = layout.row_bytes();
    let n3 = layout.n3();
    let tl1_off = layout.idx_bytes + layout.sign_bytes;
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut acc = [0i32; ROW_TILE];
        for s in 0..layout.sign_bytes {
            let sb = gather16(data, row_bytes, base, layout.idx_bytes + s);
            let g = 8 * s;
            for j in 0..4 {
                let idx = gather16(data, row_bytes, base, 4 * s + j);
                let t0 = tables.as_ptr().add((g + 2 * j) * LUT_W);
                let t1 = tables.as_ptr().add((g + 2 * j + 1) * LUT_W);
                let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    let m0 = -(((sb[r] >> (2 * j)) & 1) as i32);
                    let m1 = -(((sb[r] >> (2 * j + 1)) & 1) as i32);
                    acc[r] += ((v0[r] as i32) ^ m0) - m0;
                    acc[r] += ((v1[r] as i32) ^ m1) - m1;
                }
            }
        }
        for bb in 0..layout.tl1_bytes {
            let idx = gather16(data, row_bytes, base, tl1_off + bb);
            let t0 = tables.as_ptr().add((n3 + 2 * bb) * LUT_W);
            let t1 = tables.as_ptr().add((n3 + 2 * bb + 1) * LUT_W);
            let (v0, v1) = lut_pair_i16(t0, t1, &idx);
            for r in 0..ROW_TILE {
                acc[r] += v0[r] as i32 + v1[r] as i32;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl2::gemv_row_tl2_i16(wrow, layout, tables) as f32 * combined;
    }
}

/// AVX2 TL2 fast-path accumulation (int8 tables, per-block scales).
/// Blocks flush at sign-byte boundaries in the g=3 region, the TL1 tail
/// continues the open block, and a trailing partial block flushes last —
/// byte-for-byte the scalar flush schedule.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `data` must hold
/// `rows.end` packed TL2 rows matching `layout`; `tables`/`block_scales`
/// must match the TL2 `_0` prepare path with `block_groups` groups per
/// scale; `out.len()` must equal `rows.len()`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_tl2_i8(
    data: &[u8],
    layout: &Tl2Layout,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    let row_bytes = layout.row_bytes();
    let n3 = layout.n3();
    let tl1_off = layout.idx_bytes + layout.sign_bytes;
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut facc = [0f32; ROW_TILE];
        let mut acc = [0i32; ROW_TILE];
        let mut blk = 0usize;
        let mut in_blk = 0usize;
        for s in 0..layout.sign_bytes {
            let sb = gather16(data, row_bytes, base, layout.idx_bytes + s);
            let g = 8 * s;
            for j in 0..4 {
                let idx = gather16(data, row_bytes, base, 4 * s + j);
                let t0 = tables.as_ptr().add((g + 2 * j) * LUT_W);
                let t1 = tables.as_ptr().add((g + 2 * j + 1) * LUT_W);
                let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    let m0 = -(((sb[r] >> (2 * j)) & 1) as i32);
                    let m1 = -(((sb[r] >> (2 * j + 1)) & 1) as i32);
                    acc[r] += ((v0[r] as i32) ^ m0) - m0;
                    acc[r] += ((v1[r] as i32) ^ m1) - m1;
                }
            }
            in_blk += 8;
            if in_blk == block_groups {
                let bs = block_scales[blk];
                for r in 0..ROW_TILE {
                    facc[r] += acc[r] as f32 * bs;
                }
                acc = [0i32; ROW_TILE];
                blk += 1;
                in_blk = 0;
            }
        }
        for bb in 0..layout.tl1_bytes {
            let idx = gather16(data, row_bytes, base, tl1_off + bb);
            let t0 = tables.as_ptr().add((n3 + 2 * bb) * LUT_W);
            let t1 = tables.as_ptr().add((n3 + 2 * bb + 1) * LUT_W);
            let (v0, v1) = lut_pair_i8(t0, t1, &idx);
            for r in 0..ROW_TILE {
                acc[r] += v0[r] as i32 + v1[r] as i32;
            }
            in_blk += 2;
            if in_blk == block_groups {
                let bs = block_scales[blk];
                for r in 0..ROW_TILE {
                    facc[r] += acc[r] as f32 * bs;
                }
                acc = [0i32; ROW_TILE];
                blk += 1;
                in_blk = 0;
            }
        }
        if in_blk > 0 {
            let bs = block_scales[blk];
            for r in 0..ROW_TILE {
                facc[r] += acc[r] as f32 * bs;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = facc[r] * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl2::gemv_row_tl2_i8(wrow, layout, tables, block_scales, block_groups) * combined;
    }
}

/// AVX2 ELUT_C5 accumulation: mirror-consolidated int16 tables with one
/// group per nibble and a 1-bit sign plane.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `data` must hold
/// `rows.end` packed ELUT_C5 rows (`idx_bytes` nibble bytes followed by
/// `idx_bytes / 4` sign bytes per row); `tables` must hold
/// `2 * idx_bytes` tables of [`LUT_W`] `i16` entries; `out.len()` must
/// equal `rows.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_elut5(
    data: &[u8],
    idx_bytes: usize,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    debug_assert_eq!(idx_bytes % 4, 0, "K % 16 == 0 keeps the sign plane byte-aligned");
    let row_bytes = idx_bytes + idx_bytes / 4;
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut acc = [0i32; ROW_TILE];
        for b in 0..idx_bytes {
            let idx = gather16(data, row_bytes, base, b);
            let sb = gather16(data, row_bytes, base, idx_bytes + b / 4);
            let bit0 = 2 * (b % 4);
            let t0 = tables.as_ptr().add(2 * b * LUT_W);
            let t1 = tables.as_ptr().add((2 * b + 1) * LUT_W);
            let (v0, v1) = lut_pair_i16(t0, t1, &idx);
            for r in 0..ROW_TILE {
                let m0 = -(((sb[r] >> bit0) & 1) as i32);
                let m1 = -(((sb[r] >> (bit0 + 1)) & 1) as i32);
                acc[r] += ((v0[r] as i32) ^ m0) - m0;
                acc[r] += ((v1[r] as i32) ^ m1) - m1;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = crate::kernels::elut::gemv_row_elut5(wrow, idx_bytes, tables) as f32 * combined;
    }
}

/// AVX2 I2_S row accumulation: 2-bit codes expanded to unsigned bytes,
/// one `maddubs` + one `madd` per 32 weights, `Σ a·code − Σ a` overall.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `wrow.len() * 4` must
/// equal `aq.len()`, and `act_sum` must be the sum of `aq`.
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_row_i2s(wrow: &[u8], aq: &[i8], act_sum: i32) -> i32 {
    debug_assert_eq!(wrow.len() * 4, aq.len());
    // Deinterleave control: within each 16-activation half, activations
    // are regrouped by in-byte weight position (j = 0,1,2,3) so they
    // line up with the mask-expanded code bytes below.
    let ctrl = _mm256_setr_epi8(
        0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10, 14, 3, 7, 11, 15, 0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10,
        14, 3, 7, 11, 15,
    );
    let ones = _mm256_set1_epi16(1);
    let mut accv = _mm256_setzero_si256();
    let mut chunks = wrow.chunks_exact(8);
    let mut k = 0usize;
    for ch in &mut chunks {
        let w0 = u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        let w1 = u32::from_le_bytes([ch[4], ch[5], ch[6], ch[7]]);
        let m = 0x0303_0303u32;
        // Lane l of the low half holds the codes for weight position
        // l within each of w0's four bytes; the high half mirrors w1.
        let codes = _mm256_set_epi32(
            ((w1 >> 6) & m) as i32,
            ((w1 >> 4) & m) as i32,
            ((w1 >> 2) & m) as i32,
            (w1 & m) as i32,
            ((w0 >> 6) & m) as i32,
            ((w0 >> 4) & m) as i32,
            ((w0 >> 2) & m) as i32,
            (w0 & m) as i32,
        );
        let acts = _mm256_loadu_si256(aq.as_ptr().add(k) as *const __m256i);
        let acts = _mm256_shuffle_epi8(acts, ctrl);
        // u8 codes (≤3) × i8 activations: pairwise i16 sums ≤ 762, no
        // saturation; widen to i32 via madd against ones.
        let prod = _mm256_maddubs_epi16(codes, acts);
        accv = _mm256_add_epi32(accv, _mm256_madd_epi16(prod, ones));
        k += 32;
    }
    let lo = _mm256_castsi256_si128(accv);
    let hi = _mm256_extracti128_si256::<1>(accv);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4e>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xb1>(s));
    let mut acc = _mm_cvtsi128_si32(s);
    for &byte in chunks.remainder() {
        for j in 0..4 {
            acc += ((byte >> (2 * j)) & 0x3) as i32 * *aq.get_unchecked(k + j) as i32;
        }
        k += 4;
    }
    acc - act_sum
}

/// AVX2 I2_S over a row range (the `gemv_rows` shape).
///
/// # Safety
/// Caller must have verified AVX2 at run time. `data` must hold
/// `rows.end` packed rows of `aq.len() / 4` bytes; `act_sum` must be
/// the sum of `aq`; `out.len()` must equal `rows.len()`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_i2s(
    data: &[u8],
    aq: &[i8],
    act_sum: i32,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    let row_bytes = aq.len() / 4;
    for (o, r) in out.iter_mut().zip(rows) {
        let wrow = &data[r * row_bytes..(r + 1) * row_bytes];
        *o = gemv_row_i2s(wrow, aq, act_sum) as f32 * combined;
    }
}

/// AVX2 activation quantization: absmax reduction, then round-clamp-pack
/// to int8 — the prepare-phase half of every lossless kernel.
///
/// Bit-identical to the scalar `quantize_act_int8_into` for finite
/// inputs: f32 `max` is order-free over non-negative finite values, the
/// `v * scale` multiply is the same single f32 op, and round-half-away-
/// from-zero is emulated exactly as truncate plus a conditional ±1 when
/// `|frac| >= 0.5` (`_mm256_round_ps`'s nearest mode is round-to-even,
/// which would NOT match Rust's `round`). The final `cvtps` sees an
/// integral value, so its nearest-even mode is exact too.
///
/// # Safety
/// Caller must have verified AVX2 at run time and pass `q.len() ==
/// x.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn quantize_act_int8(x: &[f32], q: &mut [i8]) -> (f32, i32) {
    debug_assert_eq!(q.len(), x.len());
    let sign_mask = _mm256_set1_ps(-0.0);
    let mut vmax = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= x.len() {
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        vmax = _mm256_max_ps(vmax, _mm256_andnot_ps(sign_mask, v));
        i += 8;
    }
    let mut lanes = [0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), vmax);
    let mut max_abs = lanes.iter().fold(0.0f32, |a, &v| a.max(v));
    for &v in &x[i..] {
        max_abs = max_abs.max(v.abs());
    }
    let max_abs = max_abs.max(1e-5);
    let scale = 127.0 / max_abs;

    let vscale = _mm256_set1_ps(scale);
    let lim = _mm256_set1_ps(127.0);
    let nlim = _mm256_set1_ps(-127.0);
    let half = _mm256_set1_ps(0.5);
    let one = _mm256_set1_ps(1.0);
    let mut vsum = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 8 <= x.len() {
        let v = _mm256_mul_ps(_mm256_loadu_ps(x.as_ptr().add(i)), vscale);
        // Round half away from zero: trunc, then +-1 where |frac| >= 0.5.
        let t = _mm256_round_ps::<{ _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC }>(v);
        let frac = _mm256_sub_ps(v, t);
        let afrac = _mm256_andnot_ps(sign_mask, frac);
        let ge = _mm256_cmp_ps::<_CMP_GE_OQ>(afrac, half);
        let signed_one = _mm256_or_ps(one, _mm256_and_ps(sign_mask, v));
        let r = _mm256_add_ps(t, _mm256_and_ps(ge, signed_one));
        let r = _mm256_min_ps(_mm256_max_ps(r, nlim), lim);
        let qi = _mm256_cvtps_epi32(r);
        vsum = _mm256_add_epi32(vsum, qi);
        let lo = _mm256_castsi256_si128(qi);
        let hi = _mm256_extracti128_si256::<1>(qi);
        // Values are in [-127, 127], so neither saturating pack clips.
        let w16 = _mm_packs_epi32(lo, hi);
        let b8 = _mm_packs_epi16(w16, w16);
        _mm_storel_epi64(q.as_mut_ptr().add(i) as *mut __m128i, b8);
        i += 8;
    }
    let mut sums = [0i32; 8];
    _mm256_storeu_si256(sums.as_mut_ptr() as *mut __m256i, vsum);
    let mut sum: i32 = sums.iter().sum();
    for (qv, &v) in q[i..].iter_mut().zip(x[i..].iter()) {
        let t = (v * scale).round().clamp(-127.0, 127.0) as i8;
        *qv = t;
        sum += t as i32;
    }
    (scale, sum)
}

/// Sparse [`gemv_rows_lut16`]: the 16-row tile skips a weight block only
/// when *every* row in the tile has the block's bit clear (one OR over
/// the tile's bitmap words, recomputed lazily per 64 blocks). Rows whose
/// individual block is zero but whose tile-mates are not still run the
/// dense lookups — their contributions are exactly 0, so the result
/// stays bit-identical to both the dense and the scalar-sparse paths.
///
/// # Safety
/// Same contract as [`gemv_rows_lut16`]; `sidx` must have been built for
/// this tensor's rows with [`tl1::SPARSE_BLOCK_WEIGHTS`]-weight blocks.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_lut16_sparse(
    data: &[u8],
    row_bytes: usize,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    const BLOCK_BYTES: usize = tl1::SPARSE_BLOCK_WEIGHTS / 4;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut acc = [0i32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let b0 = blk * BLOCK_BYTES;
            let b1 = (b0 + BLOCK_BYTES).min(row_bytes);
            for b in b0..b1 {
                let idx = gather16(data, row_bytes, base, b);
                let t0 = tables.as_ptr().add(2 * b * LUT_W);
                let t1 = tables.as_ptr().add((2 * b + 1) * LUT_W);
                let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] =
            tl1::gemv_row_lut16_sparse(wrow, tables, sidx, row, &mut elided) as f32 * combined;
    }
    sparse::note_elided(SimdLevel::Avx2, elided);
}

/// Sparse [`gemv_rows_lut8`]: the elision block *is* the requantization
/// scale block, so a tile-skipped block also skips its `0 · block_scale`
/// folds (`+0.0` — block scales are non-negative), keeping the f32
/// accumulators bit-identical to the dense flush schedule.
///
/// # Safety
/// Same contract as [`gemv_rows_lut8`]; `sidx` blocks must coincide with
/// the requantization scale blocks (`block_groups` groups each).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_lut8_sparse(
    data: &[u8],
    row_bytes: usize,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    let bytes_per_block = block_groups / 2;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut facc = [0f32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let b0 = blk * bytes_per_block;
            let blk_bytes = bytes_per_block.min(row_bytes - b0);
            let tbase = blk * block_groups * LUT_W;
            let mut acc = [0i32; ROW_TILE];
            for bb in 0..blk_bytes {
                let idx = gather16(data, row_bytes, base, b0 + bb);
                let t0 = tables.as_ptr().add(tbase + 2 * bb * LUT_W);
                let t1 = tables.as_ptr().add(tbase + (2 * bb + 1) * LUT_W);
                let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
            }
            let bs = block_scales[blk];
            for r in 0..ROW_TILE {
                facc[r] += acc[r] as f32 * bs;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = facc[r] * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] =
            tl1::gemv_row_lut8_sparse(wrow, tables, block_scales, block_groups, sidx, row, &mut elided)
                * combined;
    }
    sparse::note_elided(SimdLevel::Avx2, elided);
}

/// Sparse [`gemv_rows_tl2_i16`]: blocks stride the unified group
/// sequence ([`Tl2Layout::sparse_bounds`]). Block boundaries land on
/// whole sign bytes in the g=3 region (`LUT_BLOCK_GROUPS` is a multiple
/// of 8 and `n3` is a multiple of 8) and on whole tail bytes in the TL1
/// region, so a nonzero block replays the dense gather schedule exactly
/// over its byte range — including blocks that span the g=3 → tail
/// boundary.
///
/// # Safety
/// Same contract as [`gemv_rows_tl2_i16`]; `sidx` must use the blocks of
/// [`Tl2Layout::sparse_bounds`].
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_tl2_i16_sparse(
    data: &[u8],
    layout: &Tl2Layout,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    let row_bytes = layout.row_bytes();
    let n3 = layout.n3();
    let groups = n3 + layout.n2();
    let tl1_off = layout.idx_bytes + layout.sign_bytes;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut acc = [0i32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let g0 = blk * tl1::LUT_BLOCK_GROUPS;
            let g1 = (g0 + tl1::LUT_BLOCK_GROUPS).min(groups);
            let mut g = g0;
            while g < g1.min(n3) {
                let s = g / 8;
                let sb = gather16(data, row_bytes, base, layout.idx_bytes + s);
                for j in 0..4 {
                    let idx = gather16(data, row_bytes, base, 4 * s + j);
                    let t0 = tables.as_ptr().add((g + 2 * j) * LUT_W);
                    let t1 = tables.as_ptr().add((g + 2 * j + 1) * LUT_W);
                    let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                    for r in 0..ROW_TILE {
                        let m0 = -(((sb[r] >> (2 * j)) & 1) as i32);
                        let m1 = -(((sb[r] >> (2 * j + 1)) & 1) as i32);
                        acc[r] += ((v0[r] as i32) ^ m0) - m0;
                        acc[r] += ((v1[r] as i32) ^ m1) - m1;
                    }
                }
                g += 8;
            }
            let mut tg = g.max(n3) - n3;
            let tg_end = g1.saturating_sub(n3);
            while tg < tg_end {
                let bb = tg / 2;
                let idx = gather16(data, row_bytes, base, tl1_off + bb);
                let t0 = tables.as_ptr().add((n3 + 2 * bb) * LUT_W);
                let t1 = tables.as_ptr().add((n3 + 2 * bb + 1) * LUT_W);
                let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
                tg += 2;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl2::gemv_row_tl2_i16_sparse(wrow, layout, tables, sidx, row, &mut elided) as f32
            * combined;
    }
    sparse::note_elided(SimdLevel::Avx2, elided);
}

/// Sparse [`gemv_rows_tl2_i8`]: the elision block *is* the scale block
/// (`block_groups == LUT_BLOCK_GROUPS`), so each nonzero block runs the
/// dense gathers over its group range and folds one scale; skipped
/// blocks drop a `+0.0` fold, keeping f32 bit-identity.
///
/// # Safety
/// Same contract as [`gemv_rows_tl2_i8`]; `sidx` must use the blocks of
/// [`Tl2Layout::sparse_bounds`] with `block_groups` groups per block.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_tl2_i8_sparse(
    data: &[u8],
    layout: &Tl2Layout,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    debug_assert_eq!(block_groups % 8, 0, "blocks must cover whole sign bytes");
    let row_bytes = layout.row_bytes();
    let n3 = layout.n3();
    let groups = n3 + layout.n2();
    let tl1_off = layout.idx_bytes + layout.sign_bytes;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut facc = [0f32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let g0 = blk * block_groups;
            let g1 = (g0 + block_groups).min(groups);
            let mut acc = [0i32; ROW_TILE];
            let mut g = g0;
            while g < g1.min(n3) {
                let s = g / 8;
                let sb = gather16(data, row_bytes, base, layout.idx_bytes + s);
                for j in 0..4 {
                    let idx = gather16(data, row_bytes, base, 4 * s + j);
                    let t0 = tables.as_ptr().add((g + 2 * j) * LUT_W);
                    let t1 = tables.as_ptr().add((g + 2 * j + 1) * LUT_W);
                    let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                    for r in 0..ROW_TILE {
                        let m0 = -(((sb[r] >> (2 * j)) & 1) as i32);
                        let m1 = -(((sb[r] >> (2 * j + 1)) & 1) as i32);
                        acc[r] += ((v0[r] as i32) ^ m0) - m0;
                        acc[r] += ((v1[r] as i32) ^ m1) - m1;
                    }
                }
                g += 8;
            }
            let mut tg = g.max(n3) - n3;
            let tg_end = g1.saturating_sub(n3);
            while tg < tg_end {
                let bb = tg / 2;
                let idx = gather16(data, row_bytes, base, tl1_off + bb);
                let t0 = tables.as_ptr().add((n3 + 2 * bb) * LUT_W);
                let t1 = tables.as_ptr().add((n3 + 2 * bb + 1) * LUT_W);
                let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
                tg += 2;
            }
            let bs = block_scales[blk];
            for r in 0..ROW_TILE {
                facc[r] += acc[r] as f32 * bs;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = facc[r] * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl2::gemv_row_tl2_i8_sparse(
            wrow,
            layout,
            tables,
            block_scales,
            block_groups,
            sidx,
            row,
            &mut elided,
        ) * combined;
    }
    sparse::note_elided(SimdLevel::Avx2, elided);
}

/// Sparse [`gemv_rows_elut5`]: one block covers 16 index bytes (32
/// groups), so the `b % 4` sign-byte addressing of the dense loop is
/// preserved inside every block (`b0` is a multiple of 4).
///
/// # Safety
/// Same contract as [`gemv_rows_elut5`]; `sidx` must use
/// [`tl1::SPARSE_BLOCK_WEIGHTS`]-weight blocks.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_elut5_sparse(
    data: &[u8],
    idx_bytes: usize,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    const BLOCK_IDX_BYTES: usize = tl1::SPARSE_BLOCK_WEIGHTS / 4;
    let row_bytes = idx_bytes + idx_bytes / 4;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut acc = [0i32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let b0 = blk * BLOCK_IDX_BYTES;
            let b1 = (b0 + BLOCK_IDX_BYTES).min(idx_bytes);
            for b in b0..b1 {
                let idx = gather16(data, row_bytes, base, b);
                let sb = gather16(data, row_bytes, base, idx_bytes + b / 4);
                let bit0 = 2 * (b % 4);
                let t0 = tables.as_ptr().add(2 * b * LUT_W);
                let t1 = tables.as_ptr().add((2 * b + 1) * LUT_W);
                let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    let m0 = -(((sb[r] >> bit0) & 1) as i32);
                    let m1 = -(((sb[r] >> (bit0 + 1)) & 1) as i32);
                    acc[r] += ((v0[r] as i32) ^ m0) - m0;
                    acc[r] += ((v1[r] as i32) ^ m1) - m1;
                }
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = crate::kernels::elut::gemv_row_elut5_sparse(
            wrow,
            idx_bytes,
            tables,
            sidx,
            row,
            &mut elided,
        ) as f32
            * combined;
    }
    sparse::note_elided(SimdLevel::Avx2, elided);
}

/// Sparse AVX2 I2_S row: nonzero blocks accumulate `Σ a·(code − 1)`
/// directly — `maddubs(codes, acts) − maddubs(1, acts)` per 8-byte
/// chunk — so no `act_sum` correction is needed and skipped blocks
/// contribute exactly nothing. The pairwise i16 difference is bounded
/// by 2·(3·127) + 2·127 < i16::MAX, so nothing saturates, and the
/// overall i32 sum equals the dense `Σ a·code − act_sum` exactly.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `wrow.len() * 4` must
/// equal `aq.len()` and `sidx` must use
/// [`crate::kernels::i2s::SPARSE_BLOCK_WEIGHTS`]-weight blocks.
#[target_feature(enable = "avx2")]
unsafe fn gemv_row_i2s_sparse(
    wrow: &[u8],
    aq: &[i8],
    sidx: &SparseIndex,
    row: usize,
    elided: &mut u64,
) -> i32 {
    debug_assert_eq!(wrow.len() * 4, aq.len());
    const BLOCK_BYTES: usize = crate::kernels::i2s::SPARSE_BLOCK_WEIGHTS / 4;
    let ctrl = _mm256_setr_epi8(
        0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10, 14, 3, 7, 11, 15, 0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10,
        14, 3, 7, 11, 15,
    );
    let ones = _mm256_set1_epi16(1);
    let ones8 = _mm256_set1_epi8(1);
    let mut accv = _mm256_setzero_si256();
    let mut acc = 0i32;
    for blk in 0..sidx.blocks_per_row() {
        if !sidx.is_nonzero(row, blk) {
            *elided += 1;
            continue;
        }
        let b0 = blk * BLOCK_BYTES;
        let b1 = (b0 + BLOCK_BYTES).min(wrow.len());
        let mut chunks = wrow[b0..b1].chunks_exact(8);
        let mut k = b0 * 4;
        for ch in &mut chunks {
            let w0 = u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            let w1 = u32::from_le_bytes([ch[4], ch[5], ch[6], ch[7]]);
            let m = 0x0303_0303u32;
            let codes = _mm256_set_epi32(
                ((w1 >> 6) & m) as i32,
                ((w1 >> 4) & m) as i32,
                ((w1 >> 2) & m) as i32,
                (w1 & m) as i32,
                ((w0 >> 6) & m) as i32,
                ((w0 >> 4) & m) as i32,
                ((w0 >> 2) & m) as i32,
                (w0 & m) as i32,
            );
            let acts = _mm256_loadu_si256(aq.as_ptr().add(k) as *const __m256i);
            let acts = _mm256_shuffle_epi8(acts, ctrl);
            let prod = _mm256_maddubs_epi16(codes, acts);
            let asum = _mm256_maddubs_epi16(ones8, acts);
            let diff = _mm256_sub_epi16(prod, asum);
            accv = _mm256_add_epi32(accv, _mm256_madd_epi16(diff, ones));
            k += 32;
        }
        for &byte in chunks.remainder() {
            for j in 0..4 {
                acc += (((byte >> (2 * j)) & 0x3) as i32 - 1) * *aq.get_unchecked(k + j) as i32;
            }
            k += 4;
        }
    }
    let lo = _mm256_castsi256_si128(accv);
    let hi = _mm256_extracti128_si256::<1>(accv);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4e>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xb1>(s));
    acc + _mm_cvtsi128_si32(s)
}

/// Sparse AVX2 I2_S over a row range.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `data` must hold
/// `rows.end` packed rows of `aq.len() / 4` bytes; `out.len()` must
/// equal `rows.len()`; `sidx` must match the tensor's packing.
#[target_feature(enable = "avx2")]
pub unsafe fn gemv_rows_i2s_sparse(
    data: &[u8],
    aq: &[i8],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    let row_bytes = aq.len() / 4;
    let mut elided = 0u64;
    for (o, r) in out.iter_mut().zip(rows) {
        let wrow = &data[r * row_bytes..(r + 1) * row_bytes];
        *o = gemv_row_i2s_sparse(wrow, aq, sidx, r, &mut elided) as f32 * combined;
    }
    sparse::note_elided(SimdLevel::Avx2, elided);
}

/// Vectorized LUT table build for the g=2 kernels (prepare phase): for
/// each activation pair `(a0, a1) = (aq[2g], aq[2g+1])` fill the whole
/// 16-entry table `tables[g·16 + c] = a0·w0[c] + a1·w1[c]` with one
/// 256-bit multiply-add pass. Padding slots carry zero weight patterns,
/// so the result equals the scalar fill-then-write loop bit for bit —
/// all arithmetic is exact in i16 (|a| ≤ 128, |w| ≤ 2 ⇒ |entry| ≤ 512).
///
/// # Safety
/// Caller must have verified AVX2 at run time. `aq.len()` must be even
/// and `tables.len()` must equal `(aq.len() / 2) * LUT_W`.
#[target_feature(enable = "avx2")]
pub unsafe fn build_lut16_pair_tables(
    aq: &[i8],
    w0: &[i16; LUT_W],
    w1: &[i16; LUT_W],
    tables: &mut [i16],
) {
    debug_assert_eq!(aq.len() % 2, 0);
    debug_assert_eq!(tables.len(), aq.len() / 2 * LUT_W);
    let vw0 = _mm256_loadu_si256(w0.as_ptr() as *const __m256i);
    let vw1 = _mm256_loadu_si256(w1.as_ptr() as *const __m256i);
    let out = tables.as_mut_ptr();
    for (g, pair) in aq.chunks_exact(2).enumerate() {
        let a0 = _mm256_set1_epi16(pair[0] as i16);
        let a1 = _mm256_set1_epi16(pair[1] as i16);
        let sum = _mm256_add_epi16(_mm256_mullo_epi16(a0, vw0), _mm256_mullo_epi16(a1, vw1));
        _mm256_storeu_si256(out.add(g * LUT_W) as *mut __m256i, sum);
    }
}

/// [`build_lut16_pair_tables`] for g=3 trios (the TL2 mirror region):
/// `tables[g·16 + h] = a0·w0[h] + a1·w1[h] + a2·w2[h]`.
///
/// # Safety
/// Caller must have verified AVX2 at run time. `aq.len()` must be a
/// multiple of 3 and `tables.len()` must equal `(aq.len() / 3) * LUT_W`.
#[target_feature(enable = "avx2")]
pub unsafe fn build_lut16_trio_tables(
    aq: &[i8],
    w0: &[i16; LUT_W],
    w1: &[i16; LUT_W],
    w2: &[i16; LUT_W],
    tables: &mut [i16],
) {
    debug_assert_eq!(aq.len() % 3, 0);
    debug_assert_eq!(tables.len(), aq.len() / 3 * LUT_W);
    let vw0 = _mm256_loadu_si256(w0.as_ptr() as *const __m256i);
    let vw1 = _mm256_loadu_si256(w1.as_ptr() as *const __m256i);
    let vw2 = _mm256_loadu_si256(w2.as_ptr() as *const __m256i);
    let out = tables.as_mut_ptr();
    for (g, trio) in aq.chunks_exact(3).enumerate() {
        let a0 = _mm256_set1_epi16(trio[0] as i16);
        let a1 = _mm256_set1_epi16(trio[1] as i16);
        let a2 = _mm256_set1_epi16(trio[2] as i16);
        let sum = _mm256_add_epi16(
            _mm256_add_epi16(_mm256_mullo_epi16(a0, vw0), _mm256_mullo_epi16(a1, vw1)),
            _mm256_mullo_epi16(a2, vw2),
        );
        _mm256_storeu_si256(out.add(g * LUT_W) as *mut __m256i, sum);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_avx2() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    #[test]
    fn lut16_i8_matches_scalar_lookup() {
        if !have_avx2() {
            return;
        }
        let table: [i8; 16] = core::array::from_fn(|i| (i as i8) * 3 - 20);
        let bytes: [u8; 16] = core::array::from_fn(|i| ((i * 7) % 16) as u8 | (((i * 3) % 14) as u8) << 4);
        // SAFETY: AVX2 presence checked above; table/bytes are 16 wide.
        let (v0, v1) = unsafe { lut_pair_i8(table.as_ptr(), table.as_ptr(), &bytes) };
        for i in 0..16 {
            assert_eq!(v0[i], table[(bytes[i] & 0xf) as usize], "lo {i}");
            assert_eq!(v1[i], table[(bytes[i] >> 4) as usize], "hi {i}");
        }
    }

    #[test]
    fn lut16_i16_matches_scalar_lookup() {
        if !have_avx2() {
            return;
        }
        // Entries spanning the full i16 range, including negatives.
        let table: [i16; 16] = core::array::from_fn(|i| (i as i16) * -2500 + 7);
        let bytes: [u8; 16] = core::array::from_fn(|i| (i as u8) | ((15 - i as u8) << 4));
        // SAFETY: AVX2 presence checked above; table/bytes are 16 wide.
        let (v0, v1) = unsafe { lut_pair_i16(table.as_ptr(), table.as_ptr(), &bytes) };
        for i in 0..16 {
            assert_eq!(v0[i], table[(bytes[i] & 0xf) as usize], "lo {i}");
            assert_eq!(v1[i], table[(bytes[i] >> 4) as usize], "hi {i}");
        }
    }

    #[test]
    fn i2s_row_matches_reference() {
        if !have_avx2() {
            return;
        }
        let mut rng = pallas_core::util::Rng::new(9);
        for trial in 0..8 {
            let k = 128 * (1 + trial % 3);
            let w: Vec<i8> = (0..k).map(|_| rng.next_ternary() as i8).collect();
            let aq: Vec<i8> = (0..k).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let mut wrow = vec![0u8; k / 4];
            for (b, quad) in w.chunks_exact(4).enumerate() {
                let mut byte = 0u8;
                for (j, &t) in quad.iter().enumerate() {
                    byte |= (((t + 1) as u8) & 0x3) << (2 * j);
                }
                wrow[b] = byte;
            }
            let act_sum: i32 = aq.iter().map(|&a| a as i32).sum();
            let want: i32 = w.iter().zip(aq.iter()).map(|(&wv, &av)| wv as i32 * av as i32).sum();
            // SAFETY: AVX2 presence checked above; wrow.len()*4 == aq.len().
            let got = unsafe { gemv_row_i2s(&wrow, &aq, act_sum) };
            assert_eq!(got, want, "trial {trial}");
        }
    }
}
