//! NEON vector paths for the ternary mpGEMM kernels (AArch64).
//!
//! Mirrors [`super::avx2`] with `vqtbl1q_u8` as the 16-wide table
//! gather (the `tbl` instruction of the paper's §3.1.2). int16 tables
//! are split into low/high byte planes with `vuzp1q_u8`/`vuzp2q_u8`
//! (on little-endian AArch64 the even bytes of an `i16` stream are the
//! low bytes), gathered per plane, and re-interleaved with
//! `vzip1q_u8`/`vzip2q_u8`. The I2_S path keeps the scalar body under
//! `target_feature(enable = "neon")` so LLVM auto-vectorizes the
//! widening multiply-add.
//!
//! The bit-identity contract of [`super::avx2`] applies unchanged:
//! integer accumulation throughout, float folds in scalar block order.

use std::ops::Range;

use crate::kernels::simd::SimdLevel;
use crate::kernels::sparse::{self, SparseIndex, TileBits};
use crate::kernels::tl1::{self, LUT_W};
use crate::kernels::tl2::{self, Tl2Layout};

use core::arch::aarch64::*;

/// Rows processed per vector pass: one `tbl` lane per output row.
pub const ROW_TILE: usize = 16;

/// Gather the byte at packed-row offset `b` from 16 consecutive weight
/// rows starting at `r0`.
///
/// # Safety
/// `data` must hold at least `(r0 + 16) * row_bytes` bytes and
/// `b < row_bytes`.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn gather16(data: &[u8], row_bytes: usize, r0: usize, b: usize) -> [u8; 16] {
    debug_assert!((r0 + ROW_TILE) * row_bytes <= data.len());
    let mut idx = [0u8; 16];
    for (r, slot) in idx.iter_mut().enumerate() {
        *slot = *data.get_unchecked((r0 + r) * row_bytes + b);
    }
    idx
}

/// Split 16 packed code bytes into their low and high nibbles.
///
/// # Safety
/// Requires NEON.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn nibbles(bytes: &[u8; 16]) -> (uint8x16_t, uint8x16_t) {
    let v = vld1q_u8(bytes.as_ptr());
    let mask = vdupq_n_u8(0x0f);
    (vandq_u8(v, mask), vandq_u8(vshrq_n_u8::<4>(v), mask))
}

/// 16 parallel lookups into a 16-entry int8 table (one `tbl`).
///
/// # Safety
/// Requires NEON; `table` must point at 16 readable `i8` values.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn lut16_i8(table: *const i8, nib: uint8x16_t) -> [i8; 16] {
    let t = vld1q_u8(table as *const u8);
    let mut out = [0i8; 16];
    vst1q_u8(out.as_mut_ptr() as *mut u8, vqtbl1q_u8(t, nib));
    out
}

/// 16 parallel lookups into a 16-entry int16 table via byte-plane
/// unzip, two `tbl`s, and a zip back into 16-bit entries.
///
/// # Safety
/// Requires NEON; `table` must point at 16 readable `i16` values.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn lut16_i16(table: *const i16, nib: uint8x16_t) -> [i16; 16] {
    let a = vld1q_u8(table as *const u8); // entries 0..8 as bytes
    let b = vld1q_u8((table as *const u8).add(16)); // entries 8..16
    let lo_plane = vuzp1q_u8(a, b); // even bytes = i16 low bytes (LE)
    let hi_plane = vuzp2q_u8(a, b); // odd bytes = i16 high bytes
    let lo = vqtbl1q_u8(lo_plane, nib);
    let hi = vqtbl1q_u8(hi_plane, nib);
    let mut out = [0i16; 16];
    let p = out.as_mut_ptr() as *mut u8;
    vst1q_u8(p, vzip1q_u8(lo, hi));
    vst1q_u8(p.add(16), vzip2q_u8(lo, hi));
    out
}

/// Pair lookup for one packed byte (int16 tables), 16 rows at once.
///
/// # Safety
/// Requires NEON; `t0` and `t1` must each point at 16 readable `i16`s.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn lut_pair_i16(t0: *const i16, t1: *const i16, bytes: &[u8; 16]) -> ([i16; 16], [i16; 16]) {
    let (lo, hi) = nibbles(bytes);
    (lut16_i16(t0, lo), lut16_i16(t1, hi))
}

/// Pair lookup for one packed byte (int8 tables), 16 rows at once.
///
/// # Safety
/// Requires NEON; `t0` and `t1` must each point at 16 readable `i8`s.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn lut_pair_i8(t0: *const i8, t1: *const i8, bytes: &[u8; 16]) -> ([i8; 16], [i8; 16]) {
    let (lo, hi) = nibbles(bytes);
    (lut16_i8(t0, lo), lut16_i8(t1, hi))
}

/// NEON accumulation over int16 LUTs with two groups per byte — the
/// shared hot loop of TL1_1 and ELUT_C4.
///
/// # Safety
/// Caller must have verified NEON at run time. `data` must hold
/// `rows.end` packed rows of `row_bytes` bytes; `tables` must hold
/// `2 * row_bytes` tables of [`LUT_W`] `i16` entries; `out.len()` must
/// equal `rows.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_lut16(
    data: &[u8],
    row_bytes: usize,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut acc = [0i32; ROW_TILE];
        for b in 0..row_bytes {
            let idx = gather16(data, row_bytes, base, b);
            let t0 = tables.as_ptr().add(2 * b * LUT_W);
            let t1 = tables.as_ptr().add((2 * b + 1) * LUT_W);
            let (v0, v1) = lut_pair_i16(t0, t1, &idx);
            for r in 0..ROW_TILE {
                acc[r] += v0[r] as i32 + v1[r] as i32;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl1::gemv_row_lut16(wrow, tables) as f32 * combined;
    }
}

/// NEON accumulation over int8 LUTs with per-block scales — TL1_0's
/// hot loop. Block flush order matches the scalar path exactly.
///
/// # Safety
/// Caller must have verified NEON at run time. `data` must hold
/// `rows.end` packed rows of `row_bytes` bytes; `tables`/`block_scales`
/// must match `row_bytes` and `block_groups` as produced by the TL1
/// prepare path; `out.len()` must equal `rows.len()`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_lut8(
    data: &[u8],
    row_bytes: usize,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    let bytes_per_block = block_groups / 2;
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut facc = [0f32; ROW_TILE];
        let mut b = 0usize;
        let mut blk = 0usize;
        while b < row_bytes {
            let blk_bytes = bytes_per_block.min(row_bytes - b);
            let tbase = blk * block_groups * LUT_W;
            let mut acc = [0i32; ROW_TILE];
            for bb in 0..blk_bytes {
                let idx = gather16(data, row_bytes, base, b + bb);
                let t0 = tables.as_ptr().add(tbase + 2 * bb * LUT_W);
                let t1 = tables.as_ptr().add(tbase + (2 * bb + 1) * LUT_W);
                let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
            }
            let bs = block_scales[blk];
            for r in 0..ROW_TILE {
                facc[r] += acc[r] as f32 * bs;
            }
            b += blk_bytes;
            blk += 1;
        }
        for r in 0..ROW_TILE {
            out[i + r] = facc[r] * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl1::gemv_row_lut8(wrow, tables, block_scales, block_groups) * combined;
    }
}

/// NEON TL2 lossless accumulation (mirror sign plane + TL1 tail).
///
/// # Safety
/// Caller must have verified NEON at run time. `data` must hold
/// `rows.end` packed TL2 rows matching `layout`; `tables` must hold
/// `(n3 + n2) * LUT_W` `i16` entries; `out.len()` must equal
/// `rows.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_tl2_i16(
    data: &[u8],
    layout: &Tl2Layout,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    let row_bytes = layout.row_bytes();
    let n3 = layout.n3();
    let tl1_off = layout.idx_bytes + layout.sign_bytes;
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut acc = [0i32; ROW_TILE];
        for s in 0..layout.sign_bytes {
            let sb = gather16(data, row_bytes, base, layout.idx_bytes + s);
            let g = 8 * s;
            for j in 0..4 {
                let idx = gather16(data, row_bytes, base, 4 * s + j);
                let t0 = tables.as_ptr().add((g + 2 * j) * LUT_W);
                let t1 = tables.as_ptr().add((g + 2 * j + 1) * LUT_W);
                let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    let m0 = -(((sb[r] >> (2 * j)) & 1) as i32);
                    let m1 = -(((sb[r] >> (2 * j + 1)) & 1) as i32);
                    acc[r] += ((v0[r] as i32) ^ m0) - m0;
                    acc[r] += ((v1[r] as i32) ^ m1) - m1;
                }
            }
        }
        for bb in 0..layout.tl1_bytes {
            let idx = gather16(data, row_bytes, base, tl1_off + bb);
            let t0 = tables.as_ptr().add((n3 + 2 * bb) * LUT_W);
            let t1 = tables.as_ptr().add((n3 + 2 * bb + 1) * LUT_W);
            let (v0, v1) = lut_pair_i16(t0, t1, &idx);
            for r in 0..ROW_TILE {
                acc[r] += v0[r] as i32 + v1[r] as i32;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl2::gemv_row_tl2_i16(wrow, layout, tables) as f32 * combined;
    }
}

/// NEON TL2 fast-path accumulation (int8 tables, per-block scales),
/// replicating the scalar flush schedule byte for byte.
///
/// # Safety
/// Caller must have verified NEON at run time. `data` must hold
/// `rows.end` packed TL2 rows matching `layout`; `tables`/`block_scales`
/// must match the TL2 `_0` prepare path with `block_groups` groups per
/// scale; `out.len()` must equal `rows.len()`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_tl2_i8(
    data: &[u8],
    layout: &Tl2Layout,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    let row_bytes = layout.row_bytes();
    let n3 = layout.n3();
    let tl1_off = layout.idx_bytes + layout.sign_bytes;
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut facc = [0f32; ROW_TILE];
        let mut acc = [0i32; ROW_TILE];
        let mut blk = 0usize;
        let mut in_blk = 0usize;
        for s in 0..layout.sign_bytes {
            let sb = gather16(data, row_bytes, base, layout.idx_bytes + s);
            let g = 8 * s;
            for j in 0..4 {
                let idx = gather16(data, row_bytes, base, 4 * s + j);
                let t0 = tables.as_ptr().add((g + 2 * j) * LUT_W);
                let t1 = tables.as_ptr().add((g + 2 * j + 1) * LUT_W);
                let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    let m0 = -(((sb[r] >> (2 * j)) & 1) as i32);
                    let m1 = -(((sb[r] >> (2 * j + 1)) & 1) as i32);
                    acc[r] += ((v0[r] as i32) ^ m0) - m0;
                    acc[r] += ((v1[r] as i32) ^ m1) - m1;
                }
            }
            in_blk += 8;
            if in_blk == block_groups {
                let bs = block_scales[blk];
                for r in 0..ROW_TILE {
                    facc[r] += acc[r] as f32 * bs;
                }
                acc = [0i32; ROW_TILE];
                blk += 1;
                in_blk = 0;
            }
        }
        for bb in 0..layout.tl1_bytes {
            let idx = gather16(data, row_bytes, base, tl1_off + bb);
            let t0 = tables.as_ptr().add((n3 + 2 * bb) * LUT_W);
            let t1 = tables.as_ptr().add((n3 + 2 * bb + 1) * LUT_W);
            let (v0, v1) = lut_pair_i8(t0, t1, &idx);
            for r in 0..ROW_TILE {
                acc[r] += v0[r] as i32 + v1[r] as i32;
            }
            in_blk += 2;
            if in_blk == block_groups {
                let bs = block_scales[blk];
                for r in 0..ROW_TILE {
                    facc[r] += acc[r] as f32 * bs;
                }
                acc = [0i32; ROW_TILE];
                blk += 1;
                in_blk = 0;
            }
        }
        if in_blk > 0 {
            let bs = block_scales[blk];
            for r in 0..ROW_TILE {
                facc[r] += acc[r] as f32 * bs;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = facc[r] * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl2::gemv_row_tl2_i8(wrow, layout, tables, block_scales, block_groups) * combined;
    }
}

/// NEON ELUT_C5 accumulation: mirror-consolidated int16 tables with one
/// group per nibble and a 1-bit sign plane.
///
/// # Safety
/// Caller must have verified NEON at run time. `data` must hold
/// `rows.end` packed ELUT_C5 rows (`idx_bytes` nibble bytes followed by
/// `idx_bytes / 4` sign bytes per row); `tables` must hold
/// `2 * idx_bytes` tables of [`LUT_W`] `i16` entries; `out.len()` must
/// equal `rows.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_elut5(
    data: &[u8],
    idx_bytes: usize,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    debug_assert_eq!(out.len(), rows.len());
    debug_assert_eq!(idx_bytes % 4, 0);
    let row_bytes = idx_bytes + idx_bytes / 4;
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut acc = [0i32; ROW_TILE];
        for b in 0..idx_bytes {
            let idx = gather16(data, row_bytes, base, b);
            let sb = gather16(data, row_bytes, base, idx_bytes + b / 4);
            let bit0 = 2 * (b % 4);
            let t0 = tables.as_ptr().add(2 * b * LUT_W);
            let t1 = tables.as_ptr().add((2 * b + 1) * LUT_W);
            let (v0, v1) = lut_pair_i16(t0, t1, &idx);
            for r in 0..ROW_TILE {
                let m0 = -(((sb[r] >> bit0) & 1) as i32);
                let m1 = -(((sb[r] >> (bit0 + 1)) & 1) as i32);
                acc[r] += ((v0[r] as i32) ^ m0) - m0;
                acc[r] += ((v1[r] as i32) ^ m1) - m1;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = crate::kernels::elut::gemv_row_elut5(wrow, idx_bytes, tables) as f32 * combined;
    }
}

/// NEON I2_S row accumulation. The scalar body under
/// `target_feature(enable = "neon")` lets LLVM emit the widening
/// multiply-accumulate (`smlal`-family) pattern.
///
/// # Safety
/// Caller must have verified NEON at run time. `wrow.len() * 4` must
/// equal `aq.len()`, and `act_sum` must be the sum of `aq`.
#[target_feature(enable = "neon")]
pub unsafe fn gemv_row_i2s(wrow: &[u8], aq: &[i8], act_sum: i32) -> i32 {
    debug_assert_eq!(wrow.len() * 4, aq.len());
    let mut acc = 0i32;
    let mut k = 0usize;
    for b4 in wrow.chunks_exact(4) {
        let a = &aq[k..k + 16];
        let mut local = 0i32;
        for (bi, &byte) in b4.iter().enumerate() {
            let base = bi * 4;
            local += (byte & 0x3) as i32 * a[base] as i32;
            local += ((byte >> 2) & 0x3) as i32 * a[base + 1] as i32;
            local += ((byte >> 4) & 0x3) as i32 * a[base + 2] as i32;
            local += ((byte >> 6) & 0x3) as i32 * a[base + 3] as i32;
        }
        acc += local;
        k += 16;
    }
    for &byte in wrow.chunks_exact(4).remainder() {
        for j in 0..4 {
            acc += ((byte >> (2 * j)) & 0x3) as i32 * aq[k + j] as i32;
        }
        k += 4;
    }
    acc - act_sum
}

/// NEON I2_S over a row range (the `gemv_rows` shape).
///
/// # Safety
/// Caller must have verified NEON at run time. `data` must hold
/// `rows.end` packed rows of `aq.len() / 4` bytes; `act_sum` must be
/// the sum of `aq`; `out.len()` must equal `rows.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_i2s(
    data: &[u8],
    aq: &[i8],
    act_sum: i32,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
) {
    let row_bytes = aq.len() / 4;
    for (o, r) in out.iter_mut().zip(rows) {
        let wrow = &data[r * row_bytes..(r + 1) * row_bytes];
        *o = gemv_row_i2s(wrow, aq, act_sum) as f32 * combined;
    }
}

/// NEON activation quantization: absmax reduction, then round-clamp-pack
/// to int8 — the prepare-phase half of every lossless kernel.
///
/// Bit-identical to the scalar `quantize_act_int8_into` for finite
/// inputs: f32 `max` is order-free over non-negative finite values, the
/// `v * scale` multiply is the same single f32 op, and `vrndaq_f32`
/// (FRINTA) rounds half away from zero — exactly Rust's `round`. The
/// `vcvtq_s32_f32` truncation sees an integral value, so it is exact.
///
/// # Safety
/// Caller must have verified NEON at run time and pass `q.len() ==
/// x.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn quantize_act_int8(x: &[f32], q: &mut [i8]) -> (f32, i32) {
    debug_assert_eq!(q.len(), x.len());
    let mut vmax = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 4 <= x.len() {
        vmax = vmaxq_f32(vmax, vabsq_f32(vld1q_f32(x.as_ptr().add(i))));
        i += 4;
    }
    let mut max_abs = vmaxvq_f32(vmax);
    for &v in &x[i..] {
        max_abs = max_abs.max(v.abs());
    }
    let max_abs = max_abs.max(1e-5);
    let scale = 127.0 / max_abs;

    let vscale = vdupq_n_f32(scale);
    let lim = vdupq_n_f32(127.0);
    let nlim = vdupq_n_f32(-127.0);
    let mut vsum = vdupq_n_s32(0);
    let mut i = 0usize;
    while i + 8 <= x.len() {
        let r0 = vrndaq_f32(vmulq_f32(vld1q_f32(x.as_ptr().add(i)), vscale));
        let r1 = vrndaq_f32(vmulq_f32(vld1q_f32(x.as_ptr().add(i + 4)), vscale));
        let c0 = vminq_f32(vmaxq_f32(r0, nlim), lim);
        let c1 = vminq_f32(vmaxq_f32(r1, nlim), lim);
        let q0 = vcvtq_s32_f32(c0);
        let q1 = vcvtq_s32_f32(c1);
        vsum = vaddq_s32(vsum, vaddq_s32(q0, q1));
        // Values are in [-127, 127], so the narrowing moves are exact.
        let w16 = vcombine_s16(vmovn_s32(q0), vmovn_s32(q1));
        vst1_s8(q.as_mut_ptr().add(i), vmovn_s16(w16));
        i += 8;
    }
    let mut sum = vaddvq_s32(vsum);
    for (qv, &v) in q[i..].iter_mut().zip(x[i..].iter()) {
        let t = (v * scale).round().clamp(-127.0, 127.0) as i8;
        *qv = t;
        sum += t as i32;
    }
    (scale, sum)
}

/// Sparse [`gemv_rows_lut16`]: the 16-row tile skips a weight block only
/// when every row in the tile has the block's bit clear (one OR over the
/// tile's bitmap words, recomputed lazily per 64 blocks). Rows whose
/// individual block is zero but whose tile-mates are not still run the
/// dense lookups — contributions of exactly 0 — so the result stays
/// bit-identical to the dense and scalar-sparse paths.
///
/// # Safety
/// Same contract as [`gemv_rows_lut16`]; `sidx` must have been built for
/// this tensor's rows with [`tl1::SPARSE_BLOCK_WEIGHTS`]-weight blocks.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_lut16_sparse(
    data: &[u8],
    row_bytes: usize,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    const BLOCK_BYTES: usize = tl1::SPARSE_BLOCK_WEIGHTS / 4;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut acc = [0i32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let b0 = blk * BLOCK_BYTES;
            let b1 = (b0 + BLOCK_BYTES).min(row_bytes);
            for b in b0..b1 {
                let idx = gather16(data, row_bytes, base, b);
                let t0 = tables.as_ptr().add(2 * b * LUT_W);
                let t1 = tables.as_ptr().add((2 * b + 1) * LUT_W);
                let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] =
            tl1::gemv_row_lut16_sparse(wrow, tables, sidx, row, &mut elided) as f32 * combined;
    }
    sparse::note_elided(SimdLevel::Neon, elided);
}

/// Sparse [`gemv_rows_lut8`]: the elision block *is* the requantization
/// scale block, so a tile-skipped block also skips its `0 · block_scale`
/// folds (`+0.0` — block scales are non-negative), keeping the f32
/// accumulators bit-identical to the dense flush schedule.
///
/// # Safety
/// Same contract as [`gemv_rows_lut8`]; `sidx` blocks must coincide with
/// the requantization scale blocks (`block_groups` groups each).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_lut8_sparse(
    data: &[u8],
    row_bytes: usize,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    let bytes_per_block = block_groups / 2;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut facc = [0f32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let b0 = blk * bytes_per_block;
            let blk_bytes = bytes_per_block.min(row_bytes - b0);
            let tbase = blk * block_groups * LUT_W;
            let mut acc = [0i32; ROW_TILE];
            for bb in 0..blk_bytes {
                let idx = gather16(data, row_bytes, base, b0 + bb);
                let t0 = tables.as_ptr().add(tbase + 2 * bb * LUT_W);
                let t1 = tables.as_ptr().add(tbase + (2 * bb + 1) * LUT_W);
                let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
            }
            let bs = block_scales[blk];
            for r in 0..ROW_TILE {
                facc[r] += acc[r] as f32 * bs;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = facc[r] * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] =
            tl1::gemv_row_lut8_sparse(wrow, tables, block_scales, block_groups, sidx, row, &mut elided)
                * combined;
    }
    sparse::note_elided(SimdLevel::Neon, elided);
}

/// Sparse [`gemv_rows_tl2_i16`]: blocks stride the unified group
/// sequence ([`Tl2Layout::sparse_bounds`]); block boundaries land on
/// whole sign bytes in the g=3 region and whole tail bytes in the TL1
/// region, so a nonzero block replays the dense gather schedule exactly
/// over its byte range.
///
/// # Safety
/// Same contract as [`gemv_rows_tl2_i16`]; `sidx` must use the blocks of
/// [`Tl2Layout::sparse_bounds`].
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_tl2_i16_sparse(
    data: &[u8],
    layout: &Tl2Layout,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    let row_bytes = layout.row_bytes();
    let n3 = layout.n3();
    let groups = n3 + layout.n2();
    let tl1_off = layout.idx_bytes + layout.sign_bytes;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut acc = [0i32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let g0 = blk * tl1::LUT_BLOCK_GROUPS;
            let g1 = (g0 + tl1::LUT_BLOCK_GROUPS).min(groups);
            let mut g = g0;
            while g < g1.min(n3) {
                let s = g / 8;
                let sb = gather16(data, row_bytes, base, layout.idx_bytes + s);
                for j in 0..4 {
                    let idx = gather16(data, row_bytes, base, 4 * s + j);
                    let t0 = tables.as_ptr().add((g + 2 * j) * LUT_W);
                    let t1 = tables.as_ptr().add((g + 2 * j + 1) * LUT_W);
                    let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                    for r in 0..ROW_TILE {
                        let m0 = -(((sb[r] >> (2 * j)) & 1) as i32);
                        let m1 = -(((sb[r] >> (2 * j + 1)) & 1) as i32);
                        acc[r] += ((v0[r] as i32) ^ m0) - m0;
                        acc[r] += ((v1[r] as i32) ^ m1) - m1;
                    }
                }
                g += 8;
            }
            let mut tg = g.max(n3) - n3;
            let tg_end = g1.saturating_sub(n3);
            while tg < tg_end {
                let bb = tg / 2;
                let idx = gather16(data, row_bytes, base, tl1_off + bb);
                let t0 = tables.as_ptr().add((n3 + 2 * bb) * LUT_W);
                let t1 = tables.as_ptr().add((n3 + 2 * bb + 1) * LUT_W);
                let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
                tg += 2;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl2::gemv_row_tl2_i16_sparse(wrow, layout, tables, sidx, row, &mut elided) as f32
            * combined;
    }
    sparse::note_elided(SimdLevel::Neon, elided);
}

/// Sparse [`gemv_rows_tl2_i8`]: the elision block *is* the scale block,
/// so each nonzero block runs the dense gathers over its group range and
/// folds one scale; skipped blocks drop a `+0.0` fold.
///
/// # Safety
/// Same contract as [`gemv_rows_tl2_i8`]; `sidx` must use the blocks of
/// [`Tl2Layout::sparse_bounds`] with `block_groups` groups per block.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_tl2_i8_sparse(
    data: &[u8],
    layout: &Tl2Layout,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    debug_assert_eq!(block_groups % 8, 0, "blocks must cover whole sign bytes");
    let row_bytes = layout.row_bytes();
    let n3 = layout.n3();
    let groups = n3 + layout.n2();
    let tl1_off = layout.idx_bytes + layout.sign_bytes;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut facc = [0f32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let g0 = blk * block_groups;
            let g1 = (g0 + block_groups).min(groups);
            let mut acc = [0i32; ROW_TILE];
            let mut g = g0;
            while g < g1.min(n3) {
                let s = g / 8;
                let sb = gather16(data, row_bytes, base, layout.idx_bytes + s);
                for j in 0..4 {
                    let idx = gather16(data, row_bytes, base, 4 * s + j);
                    let t0 = tables.as_ptr().add((g + 2 * j) * LUT_W);
                    let t1 = tables.as_ptr().add((g + 2 * j + 1) * LUT_W);
                    let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                    for r in 0..ROW_TILE {
                        let m0 = -(((sb[r] >> (2 * j)) & 1) as i32);
                        let m1 = -(((sb[r] >> (2 * j + 1)) & 1) as i32);
                        acc[r] += ((v0[r] as i32) ^ m0) - m0;
                        acc[r] += ((v1[r] as i32) ^ m1) - m1;
                    }
                }
                g += 8;
            }
            let mut tg = g.max(n3) - n3;
            let tg_end = g1.saturating_sub(n3);
            while tg < tg_end {
                let bb = tg / 2;
                let idx = gather16(data, row_bytes, base, tl1_off + bb);
                let t0 = tables.as_ptr().add((n3 + 2 * bb) * LUT_W);
                let t1 = tables.as_ptr().add((n3 + 2 * bb + 1) * LUT_W);
                let (v0, v1) = lut_pair_i8(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    acc[r] += v0[r] as i32 + v1[r] as i32;
                }
                tg += 2;
            }
            let bs = block_scales[blk];
            for r in 0..ROW_TILE {
                facc[r] += acc[r] as f32 * bs;
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = facc[r] * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = tl2::gemv_row_tl2_i8_sparse(
            wrow,
            layout,
            tables,
            block_scales,
            block_groups,
            sidx,
            row,
            &mut elided,
        ) * combined;
    }
    sparse::note_elided(SimdLevel::Neon, elided);
}

/// Sparse [`gemv_rows_elut5`]: one block covers 16 index bytes (32
/// groups), so the `b % 4` sign-byte addressing of the dense loop is
/// preserved inside every block (`b0` is a multiple of 4).
///
/// # Safety
/// Same contract as [`gemv_rows_elut5`]; `sidx` must use
/// [`tl1::SPARSE_BLOCK_WEIGHTS`]-weight blocks.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_elut5_sparse(
    data: &[u8],
    idx_bytes: usize,
    tables: &[i16],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    debug_assert_eq!(out.len(), rows.len());
    const BLOCK_IDX_BYTES: usize = tl1::SPARSE_BLOCK_WEIGHTS / 4;
    let row_bytes = idx_bytes + idx_bytes / 4;
    let nblocks = sidx.blocks_per_row();
    let n = rows.len();
    let full = n - n % ROW_TILE;
    let mut elided = 0u64;
    let mut i = 0usize;
    while i < full {
        let base = rows.start + i;
        let mut bits = TileBits::new(sidx, base, ROW_TILE);
        let mut acc = [0i32; ROW_TILE];
        for blk in 0..nblocks {
            if !bits.any_nonzero(blk) {
                elided += ROW_TILE as u64;
                continue;
            }
            let b0 = blk * BLOCK_IDX_BYTES;
            let b1 = (b0 + BLOCK_IDX_BYTES).min(idx_bytes);
            for b in b0..b1 {
                let idx = gather16(data, row_bytes, base, b);
                let sb = gather16(data, row_bytes, base, idx_bytes + b / 4);
                let bit0 = 2 * (b % 4);
                let t0 = tables.as_ptr().add(2 * b * LUT_W);
                let t1 = tables.as_ptr().add((2 * b + 1) * LUT_W);
                let (v0, v1) = lut_pair_i16(t0, t1, &idx);
                for r in 0..ROW_TILE {
                    let m0 = -(((sb[r] >> bit0) & 1) as i32);
                    let m1 = -(((sb[r] >> (bit0 + 1)) & 1) as i32);
                    acc[r] += ((v0[r] as i32) ^ m0) - m0;
                    acc[r] += ((v1[r] as i32) ^ m1) - m1;
                }
            }
        }
        for r in 0..ROW_TILE {
            out[i + r] = acc[r] as f32 * combined;
        }
        i += ROW_TILE;
    }
    for r in i..n {
        let row = rows.start + r;
        let wrow = &data[row * row_bytes..(row + 1) * row_bytes];
        out[r] = crate::kernels::elut::gemv_row_elut5_sparse(
            wrow,
            idx_bytes,
            tables,
            sidx,
            row,
            &mut elided,
        ) as f32
            * combined;
    }
    sparse::note_elided(SimdLevel::Neon, elided);
}

/// Sparse NEON I2_S row: nonzero blocks accumulate `Σ a·(code − 1)`
/// directly, so no `act_sum` correction is needed and skipped blocks
/// contribute exactly nothing; the scalar body under `target_feature`
/// keeps LLVM's widening multiply-accumulate pattern. Exact i32 — equal
/// to the dense `Σ a·code − act_sum` by construction.
///
/// # Safety
/// Caller must have verified NEON at run time. `wrow.len() * 4` must
/// equal `aq.len()` and `sidx` must use
/// [`crate::kernels::i2s::SPARSE_BLOCK_WEIGHTS`]-weight blocks.
#[target_feature(enable = "neon")]
unsafe fn gemv_row_i2s_sparse(
    wrow: &[u8],
    aq: &[i8],
    sidx: &SparseIndex,
    row: usize,
    elided: &mut u64,
) -> i32 {
    debug_assert_eq!(wrow.len() * 4, aq.len());
    const BLOCK_BYTES: usize = crate::kernels::i2s::SPARSE_BLOCK_WEIGHTS / 4;
    let mut acc = 0i32;
    for blk in 0..sidx.blocks_per_row() {
        if !sidx.is_nonzero(row, blk) {
            *elided += 1;
            continue;
        }
        let b0 = blk * BLOCK_BYTES;
        let b1 = (b0 + BLOCK_BYTES).min(wrow.len());
        let mut k = b0 * 4;
        for b4 in wrow[b0..b1].chunks_exact(4) {
            let a = &aq[k..k + 16];
            let mut local = 0i32;
            for (bi, &byte) in b4.iter().enumerate() {
                let base = bi * 4;
                local += ((byte & 0x3) as i32 - 1) * a[base] as i32;
                local += (((byte >> 2) & 0x3) as i32 - 1) * a[base + 1] as i32;
                local += (((byte >> 4) & 0x3) as i32 - 1) * a[base + 2] as i32;
                local += (((byte >> 6) & 0x3) as i32 - 1) * a[base + 3] as i32;
            }
            acc += local;
            k += 16;
        }
        for &byte in wrow[b0..b1].chunks_exact(4).remainder() {
            for j in 0..4 {
                acc += (((byte >> (2 * j)) & 0x3) as i32 - 1) * aq[k + j] as i32;
            }
            k += 4;
        }
    }
    acc
}

/// Sparse NEON I2_S over a row range.
///
/// # Safety
/// Caller must have verified NEON at run time. `data` must hold
/// `rows.end` packed rows of `aq.len() / 4` bytes; `out.len()` must
/// equal `rows.len()`; `sidx` must match the tensor's packing.
#[target_feature(enable = "neon")]
pub unsafe fn gemv_rows_i2s_sparse(
    data: &[u8],
    aq: &[i8],
    combined: f32,
    out: &mut [f32],
    rows: Range<usize>,
    sidx: &SparseIndex,
) {
    let row_bytes = aq.len() / 4;
    let mut elided = 0u64;
    for (o, r) in out.iter_mut().zip(rows) {
        let wrow = &data[r * row_bytes..(r + 1) * row_bytes];
        *o = gemv_row_i2s_sparse(wrow, aq, sidx, r, &mut elided) as f32 * combined;
    }
    sparse::note_elided(SimdLevel::Neon, elided);
}

/// Vectorized LUT table build for the g=2 kernels (prepare phase): for
/// each activation pair `(a0, a1) = (aq[2g], aq[2g+1])` fill the whole
/// 16-entry table `tables[g·16 + c] = a0·w0[c] + a1·w1[c]` with two
/// 8-lane multiply-add passes. Padding slots carry zero weight
/// patterns, so the result equals the scalar fill-then-write loop bit
/// for bit — all arithmetic is exact in i16 (|a| ≤ 128, |w| ≤ 2 ⇒
/// |entry| ≤ 512).
///
/// # Safety
/// Caller must have verified NEON at run time. `aq.len()` must be even
/// and `tables.len()` must equal `(aq.len() / 2) * LUT_W`.
#[target_feature(enable = "neon")]
pub unsafe fn build_lut16_pair_tables(
    aq: &[i8],
    w0: &[i16; LUT_W],
    w1: &[i16; LUT_W],
    tables: &mut [i16],
) {
    debug_assert_eq!(aq.len() % 2, 0);
    debug_assert_eq!(tables.len(), aq.len() / 2 * LUT_W);
    let w0a = vld1q_s16(w0.as_ptr());
    let w0b = vld1q_s16(w0.as_ptr().add(8));
    let w1a = vld1q_s16(w1.as_ptr());
    let w1b = vld1q_s16(w1.as_ptr().add(8));
    let out = tables.as_mut_ptr();
    for (g, pair) in aq.chunks_exact(2).enumerate() {
        let a0 = vdupq_n_s16(pair[0] as i16);
        let a1 = vdupq_n_s16(pair[1] as i16);
        vst1q_s16(out.add(g * LUT_W), vmlaq_s16(vmulq_s16(a0, w0a), a1, w1a));
        vst1q_s16(out.add(g * LUT_W + 8), vmlaq_s16(vmulq_s16(a0, w0b), a1, w1b));
    }
}

/// [`build_lut16_pair_tables`] for g=3 trios (the TL2 mirror region):
/// `tables[g·16 + h] = a0·w0[h] + a1·w1[h] + a2·w2[h]`.
///
/// # Safety
/// Caller must have verified NEON at run time. `aq.len()` must be a
/// multiple of 3 and `tables.len()` must equal `(aq.len() / 3) * LUT_W`.
#[target_feature(enable = "neon")]
pub unsafe fn build_lut16_trio_tables(
    aq: &[i8],
    w0: &[i16; LUT_W],
    w1: &[i16; LUT_W],
    w2: &[i16; LUT_W],
    tables: &mut [i16],
) {
    debug_assert_eq!(aq.len() % 3, 0);
    debug_assert_eq!(tables.len(), aq.len() / 3 * LUT_W);
    let w0a = vld1q_s16(w0.as_ptr());
    let w0b = vld1q_s16(w0.as_ptr().add(8));
    let w1a = vld1q_s16(w1.as_ptr());
    let w1b = vld1q_s16(w1.as_ptr().add(8));
    let w2a = vld1q_s16(w2.as_ptr());
    let w2b = vld1q_s16(w2.as_ptr().add(8));
    let out = tables.as_mut_ptr();
    for (g, trio) in aq.chunks_exact(3).enumerate() {
        let a0 = vdupq_n_s16(trio[0] as i16);
        let a1 = vdupq_n_s16(trio[1] as i16);
        let a2 = vdupq_n_s16(trio[2] as i16);
        let lo = vmlaq_s16(vmlaq_s16(vmulq_s16(a0, w0a), a1, w1a), a2, w2a);
        let hi = vmlaq_s16(vmlaq_s16(vmulq_s16(a0, w0b), a1, w1b), a2, w2b);
        vst1q_s16(out.add(g * LUT_W), lo);
        vst1q_s16(out.add(g * LUT_W + 8), hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_neon() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    #[test]
    fn lut16_i8_matches_scalar_lookup() {
        if !have_neon() {
            return;
        }
        let table: [i8; 16] = core::array::from_fn(|i| (i as i8) * 3 - 20);
        let bytes: [u8; 16] =
            core::array::from_fn(|i| ((i * 7) % 16) as u8 | (((i * 3) % 14) as u8) << 4);
        // SAFETY: NEON presence checked above; table/bytes are 16 wide.
        let (v0, v1) = unsafe { lut_pair_i8(table.as_ptr(), table.as_ptr(), &bytes) };
        for i in 0..16 {
            assert_eq!(v0[i], table[(bytes[i] & 0xf) as usize], "lo {i}");
            assert_eq!(v1[i], table[(bytes[i] >> 4) as usize], "hi {i}");
        }
    }

    #[test]
    fn lut16_i16_matches_scalar_lookup() {
        if !have_neon() {
            return;
        }
        let table: [i16; 16] = core::array::from_fn(|i| (i as i16) * -2500 + 7);
        let bytes: [u8; 16] = core::array::from_fn(|i| (i as u8) | ((15 - i as u8) << 4));
        // SAFETY: NEON presence checked above; table/bytes are 16 wide.
        let (v0, v1) = unsafe { lut_pair_i16(table.as_ptr(), table.as_ptr(), &bytes) };
        for i in 0..16 {
            assert_eq!(v0[i], table[(bytes[i] & 0xf) as usize], "lo {i}");
            assert_eq!(v1[i], table[(bytes[i] >> 4) as usize], "hi {i}");
        }
    }
}
