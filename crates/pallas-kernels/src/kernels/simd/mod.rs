//! SIMD layer of the mpGEMM kernel library.
//!
//! The paper's speedups rest on two instruction families: 16-entry
//! table *gathers* (`vpshufb` on AVX2, `tbl`/`vqtbl1q_u8` on NEON) for
//! the LUT kernels, and widening `maddubs`-style multiply-adds for the
//! MAD kernels. The explicit vector implementations live in [`avx2`]
//! and [`neon`]; which one runs is the process-wide dispatch decision
//! owned by [`pallas_core::simd`] since the attention/ops vector layer
//! joined the kernels as a dispatch consumer — everything is re-exported
//! here under the historical paths ([`SimdLevel`], [`active_level`],
//! [`with_level`], [`note_call`], …), so kernel code and embedders are
//! unaffected by the move.
//!
//! The vector paths are **bit-identical** to the scalar ones by
//! construction: all inner accumulation is integer (reassociation-safe),
//! and the only ordered float operations — the per-block scale folds of
//! the `_0` LUT variants — replicate the scalar block order exactly
//! (see `rust/tests/simd_identity.rs`).

pub use pallas_core::simd::{
    active_level, available_levels, call_counts, clamp, detect, note_call, set_level, usable,
    with_level, SimdLevel,
};

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "aarch64")]
pub mod neon;

/// The vector tiers the *compile target* can reach for the vectorized
/// kernels (TL1/TL2/I2_S/ELUT). Scalar-only on other architectures.
#[cfg(target_arch = "x86_64")]
pub const KERNEL_LEVELS: &[SimdLevel] = &[SimdLevel::Scalar, SimdLevel::Avx2];
/// The vector tiers the *compile target* can reach for the vectorized
/// kernels (TL1/TL2/I2_S/ELUT). Scalar-only on other architectures.
#[cfg(target_arch = "aarch64")]
pub const KERNEL_LEVELS: &[SimdLevel] = &[SimdLevel::Scalar, SimdLevel::Neon];
/// The vector tiers the *compile target* can reach for the vectorized
/// kernels (TL1/TL2/I2_S/ELUT). Scalar-only on other architectures.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub const KERNEL_LEVELS: &[SimdLevel] = &[SimdLevel::Scalar];
