//! **TL2** — element-wise LUT-based ternary kernel with group size g=3,
//! element-wise mirror consolidation, signed-unsigned weight splitting and
//! block-fitting weight splitting (paper §3.1, Algorithm 4, Tables 6).
//!
//! Storage per group of 3 ternary weights: a **4-bit index** into the
//! 14-entry mirror-consolidated table plus a **1-bit sign** stored in a
//! separate plane (signed-unsigned weight splitting, Fig. 5), i.e.
//! 5 bits / 3 weights = **1.67 bpw** — below the 2-bit alignment floor of
//! bit-wise methods.
//!
//! Because most model dimensions K are not multiples of 3, the row is
//! split *block-fitting* style (Fig. 6): `ThreeK = ⌊K/BK3⌋·BK3` leading
//! weights use g=3, and the `TwoK = K−ThreeK` tail is computed with the
//! TL1 (g=2) scheme — no padding, no misaligned blocks.
//!
//! Variants: **TL2_0** (int8-requantized tables, fast) and **TL2_1**
//! (int16 tables via pack-and-unpack, lossless).

use super::lut::{decode_code, mirror_join, mirror_split, sign_apply_i32};
use super::quant::{quantize_act_int8_into, TernaryWeights};
use super::simd::{self, SimdLevel};
use super::sparse;
use super::tl1::{
    build_tables_tl1_into, pack_row_tl1, requantize_tables_into, LUT_BLOCK_GROUPS, LUT_W,
};
use super::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};

const TERNARY: [i8; 3] = [-1, 0, 1];

/// Granularity of the g=3 region: ThreeK is a multiple of BK3 so the index
/// plane (2 groups/byte) and the sign plane (8 groups/byte) both stay
/// byte-aligned — the paper's "block-fitting" constraint.
pub const BK3: usize = 24;

/// Geometry of one TL2 row for a given K.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tl2Layout {
    /// Leading weights handled with g=3.
    pub three_k: usize,
    /// Trailing weights handled with g=2 (TL1 scheme).
    pub two_k: usize,
    /// Bytes of the 4-bit index plane.
    pub idx_bytes: usize,
    /// Bytes of the 1-bit sign plane.
    pub sign_bytes: usize,
    /// Bytes of the TL1 tail.
    pub tl1_bytes: usize,
}

impl Tl2Layout {
    pub fn new(k: usize) -> Tl2Layout {
        assert_eq!(k % 4, 0, "TL2 requires K % 4 == 0");
        let three_k = (k / BK3) * BK3;
        let two_k = k - three_k;
        debug_assert_eq!(two_k % 4, 0);
        Tl2Layout {
            three_k,
            two_k,
            idx_bytes: three_k / 6,   // 2 nibble codes per byte, 3 weights per code
            sign_bytes: three_k / 24, // 8 sign bits per byte
            tl1_bytes: two_k / 4,
        }
    }

    pub fn row_bytes(&self) -> usize {
        self.idx_bytes + self.sign_bytes + self.tl1_bytes
    }

    /// Number of g=3 groups.
    pub fn n3(&self) -> usize {
        self.three_k / 3
    }

    /// Number of g=2 tail groups.
    pub fn n2(&self) -> usize {
        self.two_k / 2
    }

    /// First weight index of unified group `g` (g=3 region first, then
    /// the g=2 tail; `g == n3` maps to `three_k` from either side).
    fn group_weight(&self, g: usize) -> usize {
        let n3 = self.n3();
        if g <= n3 {
            3 * g
        } else {
            self.three_k + 2 * (g - n3)
        }
    }

    /// Per-block weight ranges for the sparse index: blocks stride the
    /// unified group sequence in [`LUT_BLOCK_GROUPS`]-group steps — the
    /// same schedule as the `_0` requantization scale blocks, so one
    /// elided block skips exactly one scale fold. A block may span the
    /// g=3 → tail boundary; the range covers both regions' weights.
    pub fn sparse_bounds(&self) -> Vec<std::ops::Range<usize>> {
        let groups = self.n3() + self.n2();
        let mut bounds = Vec::with_capacity(groups.div_ceil(LUT_BLOCK_GROUPS));
        let mut g = 0usize;
        while g < groups {
            let g1 = (g + LUT_BLOCK_GROUPS).min(groups);
            bounds.push(self.group_weight(g)..self.group_weight(g1));
            g = g1;
        }
        bounds
    }
}

/// Pack one ternary row into (index plane, sign plane, TL1 tail).
pub fn pack_row_tl2(row: &[i8], layout: &Tl2Layout, out: &mut [u8]) {
    debug_assert_eq!(row.len(), layout.three_k + layout.two_k);
    debug_assert_eq!(out.len(), layout.row_bytes());
    let (idx_plane, rest) = out.split_at_mut(layout.idx_bytes);
    let (sign_plane, tl1_tail) = rest.split_at_mut(layout.sign_bytes);

    for (g, trio) in row[..layout.three_k].chunks_exact(3).enumerate() {
        let code = ((trio[0] + 1) as usize) * 9 + ((trio[1] + 1) as usize) * 3 + (trio[2] + 1) as usize;
        let (sign, half) = mirror_split(code, 3, 3);
        debug_assert!(half < 14);
        if g % 2 == 0 {
            idx_plane[g / 2] = half as u8;
        } else {
            idx_plane[g / 2] |= (half as u8) << 4;
        }
        sign_plane[g / 8] |= sign << (g % 8);
    }
    if layout.two_k > 0 {
        pack_row_tl1(&row[layout.three_k..], tl1_tail);
    }
}

/// Build the int16 tables for TL2: one 16-entry table per g=3 group over
/// the *unsigned* (positive-half) enumeration, followed by the TL1 pair
/// tables for the tail. The concatenation keeps every group at 16 entries
/// so the `_0` requantization blocks stay uniform.
pub fn build_tables_tl2(aq: &[i8], layout: &Tl2Layout) -> Vec<i16> {
    let mut tables = vec![0i16; (layout.n3() + layout.n2()) * LUT_W];
    build_tables_tl2_into(aq, layout, &mut tables);
    tables
}

/// Allocation-free [`build_tables_tl2`]: fills the caller-owned table
/// buffer (`(n3 + n2) * LUT_W` entries), zeroing the padding slots.
pub fn build_tables_tl2_into(aq: &[i8], layout: &Tl2Layout, tables: &mut [i16]) {
    let n3 = layout.n3();
    debug_assert_eq!(tables.len(), (n3 + layout.n2()) * LUT_W);
    build_trio_region(&aq[..layout.three_k], &mut tables[..n3 * LUT_W]);
    if layout.two_k > 0 {
        build_tables_tl1_into(&aq[layout.three_k..], &mut tables[n3 * LUT_W..]);
    }
}

/// Per-slot weight patterns of the positive-half g=3 enumeration (paper
/// Table 6): slot `h` holds the trio decoded from
/// `mirror_join(0, h, 3, 3)`; padding slots 14/15 stay zero. Derived
/// once from the same decode the pack/unpack paths use, so the scalar
/// and vector table builders provably tabulate the same enumeration.
fn trio_patterns() -> (&'static [i16; LUT_W], &'static [i16; LUT_W], &'static [i16; LUT_W]) {
    static PATTERNS: std::sync::OnceLock<([i16; LUT_W], [i16; LUT_W], [i16; LUT_W])> =
        std::sync::OnceLock::new();
    let (w0, w1, w2) = PATTERNS.get_or_init(|| {
        let mut p = ([0i16; LUT_W], [0i16; LUT_W], [0i16; LUT_W]);
        for half in 0..14 {
            let w = decode_code(mirror_join(0, half, 3, 3), 3, 3, &TERNARY);
            p.0[half] = w[0] as i16;
            p.1[half] = w[1] as i16;
            p.2[half] = w[2] as i16;
        }
        p
    });
    (w0, w1, w2)
}

/// Tabulate the g=3 mirror-consolidated region: one 16-entry table per
/// activation trio over the positive-half enumeration.
fn build_trio_region(aq: &[i8], tables: &mut [i16]) {
    debug_assert_eq!(aq.len() % 3, 0);
    debug_assert_eq!(tables.len(), (aq.len() / 3) * LUT_W);
    let (w0, w1, w2) = trio_patterns();
    #[cfg(target_arch = "x86_64")]
    if simd::active_level() == SimdLevel::Avx2 {
        // SAFETY: AVX2 verified by the active dispatch level; the trio
        // count and table length match the builder's shape contract.
        unsafe { simd::avx2::build_lut16_trio_tables(aq, w0, w1, w2, tables) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_level() == SimdLevel::Neon {
        // SAFETY: NEON verified by the active dispatch level; the trio
        // count and table length match the builder's shape contract.
        unsafe { simd::neon::build_lut16_trio_tables(aq, w0, w1, w2, tables) };
        return;
    }
    tables.fill(0);
    for (g, trio) in aq.chunks_exact(3).enumerate() {
        let (a0, a1, a2) = (trio[0] as i16, trio[1] as i16, trio[2] as i16);
        let t = &mut tables[g * LUT_W..(g + 1) * LUT_W];
        for half in 0..14 {
            t[half] = a0 * w0[half] + a1 * w1[half] + a2 * w2[half];
        }
    }
}

/// TL2 kernel; `LOSSLESS = false` → TL2_0, `true` → TL2_1.
pub struct Tl2Kernel<const LOSSLESS: bool>;

/// TL2_0: int8-requantized LUT, bpw 1.67 (the paper's headline kernel).
pub static TL2_0: Tl2Kernel<false> = Tl2Kernel::<false>;
/// TL2_1: int16 LUT, lossless, bpw 1.67.
pub static TL2_1: Tl2Kernel<true> = Tl2Kernel::<true>;

impl<const LOSSLESS: bool> Kernel for Tl2Kernel<LOSSLESS> {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: if LOSSLESS { QuantType::Tl21 } else { QuantType::Tl20 },
            name: if LOSSLESS { "TL2_1" } else { "TL2_0" },
            class: KernelClass::LutBased,
            element_wise: true,
            bpw: 5.0 / 3.0,
            lossless: LOSSLESS,
            // Block-fitting weight splitting handles any K % 4 == 0: the
            // g=3 region covers ⌊K/24⌋·24 and the TL1 tail the rest.
            k_multiple: 4,
            ternary_native: true,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let layout = Tl2Layout::new(w.k);
        let row_bytes = layout.row_bytes();
        let mut data = vec![0u8; w.m * row_bytes];
        for r in 0..w.m {
            pack_row_tl2(w.row(r), &layout, &mut data[r * row_bytes..(r + 1) * row_bytes]);
        }
        let bounds = layout.sparse_bounds();
        let sparse = sparse::maybe_index(&w.q, w.m, w.k, &bounds);
        QTensor { qtype: self.info().qtype, m: w.m, k: w.k, data, scale: w.scale, sparse }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let layout = Tl2Layout::new(t.k);
        let row_bytes = layout.row_bytes();
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
            let (idx_plane, rest) = row.split_at(layout.idx_bytes);
            let (sign_plane, tl1_tail) = rest.split_at(layout.sign_bytes);
            for g in 0..layout.n3() {
                let nib = if g % 2 == 0 { idx_plane[g / 2] & 0xf } else { idx_plane[g / 2] >> 4 };
                let sign = (sign_plane[g / 8] >> (g % 8)) & 1;
                let code = mirror_join(sign, nib as usize, 3, 3);
                for w in decode_code(code, 3, 3, &TERNARY) {
                    out.push(w as f32 * t.scale);
                }
            }
            for &byte in tl1_tail {
                for code in [byte & 0xf, byte >> 4] {
                    for w in decode_code(code as usize, 3, 2, &TERNARY) {
                        out.push(w as f32 * t.scale);
                    }
                }
            }
        }
        out
    }

    fn prepare_kind(&self, k: usize) -> PrepareKind {
        let layout = Tl2Layout::new(k);
        let groups = layout.n3() + layout.n2();
        if LOSSLESS {
            PrepareKind::LutI16 { groups }
        } else {
            PrepareKind::LutI8 { groups, block_groups: LUT_BLOCK_GROUPS }
        }
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        let layout = Tl2Layout::new(k);
        match dst {
            PreparedRowMut::LutI16 { aq, tables, scale } => {
                let (s, _) = quantize_act_int8_into(x, aq);
                build_tables_tl2_into(aq, &layout, tables);
                *scale = s;
            }
            PreparedRowMut::LutI8 { aq, tmp16, tables, block_scales, scale } => {
                let (s, _) = quantize_act_int8_into(x, aq);
                build_tables_tl2_into(aq, &layout, tmp16);
                requantize_tables_into(tmp16, LUT_BLOCK_GROUPS, tables, block_scales);
                *scale = s;
            }
            _ => panic!("TL2 expects a LUT destination"),
        }
    }

    fn simd_levels(&self) -> &'static [SimdLevel] {
        simd::KERNEL_LEVELS
    }

    fn sparse_capable(&self) -> bool {
        true
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let layout = Tl2Layout::new(t.k);
        let row_bytes = layout.row_bytes();
        let level = simd::active_level();
        simd::note_call(level);
        match p {
            PreparedRow::LutI16 { tables, scale } => {
                let combined = t.scale / scale;
                if let Some(idx) = &t.sparse {
                    #[cfg(target_arch = "x86_64")]
                    if level == SimdLevel::Avx2 {
                        // SAFETY: AVX2 verified by the active dispatch level;
                        // buffer shapes are guaranteed by quantize/prepare.
                        unsafe {
                            simd::avx2::gemv_rows_tl2_i16_sparse(
                                &t.data, &layout, tables, combined, out, rows, idx,
                            );
                        }
                        return;
                    }
                    #[cfg(target_arch = "aarch64")]
                    if level == SimdLevel::Neon {
                        // SAFETY: NEON verified by the active dispatch level;
                        // buffer shapes are guaranteed by quantize/prepare.
                        unsafe {
                            simd::neon::gemv_rows_tl2_i16_sparse(
                                &t.data, &layout, tables, combined, out, rows, idx,
                            );
                        }
                        return;
                    }
                    let mut elided = 0u64;
                    for (o, r) in out.iter_mut().zip(rows) {
                        let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
                        *o = gemv_row_tl2_i16_sparse(row, &layout, tables, idx, r, &mut elided)
                            as f32
                            * combined;
                    }
                    sparse::note_elided(level, elided);
                    return;
                }
                #[cfg(target_arch = "x86_64")]
                if level == SimdLevel::Avx2 {
                    // SAFETY: AVX2 verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::avx2::gemv_rows_tl2_i16(&t.data, &layout, tables, combined, out, rows);
                    }
                    return;
                }
                #[cfg(target_arch = "aarch64")]
                if level == SimdLevel::Neon {
                    // SAFETY: NEON verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::neon::gemv_rows_tl2_i16(&t.data, &layout, tables, combined, out, rows);
                    }
                    return;
                }
                for (o, r) in out.iter_mut().zip(rows) {
                    let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
                    *o = gemv_row_tl2_i16(row, &layout, tables) as f32 * combined;
                }
            }
            PreparedRow::LutI8 { tables, block_scales, block_groups, scale } => {
                let combined = t.scale / scale;
                if let Some(idx) = &t.sparse {
                    #[cfg(target_arch = "x86_64")]
                    if level == SimdLevel::Avx2 {
                        // SAFETY: AVX2 verified by the active dispatch level;
                        // buffer shapes are guaranteed by quantize/prepare.
                        unsafe {
                            simd::avx2::gemv_rows_tl2_i8_sparse(
                                &t.data,
                                &layout,
                                tables,
                                block_scales,
                                block_groups,
                                combined,
                                out,
                                rows,
                                idx,
                            );
                        }
                        return;
                    }
                    #[cfg(target_arch = "aarch64")]
                    if level == SimdLevel::Neon {
                        // SAFETY: NEON verified by the active dispatch level;
                        // buffer shapes are guaranteed by quantize/prepare.
                        unsafe {
                            simd::neon::gemv_rows_tl2_i8_sparse(
                                &t.data,
                                &layout,
                                tables,
                                block_scales,
                                block_groups,
                                combined,
                                out,
                                rows,
                                idx,
                            );
                        }
                        return;
                    }
                    let mut elided = 0u64;
                    for (o, r) in out.iter_mut().zip(rows) {
                        let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
                        *o = gemv_row_tl2_i8_sparse(
                            row,
                            &layout,
                            tables,
                            block_scales,
                            block_groups,
                            idx,
                            r,
                            &mut elided,
                        ) * combined;
                    }
                    sparse::note_elided(level, elided);
                    return;
                }
                #[cfg(target_arch = "x86_64")]
                if level == SimdLevel::Avx2 {
                    // SAFETY: AVX2 verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::avx2::gemv_rows_tl2_i8(
                            &t.data,
                            &layout,
                            tables,
                            block_scales,
                            block_groups,
                            combined,
                            out,
                            rows,
                        );
                    }
                    return;
                }
                #[cfg(target_arch = "aarch64")]
                if level == SimdLevel::Neon {
                    // SAFETY: NEON verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::neon::gemv_rows_tl2_i8(
                            &t.data,
                            &layout,
                            tables,
                            block_scales,
                            block_groups,
                            combined,
                            out,
                            rows,
                        );
                    }
                    return;
                }
                for (o, r) in out.iter_mut().zip(rows) {
                    let row = &t.data[r * row_bytes..(r + 1) * row_bytes];
                    *o = gemv_row_tl2_i8(row, &layout, tables, block_scales, block_groups)
                        * combined;
                }
            }
            _ => panic!("TL2 expects a LUT-prepared activation"),
        }
    }
}

/// Lossless accumulation over the split row: g=3 lookups with the 1-bit
/// sign operation, then the TL1 tail.
///
/// §Perf: signs are handled with two accumulators (`accs[sign]`) instead
/// of a per-element conditional negate — one indexed add replaces the
/// add+xor of Eq. 5 and removes a data dependency on the sign bit.
#[inline]
pub fn gemv_row_tl2_i16(row: &[u8], layout: &Tl2Layout, tables: &[i16]) -> i32 {
    let (idx_plane, rest) = row.split_at(layout.idx_bytes);
    let (sign_plane, tl1_tail) = rest.split_at(layout.sign_bytes);
    let n3 = layout.n3();
    let mut accs = [0i32; 2];
    // 8 groups per sign byte, 2 groups per index byte → process 8 at a time.
    let mut g = 0usize;
    for &sbyte in sign_plane {
        // 4 index bytes cover the same 8 groups.
        let ib = g / 2;
        let tb = g * LUT_W;
        for j in 0..4 {
            // SAFETY: each sign byte covers 4 index bytes and 8 tables;
            // the layout sizes both planes and nibble codes are < LUT_W.
            let byte = unsafe { *idx_plane.get_unchecked(ib + j) };
            let t0 = tb + 2 * j * LUT_W;
            // SAFETY: as above.
            let v0 = unsafe { *tables.get_unchecked(t0 + (byte & 0xf) as usize) } as i32;
            // SAFETY: as above.
            let v1 = unsafe { *tables.get_unchecked(t0 + LUT_W + (byte >> 4) as usize) } as i32;
            accs[((sbyte >> (2 * j)) & 1) as usize] += v0;
            accs[((sbyte >> (2 * j + 1)) & 1) as usize] += v1;
        }
        g += 8;
    }
    let mut acc = accs[0] - accs[1];
    // TL1 tail (tables offset by the n3 g=3 tables).
    let mut gg = n3;
    for &byte in tl1_tail {
        // SAFETY: the tail holds n2 groups of LUT_W entries after the n3
        // g=3 tables; nibble codes are < LUT_W.
        acc += unsafe { *tables.get_unchecked(gg * LUT_W + (byte & 0xf) as usize) } as i32;
        // SAFETY: as above.
        acc += unsafe { *tables.get_unchecked((gg + 1) * LUT_W + (byte >> 4) as usize) } as i32;
        gg += 2;
    }
    acc
}

/// Fast-path accumulation with int8 tables and per-block scales. Group
/// indexing is uniform across the g=3 region and the TL1 tail (16 entries
/// per group), so blocks of `block_groups` groups stride both regions.
#[inline]
pub fn gemv_row_tl2_i8(
    row: &[u8],
    layout: &Tl2Layout,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
) -> f32 {
    let (idx_plane, rest) = row.split_at(layout.idx_bytes);
    let (sign_plane, tl1_tail) = rest.split_at(layout.sign_bytes);
    let n3 = layout.n3();
    debug_assert_eq!(n3 % 8, 0, "ThreeK multiple of 24 → n3 multiple of 8");
    debug_assert_eq!(block_groups % 8, 0, "scale blocks align to sign bytes");
    let mut facc = 0f32;
    let mut accs = [0i32; 2];
    let mut blk = 0usize;
    let mut in_blk = 0usize;
    // §Perf: 8 groups per iteration (one sign byte, four index bytes),
    // dual accumulators instead of per-element sign_apply, block flush
    // only at sign-byte boundaries (LUT_BLOCK_GROUPS is a multiple of 8).
    let mut g = 0usize;
    for &sbyte in sign_plane {
        let ib = g / 2;
        let tb = g * LUT_W;
        for j in 0..4 {
            // SAFETY: each sign byte covers 4 index bytes and 8 tables;
            // the layout sizes both planes and nibble codes are < LUT_W.
            let byte = unsafe { *idx_plane.get_unchecked(ib + j) };
            let t0 = tb + 2 * j * LUT_W;
            // SAFETY: as above.
            let v0 = unsafe { *tables.get_unchecked(t0 + (byte & 0xf) as usize) } as i32;
            // SAFETY: as above.
            let v1 = unsafe { *tables.get_unchecked(t0 + LUT_W + (byte >> 4) as usize) } as i32;
            accs[((sbyte >> (2 * j)) & 1) as usize] += v0;
            accs[((sbyte >> (2 * j + 1)) & 1) as usize] += v1;
        }
        g += 8;
        in_blk += 8;
        if in_blk == block_groups {
            facc += (accs[0] - accs[1]) as f32 * block_scales[blk];
            accs = [0; 2];
            blk += 1;
            in_blk = 0;
        }
    }
    // TL1 tail (no sign plane): continue filling the current block.
    let mut acc = accs[0] - accs[1];
    let mut gg = n3;
    for &byte in tl1_tail {
        // SAFETY: the tail holds n2 groups of LUT_W entries after the n3
        // g=3 tables; nibble codes are < LUT_W.
        acc += unsafe { *tables.get_unchecked(gg * LUT_W + (byte & 0xf) as usize) } as i32;
        // SAFETY: as above.
        acc += unsafe { *tables.get_unchecked((gg + 1) * LUT_W + (byte >> 4) as usize) } as i32;
        gg += 2;
        in_blk += 2;
        if in_blk == block_groups {
            facc += acc as f32 * block_scales[blk];
            acc = 0;
            blk += 1;
            in_blk = 0;
        }
    }
    if in_blk > 0 {
        facc += acc as f32 * block_scales[blk];
    }
    facc
}

/// Accumulate one unified group (g=3 region or TL1 tail) of a TL2 row
/// into `acc` — the group-addressed body shared by the sparse walkers.
/// Generic over the table element so the i16 and i8 variants share it.
#[inline(always)]
fn tl2_group_acc<T: Copy + Into<i32>>(
    g: usize,
    n3: usize,
    idx_plane: &[u8],
    sign_plane: &[u8],
    tl1_tail: &[u8],
    tables: &[T],
    acc: &mut i32,
) {
    if g < n3 {
        // SAFETY: the layout sizes the planes for n3 groups (2 per index
        // byte, 8 per sign byte), tables holds one LUT_W-entry table per
        // group, and nibble codes are < LUT_W.
        let byte = unsafe { *idx_plane.get_unchecked(g / 2) };
        let nib = if g % 2 == 0 { byte & 0xf } else { byte >> 4 };
        // SAFETY: as above.
        let sign = (unsafe { *sign_plane.get_unchecked(g / 8) } >> (g % 8)) & 1;
        // SAFETY: as above.
        let v: i32 = unsafe { *tables.get_unchecked(g * LUT_W + nib as usize) }.into();
        *acc += sign_apply_i32(v, sign);
    } else {
        let tg = g - n3;
        // SAFETY: the tail holds n2 groups (2 per byte) with one
        // LUT_W-entry table per group after the n3 g=3 tables.
        let byte = unsafe { *tl1_tail.get_unchecked(tg / 2) };
        let nib = if tg % 2 == 0 { byte & 0xf } else { byte >> 4 };
        // SAFETY: as above.
        *acc += unsafe { *tables.get_unchecked(g * LUT_W + nib as usize) }.into();
    }
}

/// Sparse [`gemv_row_tl2_i16`]: blocks stride the unified group sequence
/// (see [`Tl2Layout::sparse_bounds`]); a skipped block's groups all hold
/// the zero code, whose table entry is exactly 0 under either sign, so
/// the i32 accumulator stays bit-identical to the dense dual-accumulator
/// schedule (integer addition is order-free).
#[inline]
pub fn gemv_row_tl2_i16_sparse(
    row: &[u8],
    layout: &Tl2Layout,
    tables: &[i16],
    sidx: &sparse::SparseIndex,
    wr: usize,
    elided: &mut u64,
) -> i32 {
    let (idx_plane, rest) = row.split_at(layout.idx_bytes);
    let (sign_plane, tl1_tail) = rest.split_at(layout.sign_bytes);
    let n3 = layout.n3();
    let groups = n3 + layout.n2();
    let mut acc = 0i32;
    for blk in 0..sidx.blocks_per_row() {
        if !sidx.is_nonzero(wr, blk) {
            *elided += 1;
            continue;
        }
        let g0 = blk * LUT_BLOCK_GROUPS;
        let g1 = (g0 + LUT_BLOCK_GROUPS).min(groups);
        for g in g0..g1 {
            tl2_group_acc(g, n3, idx_plane, sign_plane, tl1_tail, tables, &mut acc);
        }
    }
    acc
}

/// Sparse [`gemv_row_tl2_i8`]: the elision block *is* the requantization
/// scale block, so a skipped block also skips its `0 · block_scale`
/// fold (`+0.0`, bit-safe — block scales are non-negative and the f32
/// accumulator is never `-0.0`).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemv_row_tl2_i8_sparse(
    row: &[u8],
    layout: &Tl2Layout,
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    sidx: &sparse::SparseIndex,
    wr: usize,
    elided: &mut u64,
) -> f32 {
    let (idx_plane, rest) = row.split_at(layout.idx_bytes);
    let (sign_plane, tl1_tail) = rest.split_at(layout.sign_bytes);
    let n3 = layout.n3();
    let groups = n3 + layout.n2();
    let mut facc = 0f32;
    for blk in 0..sidx.blocks_per_row() {
        if !sidx.is_nonzero(wr, blk) {
            *elided += 1;
            continue;
        }
        let g0 = blk * block_groups;
        let g1 = (g0 + block_groups).min(groups);
        let mut acc = 0i32;
        for g in g0..g1 {
            tl2_group_acc(g, n3, idx_plane, sign_plane, tl1_tail, tables, &mut acc);
        }
        facc += acc as f32 * block_scales[blk];
    }
    facc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::quant::{quantize_act_int8, training_scheme_ref_row};
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.042)
    }

    #[test]
    fn layout_block_fitting() {
        // K=4096: ThreeK=4080, TwoK=16 (paper Fig. 6: no padding needed).
        let l = Tl2Layout::new(4096);
        assert_eq!(l.three_k, 4080);
        assert_eq!(l.two_k, 16);
        assert_eq!(l.row_bytes(), 4080 / 6 + 4080 / 24 + 4);
        // bpw ≈ 1.668
        let bpw = l.row_bytes() as f64 * 8.0 / 4096.0;
        assert!((bpw - 5.0 / 3.0).abs() < 0.01, "bpw {bpw}");
        // K divisible by 24: no tail at all.
        let l2 = Tl2Layout::new(3072);
        assert_eq!(l2.two_k, 0);
        assert_eq!(l2.tl1_bytes, 0);
    }

    /// Paper Table 6 spot checks: sign/index assignments.
    #[test]
    fn table6_sign_index() {
        let case = |w: [i8; 3]| {
            let code =
                ((w[0] + 1) as usize) * 9 + ((w[1] + 1) as usize) * 3 + (w[2] + 1) as usize;
            mirror_split(code, 3, 3)
        };
        assert_eq!(case([-1, -1, -1]), (1, 13));
        assert_eq!(case([-1, -1, 0]), (1, 12));
        assert_eq!(case([-1, -1, 1]), (1, 11));
        assert_eq!(case([-1, 0, -1]), (1, 10));
        assert_eq!(case([0, 0, 0]), (0, 0));
        assert_eq!(case([1, 0, 1]), (0, 10));
        assert_eq!(case([1, 1, -1]), (0, 11));
        assert_eq!(case([1, 1, 0]), (0, 12));
        assert_eq!(case([1, 1, 1]), (0, 13));
    }

    #[test]
    fn pack_dequantize_round_trip() {
        for k in [24, 48, 96, 100, 1024, 4096] {
            let t = random_ternary(3, k, k as u64);
            let packed = TL2_0.quantize(&t);
            assert_eq!(TL2_0.dequantize(&packed), t.dequantize(), "k={k}");
        }
    }

    #[test]
    fn bpw_is_sub_2() {
        let t = random_ternary(8, 4096, 7);
        let packed = TL2_0.quantize(&t);
        let bpw = packed.bits_per_weight();
        assert!(bpw < 1.7, "TL2 bpw {bpw} must beat the 2-bit floor");
    }

    #[test]
    fn tl2_1_is_bit_identical_to_training_scheme() {
        for k in [96, 768, 1000] {
            let m = 16;
            let t = random_ternary(m, k, 100 + k as u64);
            let mut rng = Rng::new(200 + k as u64);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let act = quantize_act_int8(&x);
            let packed = TL2_1.quantize(&t);
            let p = TL2_1.prepare(&x, k);
            let mut out = vec![0f32; m];
            TL2_1.gemv(&packed, &p, &mut out);
            for r in 0..m {
                assert_eq!(
                    out[r],
                    training_scheme_ref_row(t.row(r), t.scale, &act),
                    "k={k} row {r}"
                );
            }
        }
    }

    #[test]
    fn tl2_0_close_but_not_exact() {
        let (m, k) = (32, 2048);
        let t = random_ternary(m, k, 301);
        let mut rng = Rng::new(302);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let act = quantize_act_int8(&x);
        let packed = TL2_0.quantize(&t);
        let p = TL2_0.prepare(&x, k);
        let mut out = vec![0f32; m];
        TL2_0.gemv(&packed, &p, &mut out);
        let mut err2 = 0f64;
        let mut ref2 = 0f64;
        let mut any_diff = false;
        for r in 0..m {
            let want = training_scheme_ref_row(t.row(r), t.scale, &act) as f64;
            err2 += ((out[r] as f64) - want).powi(2);
            ref2 += want * want;
            any_diff |= out[r] as f64 != want;
        }
        let rel = (err2 / ref2.max(1e-12)).sqrt();
        assert!(rel < 0.05, "{rel}");
        assert!(any_diff, "TL2_0 should NOT be bit-exact (it requantizes the LUT)");
    }

    #[test]
    fn tl2_variants_agree_closely() {
        let (m, k) = (16, 960);
        let t = random_ternary(m, k, 401);
        let mut rng = Rng::new(402);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let p0 = TL2_0.prepare(&x, k);
        let p1 = TL2_1.prepare(&x, k);
        let q0 = TL2_0.quantize(&t);
        let q1 = TL2_1.quantize(&t);
        let (mut o0, mut o1) = (vec![0f32; m], vec![0f32; m]);
        TL2_0.gemv(&q0, &p0, &mut o0);
        TL2_1.gemv(&q1, &p1, &mut o1);
        for r in 0..m {
            assert!((o0[r] - o1[r]).abs() < 0.03 * o1[r].abs().max(1.0), "row {r}");
        }
    }
}
