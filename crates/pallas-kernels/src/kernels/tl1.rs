//! **TL1** — element-wise LUT-based ternary kernel with group size g=2
//! (paper §3.1.1, Algorithm 3, Table 5).
//!
//! Every pair of ternary weights is packed into a 4-bit code
//! `c = 3·(w0+1) + (w1+1) ∈ 0..9` (bpw = 2). The activation-side
//! preprocessing enumerates all 9 pair sums `a0·w0 + a1·w1` into a
//! 16-entry table per weight pair position; accumulation is one table
//! lookup per 2 weights instead of 2 multiply-adds.
//!
//! Two variants (paper §3.2.1):
//! * **TL1_0** — tables requantized to int8 with one scale per block of
//!   [`LUT_BLOCK_GROUPS`] groups (T-MAC-style). Fast, *near*-lossless.
//! * **TL1_1** — tables kept in int16 via the pack-and-unpack technique
//!   (two byte-table lookups reconstruct the 16-bit entry). Lossless:
//!   bit-identical to the BitNet b1.58 training computation.

use super::lut::{decode_code, requantize_lut_block};
use super::quant::{quantize_act_int8_into, TernaryWeights};
use super::simd::{self, SimdLevel};
use super::sparse;
use super::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};

/// Table entries per group (9 used, padded to 16 = one 128-bit SIMD
/// register of int8, the `vpshufb`/`vqtbl1q_u8` width).
pub const LUT_W: usize = 16;
/// Number of weight pairs (groups) sharing one int8 requantization scale
/// in the `_0` fast path.
pub const LUT_BLOCK_GROUPS: usize = 32;

/// Weights per sparse-elision block: one `_0` scale block (32 groups ×
/// g=2), so a skipped block skips its whole scale fold too. Shared by
/// TL1 and the ELUT kernels that reuse the TL1 accumulation paths.
pub const SPARSE_BLOCK_WEIGHTS: usize = 2 * LUT_BLOCK_GROUPS;

const TERNARY: [i8; 3] = [-1, 0, 1];

/// Per-slot weight patterns of the Table-5 pair enumeration: slot `c`
/// holds `(w0, w1)` of code `c = 3·(w0+1) + (w1+1)` for `c < 9`; the
/// padding slots stay zero so the vector table builders reproduce the
/// scalar fill-then-write layout exactly.
const PAIR_W0: [i16; LUT_W] = [-1, -1, -1, 0, 0, 0, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0];
/// See [`PAIR_W0`].
const PAIR_W1: [i16; LUT_W] = [-1, 0, 1, -1, 0, 1, -1, 0, 1, 0, 0, 0, 0, 0, 0, 0];

/// TL1 kernel; `LOSSLESS = false` → TL1_0, `true` → TL1_1.
pub struct Tl1Kernel<const LOSSLESS: bool>;

/// TL1_0: int8-requantized LUT (fast path).
pub static TL1_0: Tl1Kernel<false> = Tl1Kernel::<false>;
/// TL1_1: int16 LUT via pack-and-unpack (lossless path).
pub static TL1_1: Tl1Kernel<true> = Tl1Kernel::<true>;

/// Pack one row of ternary weights into 4-bit TL1 codes (2 per byte).
pub fn pack_row_tl1(row: &[i8], out: &mut [u8]) {
    debug_assert_eq!(row.len() % 4, 0);
    debug_assert_eq!(out.len(), row.len() / 4);
    for (b, quad) in row.chunks_exact(4).enumerate() {
        let c0 = (3 * (quad[0] + 1) + (quad[1] + 1)) as u8;
        let c1 = (3 * (quad[2] + 1) + (quad[3] + 1)) as u8;
        out[b] = c0 | (c1 << 4);
    }
}

/// Build the int16 pair-sum tables for a quantized activation vector:
/// `tables[g*16 + c] = aq[2g]·w0(c) + aq[2g+1]·w1(c)`.
pub fn build_tables_tl1(aq: &[i8]) -> Vec<i16> {
    let mut tables = vec![0i16; (aq.len() / 2) * LUT_W];
    build_tables_tl1_into(aq, &mut tables);
    tables
}

/// Allocation-free [`build_tables_tl1`]: fills the caller-owned table
/// buffer (`(aq.len()/2) * LUT_W` entries), zeroing the padding slots so
/// requantization over reused buffers stays deterministic.
pub fn build_tables_tl1_into(aq: &[i8], tables: &mut [i16]) {
    debug_assert_eq!(aq.len() % 2, 0);
    let groups = aq.len() / 2;
    debug_assert_eq!(tables.len(), groups * LUT_W);
    #[cfg(target_arch = "x86_64")]
    if simd::active_level() == SimdLevel::Avx2 {
        // SAFETY: AVX2 verified by the active dispatch level; `aq` holds
        // 2 quants per group and `tables` one LUT_W-entry table per group.
        unsafe { simd::avx2::build_lut16_pair_tables(aq, &PAIR_W0, &PAIR_W1, tables) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_level() == SimdLevel::Neon {
        // SAFETY: NEON verified by the active dispatch level; `aq` holds
        // 2 quants per group and `tables` one LUT_W-entry table per group.
        unsafe { simd::neon::build_lut16_pair_tables(aq, &PAIR_W0, &PAIR_W1, tables) };
        return;
    }
    tables.fill(0);
    for g in 0..groups {
        let a0 = aq[2 * g] as i16;
        let a1 = aq[2 * g + 1] as i16;
        let t = &mut tables[g * LUT_W..g * LUT_W + 9];
        // Enumerate codes in Table-5 order: c = 3*(w0+1) + (w1+1).
        let mut c = 0;
        for w0 in TERNARY {
            for w1 in TERNARY {
                t[c] = a0 * w0 as i16 + a1 * w1 as i16;
                c += 1;
            }
        }
    }
}

/// Requantize i16 tables to i8 per block of `block_groups` groups.
pub fn requantize_tables(
    tables: &[i16],
    block_groups: usize,
) -> (Vec<i8>, Vec<f32>) {
    let per_block = block_groups * LUT_W;
    let mut out = vec![0i8; tables.len()];
    let mut scales = vec![0f32; pallas_core::util::ceil_div(tables.len(), per_block)];
    requantize_tables_into(tables, block_groups, &mut out, &mut scales);
    (out, scales)
}

/// Allocation-free [`requantize_tables`]: `out` matches `tables`,
/// `scales` holds one entry per block of `block_groups` groups.
pub fn requantize_tables_into(
    tables: &[i16],
    block_groups: usize,
    out: &mut [i8],
    scales: &mut [f32],
) {
    let per_block = block_groups * LUT_W;
    debug_assert_eq!(out.len(), tables.len());
    debug_assert_eq!(scales.len(), pallas_core::util::ceil_div(tables.len(), per_block));
    for ((src, dst), s) in
        tables.chunks(per_block).zip(out.chunks_mut(per_block)).zip(scales.iter_mut())
    {
        *s = requantize_lut_block(src, dst);
    }
}

impl<const LOSSLESS: bool> Kernel for Tl1Kernel<LOSSLESS> {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: if LOSSLESS { QuantType::Tl11 } else { QuantType::Tl10 },
            name: if LOSSLESS { "TL1_1" } else { "TL1_0" },
            class: KernelClass::LutBased,
            element_wise: true,
            bpw: 2.0,
            lossless: LOSSLESS,
            k_multiple: 4,
            ternary_native: true,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let (m, k) = (w.m, w.k);
        assert_eq!(k % 4, 0, "TL1 requires K % 4 == 0");
        let row_bytes = k / 4;
        let mut data = vec![0u8; m * row_bytes];
        for r in 0..m {
            pack_row_tl1(w.row(r), &mut data[r * row_bytes..(r + 1) * row_bytes]);
        }
        let bounds = sparse::uniform_bounds(k, SPARSE_BLOCK_WEIGHTS);
        let sparse = sparse::maybe_index(&w.q, m, k, &bounds);
        QTensor {
            qtype: self.info().qtype,
            m,
            k,
            data,
            scale: w.scale,
            sparse,
        }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let row_bytes = t.k / 4;
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            for b in 0..row_bytes {
                let byte = t.data[r * row_bytes + b];
                for code in [byte & 0xf, byte >> 4] {
                    for w in decode_code(code as usize, 3, 2, &TERNARY) {
                        out.push(w as f32 * t.scale);
                    }
                }
            }
        }
        out
    }

    fn prepare_kind(&self, k: usize) -> PrepareKind {
        let groups = k / 2;
        if LOSSLESS {
            PrepareKind::LutI16 { groups }
        } else {
            PrepareKind::LutI8 { groups, block_groups: LUT_BLOCK_GROUPS }
        }
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        match dst {
            PreparedRowMut::LutI16 { aq, tables, scale } => {
                let (s, _) = quantize_act_int8_into(x, aq);
                build_tables_tl1_into(aq, tables);
                *scale = s;
            }
            PreparedRowMut::LutI8 { aq, tmp16, tables, block_scales, scale } => {
                let (s, _) = quantize_act_int8_into(x, aq);
                build_tables_tl1_into(aq, tmp16);
                requantize_tables_into(tmp16, LUT_BLOCK_GROUPS, tables, block_scales);
                *scale = s;
            }
            _ => panic!("TL1 expects a LUT destination"),
        }
    }

    fn simd_levels(&self) -> &'static [SimdLevel] {
        simd::KERNEL_LEVELS
    }

    fn sparse_capable(&self) -> bool {
        true
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let row_bytes = t.k / 4;
        let level = simd::active_level();
        simd::note_call(level);
        match p {
            PreparedRow::LutI16 { tables, scale } => {
                let combined = t.scale / scale;
                if let Some(idx) = &t.sparse {
                    #[cfg(target_arch = "x86_64")]
                    if level == SimdLevel::Avx2 {
                        // SAFETY: AVX2 verified by the active dispatch level;
                        // buffer shapes are guaranteed by quantize/prepare.
                        unsafe {
                            simd::avx2::gemv_rows_lut16_sparse(
                                &t.data, row_bytes, tables, combined, out, rows, idx,
                            );
                        }
                        return;
                    }
                    #[cfg(target_arch = "aarch64")]
                    if level == SimdLevel::Neon {
                        // SAFETY: NEON verified by the active dispatch level;
                        // buffer shapes are guaranteed by quantize/prepare.
                        unsafe {
                            simd::neon::gemv_rows_lut16_sparse(
                                &t.data, row_bytes, tables, combined, out, rows, idx,
                            );
                        }
                        return;
                    }
                    let mut elided = 0u64;
                    for (o, r) in out.iter_mut().zip(rows) {
                        let wrow = &t.data[r * row_bytes..(r + 1) * row_bytes];
                        *o = gemv_row_lut16_sparse(wrow, tables, idx, r, &mut elided) as f32
                            * combined;
                    }
                    sparse::note_elided(level, elided);
                    return;
                }
                #[cfg(target_arch = "x86_64")]
                if level == SimdLevel::Avx2 {
                    // SAFETY: AVX2 verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::avx2::gemv_rows_lut16(&t.data, row_bytes, tables, combined, out, rows);
                    }
                    return;
                }
                #[cfg(target_arch = "aarch64")]
                if level == SimdLevel::Neon {
                    // SAFETY: NEON verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::neon::gemv_rows_lut16(&t.data, row_bytes, tables, combined, out, rows);
                    }
                    return;
                }
                for (o, r) in out.iter_mut().zip(rows) {
                    let wrow = &t.data[r * row_bytes..(r + 1) * row_bytes];
                    *o = gemv_row_lut16(wrow, tables) as f32 * combined;
                }
            }
            PreparedRow::LutI8 { tables, block_scales, block_groups, scale } => {
                let combined = t.scale / scale;
                if let Some(idx) = &t.sparse {
                    #[cfg(target_arch = "x86_64")]
                    if level == SimdLevel::Avx2 {
                        // SAFETY: AVX2 verified by the active dispatch level;
                        // buffer shapes are guaranteed by quantize/prepare.
                        unsafe {
                            simd::avx2::gemv_rows_lut8_sparse(
                                &t.data,
                                row_bytes,
                                tables,
                                block_scales,
                                block_groups,
                                combined,
                                out,
                                rows,
                                idx,
                            );
                        }
                        return;
                    }
                    #[cfg(target_arch = "aarch64")]
                    if level == SimdLevel::Neon {
                        // SAFETY: NEON verified by the active dispatch level;
                        // buffer shapes are guaranteed by quantize/prepare.
                        unsafe {
                            simd::neon::gemv_rows_lut8_sparse(
                                &t.data,
                                row_bytes,
                                tables,
                                block_scales,
                                block_groups,
                                combined,
                                out,
                                rows,
                                idx,
                            );
                        }
                        return;
                    }
                    let mut elided = 0u64;
                    for (o, r) in out.iter_mut().zip(rows) {
                        let wrow = &t.data[r * row_bytes..(r + 1) * row_bytes];
                        *o = gemv_row_lut8_sparse(
                            wrow,
                            tables,
                            block_scales,
                            block_groups,
                            idx,
                            r,
                            &mut elided,
                        ) * combined;
                    }
                    sparse::note_elided(level, elided);
                    return;
                }
                #[cfg(target_arch = "x86_64")]
                if level == SimdLevel::Avx2 {
                    // SAFETY: AVX2 verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::avx2::gemv_rows_lut8(
                            &t.data,
                            row_bytes,
                            tables,
                            block_scales,
                            block_groups,
                            combined,
                            out,
                            rows,
                        );
                    }
                    return;
                }
                #[cfg(target_arch = "aarch64")]
                if level == SimdLevel::Neon {
                    // SAFETY: NEON verified by the active dispatch level;
                    // buffer shapes are guaranteed by quantize/prepare.
                    unsafe {
                        simd::neon::gemv_rows_lut8(
                            &t.data,
                            row_bytes,
                            tables,
                            block_scales,
                            block_groups,
                            combined,
                            out,
                            rows,
                        );
                    }
                    return;
                }
                for (o, r) in out.iter_mut().zip(rows) {
                    let wrow = &t.data[r * row_bytes..(r + 1) * row_bytes];
                    *o = gemv_row_lut8(wrow, tables, block_scales, block_groups) * combined;
                }
            }
            _ => panic!("TL1 expects a LUT-prepared activation"),
        }
    }
}

/// Lossless accumulation: i32 sum of i16 table entries, one lookup per
/// packed nibble. Codes stream linearly; the table for group g sits at
/// `tables[g*16..]`, i.e. the LUT-centric layout of §3.1.2.
#[inline]
pub fn gemv_row_lut16(wrow: &[u8], tables: &[i16]) -> i32 {
    let mut acc = 0i32;
    let mut g = 0usize;
    for &byte in wrow {
        let c0 = (byte & 0xf) as usize;
        let c1 = (byte >> 4) as usize;
        // SAFETY: tables holds 2 groups of LUT_W entries per packed byte
        // and nibble codes are < LUT_W, so both indices are in bounds.
        acc += unsafe { *tables.get_unchecked(g * LUT_W + c0) } as i32;
        // SAFETY: as above.
        acc += unsafe { *tables.get_unchecked((g + 1) * LUT_W + c1) } as i32;
        g += 2;
    }
    acc
}

/// Fast-path accumulation: int8 table entries summed per scale-block in
/// i32, then folded into f32 with the block scale.
#[inline]
pub fn gemv_row_lut8(
    wrow: &[u8],
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
) -> f32 {
    let mut facc = 0f32;
    let bytes_per_block = block_groups / 2; // 2 groups per byte
    for (blk, bytes) in wrow.chunks(bytes_per_block).enumerate() {
        let mut acc = 0i32;
        let base = blk * block_groups * LUT_W;
        let mut g = 0usize;
        for &byte in bytes {
            let c0 = (byte & 0xf) as usize;
            let c1 = (byte >> 4) as usize;
            // SAFETY: tables holds 2 groups of LUT_W entries per packed
            // byte and nibble codes are < LUT_W; `base` advances by one
            // whole block per chunk, so both indices are in bounds.
            acc += unsafe { *tables.get_unchecked(base + g * LUT_W + c0) } as i32;
            // SAFETY: as above.
            acc += unsafe { *tables.get_unchecked(base + (g + 1) * LUT_W + c1) } as i32;
            g += 2;
        }
        facc += acc as f32 * block_scales[blk];
    }
    facc
}

/// Sparse [`gemv_row_lut16`]: iterate [`SPARSE_BLOCK_WEIGHTS`]-sized
/// blocks and skip those the index marks all-zero (their table entries
/// would all be the zero-pair code, entry exactly 0, so skipping them
/// leaves the i32 accumulator bit-identical). `elided` counts skipped
/// blocks.
#[inline]
pub fn gemv_row_lut16_sparse(
    wrow: &[u8],
    tables: &[i16],
    idx: &sparse::SparseIndex,
    row: usize,
    elided: &mut u64,
) -> i32 {
    const BLOCK_BYTES: usize = SPARSE_BLOCK_WEIGHTS / 4;
    let mut acc = 0i32;
    for blk in 0..idx.blocks_per_row() {
        if !idx.is_nonzero(row, blk) {
            *elided += 1;
            continue;
        }
        let b0 = blk * BLOCK_BYTES;
        let b1 = (b0 + BLOCK_BYTES).min(wrow.len());
        let mut g = b0 * 2;
        for &byte in &wrow[b0..b1] {
            let c0 = (byte & 0xf) as usize;
            let c1 = (byte >> 4) as usize;
            // SAFETY: tables holds 2 groups of LUT_W entries per packed
            // byte and nibble codes are < LUT_W, so both indices are in
            // bounds.
            acc += unsafe { *tables.get_unchecked(g * LUT_W + c0) } as i32;
            // SAFETY: as above.
            acc += unsafe { *tables.get_unchecked((g + 1) * LUT_W + c1) } as i32;
            g += 2;
        }
    }
    acc
}

/// Sparse [`gemv_row_lut8`]: the elision block *is* the requantization
/// scale block, so a skipped block also skips its `0 · block_scale`
/// fold — which is `+0.0` (block scales are non-negative), so the f32
/// accumulator stays bit-identical to the dense path.
#[inline]
pub fn gemv_row_lut8_sparse(
    wrow: &[u8],
    tables: &[i8],
    block_scales: &[f32],
    block_groups: usize,
    idx: &sparse::SparseIndex,
    row: usize,
    elided: &mut u64,
) -> f32 {
    let mut facc = 0f32;
    let bytes_per_block = block_groups / 2; // 2 groups per byte
    for (blk, bytes) in wrow.chunks(bytes_per_block).enumerate() {
        if !idx.is_nonzero(row, blk) {
            *elided += 1;
            continue;
        }
        let mut acc = 0i32;
        let base = blk * block_groups * LUT_W;
        let mut g = 0usize;
        for &byte in bytes {
            let c0 = (byte & 0xf) as usize;
            let c1 = (byte >> 4) as usize;
            // SAFETY: tables holds 2 groups of LUT_W entries per packed
            // byte and nibble codes are < LUT_W; `base` advances by one
            // whole block per chunk, so both indices are in bounds.
            acc += unsafe { *tables.get_unchecked(base + g * LUT_W + c0) } as i32;
            // SAFETY: as above.
            acc += unsafe { *tables.get_unchecked(base + (g + 1) * LUT_W + c1) } as i32;
            g += 2;
        }
        facc += acc as f32 * block_scales[blk];
    }
    facc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::quant::{quantize_act_int8, training_scheme_ref_row};
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.05)
    }

    /// Paper Table 5: the pack/unpack enumeration for every pair.
    #[test]
    fn table5_pack_unpack() {
        let expected: [( [i8; 2], u8); 9] = [
            ([-1, -1], 0b0000),
            ([-1, 0], 0b0001),
            ([-1, 1], 0b0010),
            ([0, -1], 0b0011),
            ([0, 0], 0b0100),
            ([0, 1], 0b0101),
            ([1, -1], 0b0110),
            ([1, 0], 0b0111),
            ([1, 1], 0b1000),
        ];
        for (pair, code) in expected {
            let mut row = [pair[0], pair[1], 0, 0];
            let mut out = [0u8; 1];
            pack_row_tl1(&row, &mut out);
            assert_eq!(out[0] & 0xf, code, "pack {pair:?}");
            // And the decode direction:
            let d = decode_code(code as usize, 3, 2, &TERNARY);
            assert_eq!(&d[..], &pair[..], "unpack {code:#06b}");
            row = [0, 0, pair[0], pair[1]];
            pack_row_tl1(&row, &mut out);
            assert_eq!(out[0] >> 4, code, "pack high nibble {pair:?}");
        }
    }

    /// The vector builders' pattern constants must enumerate exactly the
    /// Table-5 code order the scalar loop produces, with zeroed padding.
    #[test]
    fn pair_patterns_match_code_enumeration() {
        let mut c = 0usize;
        for w0 in TERNARY {
            for w1 in TERNARY {
                assert_eq!(PAIR_W0[c], w0 as i16, "slot {c}");
                assert_eq!(PAIR_W1[c], w1 as i16, "slot {c}");
                c += 1;
            }
        }
        for slot in c..LUT_W {
            assert_eq!((PAIR_W0[slot], PAIR_W1[slot]), (0, 0), "padding slot {slot}");
        }
    }

    #[test]
    fn tables_enumerate_pair_sums() {
        let aq = [3i8, -5, 100, 2];
        let t = build_tables_tl1(&aq);
        // group 0, code for (1, -1) = 3*2+0 = 6 → 3*1 + (-5)*(-1) = 8
        assert_eq!(t[6], 8);
        // group 1, code for (-1, 1) = 0*3+2 = 2 → -100 + 2 = -98
        assert_eq!(t[LUT_W + 2], -98);
        // all-zero code (0,0) = 4 → 0
        assert_eq!(t[4], 0);
    }

    #[test]
    fn tl1_1_is_bit_identical_to_training_scheme() {
        let (m, k) = (24, 768);
        let t = random_ternary(m, k, 21);
        let mut rng = Rng::new(22);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let packed = TL1_1.quantize(&t);
        let p = TL1_1.prepare(&x, k);
        let act = quantize_act_int8(&x);
        let mut out = vec![0f32; m];
        TL1_1.gemv(&packed, &p, &mut out);
        for r in 0..m {
            assert_eq!(out[r], training_scheme_ref_row(t.row(r), t.scale, &act), "row {r}");
        }
    }

    #[test]
    fn tl1_0_close_but_not_exact() {
        let (m, k) = (32, 1024);
        let t = random_ternary(m, k, 31);
        let mut rng = Rng::new(32);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let act = quantize_act_int8(&x);
        let packed = TL1_0.quantize(&t);
        let p = TL1_0.prepare(&x, k);
        let mut out = vec![0f32; m];
        TL1_0.gemv(&packed, &p, &mut out);
        // L2-relative error across the row vector: per-row relative error
        // is meaningless on near-zero dot products.
        let mut err2 = 0f64;
        let mut ref2 = 0f64;
        let mut any_diff = false;
        for r in 0..m {
            let want = training_scheme_ref_row(t.row(r), t.scale, &act) as f64;
            err2 += ((out[r] as f64) - want).powi(2);
            ref2 += want * want;
            any_diff |= out[r] as f64 != want;
        }
        let rel = (err2 / ref2.max(1e-12)).sqrt();
        assert!(rel < 0.05, "requantized LUT should be close: {rel}");
        assert!(any_diff, "TL1_0 should NOT be bit-exact (it requantizes the LUT)");
    }

    #[test]
    fn dequantize_round_trip() {
        let t = random_ternary(4, 64, 41);
        let packed = TL1_0.quantize(&t);
        assert_eq!(packed.bits_per_weight(), 2.0);
        assert_eq!(TL1_0.dequantize(&packed), t.dequantize());
    }

    #[test]
    fn k_not_multiple_of_block_still_works() {
        // K/2 groups not a multiple of LUT_BLOCK_GROUPS exercises the
        // trailing partial block in the `_0` path.
        let k = 4 * 9; // 18 groups < 32
        let t = random_ternary(8, k, 51);
        let mut rng = Rng::new(52);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let packed = TL1_0.quantize(&t);
        let p = TL1_0.prepare(&x, k);
        let mut out = vec![0f32; 8];
        TL1_0.gemv(&packed, &p, &mut out);
        let wd = t.dequantize();
        for r in 0..8 {
            let want: f32 = wd[r * k..(r + 1) * k].iter().zip(&x).map(|(w, a)| w * a).sum();
            assert!((out[r] - want).abs() < 0.05 * want.abs().max(1.0), "row {r}");
        }
    }
}
