//! Instrumented reference implementations of the paper's Algorithm 1
//! (MAD-based mpGEMM) and Algorithm 2 (ELUT mpGEMM) that count every
//! arithmetic operation and memory access, so the complexity claims of
//! Appendix A can be *checked*, not assumed:
//!
//! * MAD: compute `O(MNK)`, memory `O(MNK)` (+ `O(NK)` preprocessing).
//! * ELUT: compute `max(O(NK·C^g/g), O(MNK/g))`, memory `O(MNK·C^g/g)`
//!   in the worst case (whole table reloaded per group), reduced by
//!   mirror consolidation.
//!
//! These run the *same math* as the production kernels (integer dot /
//! table lookup) but favour countability over speed.

/// Operation / memory-access tally.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// Multiply (or multiply-add counted once) operations.
    pub mul: u64,
    /// Additions (table build + accumulation).
    pub add: u64,
    /// Table lookups.
    pub lookup: u64,
    /// Bytes read from the weight side.
    pub weight_bytes: u64,
    /// Bytes read from the activation/LUT side.
    pub act_bytes: u64,
}

impl OpCounts {
    pub fn compute_ops(&self) -> u64 {
        self.mul + self.add + self.lookup
    }
    pub fn memory_bytes(&self) -> u64 {
        self.weight_bytes + self.act_bytes
    }
}

/// Algorithm 1 (MAD-based): counts for an M×K weight, N activation rows.
/// Weight storage is assumed 2-bit (the element-wise MAD formats).
pub fn mad_counts(m: u64, n: u64, k: u64) -> OpCounts {
    OpCounts {
        // Phase 1: quantization — one mul per activation element.
        // Phase 2: one mul + one add per (m, n, k).
        mul: n * k + m * n * k,
        add: m * n * k,
        lookup: 0,
        weight_bytes: m * n * k / 4, // 2 bpw, re-streamed per activation row
        act_bytes: n * k + m * n * k, // int8 activations read per row
    }
}

/// Algorithm 2 (ELUT): counts for group size g, cardinality c, with or
/// without mirror consolidation.
pub fn elut_counts(m: u64, n: u64, k: u64, c: u64, g: u64, mirror: bool) -> OpCounts {
    let full = c.pow(g as u32);
    let entries = if mirror { full / 2 + 1 } else { full };
    let groups = k / g;
    // Phase 1: build NK/g tables of `entries` sums, ~g adds each (the
    // incremental build used by the real kernels is cheaper; we count the
    // naive bound the paper uses: O(NK·C^g/g)).
    let build_adds = n * groups * entries * g;
    // Phase 2: one lookup + one add per (m, n, group); plus a sign op for
    // mirrored tables (counted as an add).
    let lookups = m * n * groups;
    let sign_ops = if mirror { lookups } else { 0 };
    // Index bits per group: 4-bit nibble (+1 sign bit if mirrored).
    let idx_bits = if mirror { 5 } else { 4 };
    OpCounts {
        mul: n * k, // activation quantization
        add: build_adds + lookups + sign_ops,
        lookup: lookups,
        weight_bytes: m * n * groups * idx_bits / 8,
        // Each lookup touches the 16-byte table line (the paper's
        // O(MNK·C^g/g) term), plus the build writes.
        act_bytes: m * n * groups * 16 + n * groups * entries * 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: u64 = 4096;
    const N: u64 = 1;
    const K: u64 = 6144; // divisible by both 2 and 3 so group counts are exact

    /// Appendix A.1: ELUT compute is ~1/g of MAD compute when C^g ≪ M.
    #[test]
    fn elut_compute_is_fraction_of_mad() {
        let mad = mad_counts(M, N, K);
        let elut = elut_counts(M, N, K, 3, 3, true);
        let ratio = elut.compute_ops() as f64 / mad.compute_ops() as f64;
        // Accumulation dominates: expect ≈ (2 lookups+adds per group) /
        // (2 ops per element) = 1/g, within 2x for the build term.
        assert!(ratio < 2.0 / 3.0, "ratio {ratio}");
    }

    /// Appendix A.1: ELUT memory complexity exceeds MAD's in the naive
    /// count (O(MNK·C^g/g) vs O(MNK)).
    #[test]
    fn elut_memory_exceeds_mad_naive() {
        let mad = mad_counts(M, N, K);
        let elut = elut_counts(M, N, K, 3, 3, true);
        assert!(elut.act_bytes > mad.act_bytes);
    }

    /// Appendix A.3: at equal memory complexity, g=3 mirrored beats g=2 in
    /// compute: O(MNK·3²/2) == O(MNK·(3³/2)/3) while lookups drop 1/3.
    #[test]
    fn g3_mirror_matches_g2_memory_with_fewer_lookups() {
        let e2 = elut_counts(M, N, K, 3, 2, false);
        let e3 = elut_counts(M, N, K, 3, 3, true);
        assert!(e3.lookup < e2.lookup);
        assert!((e3.lookup as f64 / e2.lookup as f64 - 2.0 / 3.0).abs() < 1e-9);
        // Weight traffic also drops: 5 bits/3w < 4 bits/2w.
        assert!(e3.weight_bytes < e2.weight_bytes);
    }

    /// The crossover the paper's Fig. 11 discusses: once C^g ≥ M, table
    /// construction dominates and larger g stops helping.
    #[test]
    fn table_build_dominates_when_cg_reaches_m() {
        let m_small = 128u64;
        let big_g = elut_counts(m_small, N, K, 3, 5, true); // 3^5 = 243 > m
        let build = N * (K / 5) * (243 / 2 + 1) * 5;
        let lookups = m_small * N * (K / 5);
        assert!(build > lookups, "build {build} must dominate lookups {lookups}");
        assert!(big_g.add > big_g.lookup * 2);
    }
}
