//! llama.cpp **Q4_0**: general-purpose 4-bit format. Blocks of 32 weights:
//! one f16 scale `d` + 16 bytes of nibbles, `w ≈ (q - 8) * d` → 18 bytes /
//! 32 weights = 4.5 bpw. Activations quantized per-32 block (`Q8_0`).
//!
//! The paper uses Q4_0 as the "general kernel" column of Table 7: it can
//! *store* a ternary model (wastefully) but is neither element-wise nor
//! lossless.

use crate::kernels::quant::{quantize_act_blocked_into, TernaryWeights};
use crate::kernels::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};
use pallas_core::util::{f16_to_f32, f32_to_f16};

pub struct Q40Kernel;

/// Block length.
pub const QK: usize = 32;
/// Bytes per packed block: f16 d + 16 nibble bytes.
pub const BLOCK_BYTES: usize = 2 + QK / 2;

impl Kernel for Q40Kernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: QuantType::Q40,
            name: "Q4_0",
            class: KernelClass::MadBased,
            element_wise: false,
            bpw: BLOCK_BYTES as f64 * 8.0 / QK as f64, // 4.5
            lossless: false,
            k_multiple: QK,
            ternary_native: false, // general format; ternary round-trips only approximately
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let (m, k) = (w.m, w.k);
        assert_eq!(k % QK, 0, "Q4_0 requires K % 32 == 0");
        let blocks_per_row = k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        let mut data = vec![0u8; m * row_bytes];
        let deq = w.dequantize();
        for r in 0..m {
            for b in 0..blocks_per_row {
                let xs = &deq[r * k + b * QK..r * k + (b + 1) * QK];
                let out = &mut data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                pack_block_q4_0(xs, out);
            }
        }
        QTensor { qtype: QuantType::Q40, m, k, data, scale: w.scale, sparse: None }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let blocks_per_row = t.k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            for b in 0..blocks_per_row {
                let blk = &t.data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                let d = f16_to_f32(u16::from_le_bytes([blk[0], blk[1]]));
                // llama.cpp layout: nibble i low = weight i, high = weight i+16
                for i in 0..QK / 2 {
                    out.push(((blk[2 + i] & 0xf) as i32 - 8) as f32 * d);
                }
                for i in 0..QK / 2 {
                    out.push(((blk[2 + i] >> 4) as i32 - 8) as f32 * d);
                }
            }
        }
        out
    }

    fn prepare_kind(&self, _k: usize) -> PrepareKind {
        PrepareKind::Blocked { block_len: QK }
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        match dst {
            PreparedRowMut::Blocked { q, d, bsums } => quantize_act_blocked_into(x, QK, q, d, bsums),
            _ => panic!("Q4_0 expects a blocked destination"),
        }
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let (actq, actd, bsums, block_len) = match p {
            PreparedRow::Blocked { q, d, bsums, block_len } => (q, d, bsums, block_len),
            _ => panic!("Q4_0 expects Q8_0 blocked activations"),
        };
        assert_eq!(block_len, QK);
        let blocks_per_row = t.k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        for (o, r) in out.iter_mut().zip(rows) {
            let mut sum = 0f32;
            for b in 0..blocks_per_row {
                let blk = &t.data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                let d = f16_to_f32(u16::from_le_bytes([blk[0], blk[1]]));
                let aq = &actq[b * QK..(b + 1) * QK];
                // Σ (q-8)·a = Σ q·a − 8·Σa, with Σa precomputed per block.
                let mut isum = 0i32;
                for i in 0..QK / 2 {
                    let byte = blk[2 + i];
                    isum += ((byte & 0xf) as i32) * aq[i] as i32;
                    isum += ((byte >> 4) as i32) * aq[i + QK / 2] as i32;
                }
                isum -= 8 * bsums[b];
                sum += isum as f32 * d * actd[b];
            }
            *o = sum;
        }
    }
}

/// Quantize one block of 32 f32 values to Q4_0 (llama.cpp reference
/// algorithm: d = max-by-|magnitude| / -8).
pub fn pack_block_q4_0(xs: &[f32], out: &mut [u8]) {
    debug_assert_eq!(xs.len(), QK);
    let mut amax = 0f32;
    let mut max = 0f32;
    for &v in xs {
        if v.abs() > amax {
            amax = v.abs();
            max = v;
        }
    }
    let d = max / -8.0;
    let dbits = f32_to_f16(d);
    out[0..2].copy_from_slice(&dbits.to_le_bytes());
    let df = f16_to_f32(dbits);
    let id = if df != 0.0 { 1.0 / df } else { 0.0 };
    for i in 0..QK / 2 {
        let q0 = ((xs[i] * id + 8.5) as i32).clamp(0, 15) as u8;
        let q1 = ((xs[i + QK / 2] * id + 8.5) as i32).clamp(0, 15) as u8;
        out[2 + i] = q0 | (q1 << 4);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    #[test]
    fn bpw_is_4_5() {
        let mut rng = Rng::new(1);
        let q: Vec<i8> = (0..4 * 128).map(|_| rng.next_ternary() as i8).collect();
        let t = TernaryWeights::from_ternary(q, 4, 128, 0.05);
        let packed = Q40Kernel.quantize(&t);
        assert_eq!(packed.bits_per_weight(), 4.5);
    }

    #[test]
    fn round_trip_error_small() {
        let mut rng = Rng::new(2);
        let xs: Vec<f32> = (0..QK).map(|_| rng.next_gaussian()).collect();
        let mut blk = [0u8; BLOCK_BYTES];
        pack_block_q4_0(&xs, &mut blk);
        let d = f16_to_f32(u16::from_le_bytes([blk[0], blk[1]]));
        let step = d.abs();
        for i in 0..QK / 2 {
            let lo = ((blk[2 + i] & 0xf) as i32 - 8) as f32 * d;
            let hi = ((blk[2 + i] >> 4) as i32 - 8) as f32 * d;
            assert!((lo - xs[i]).abs() <= step + 1e-4);
            assert!((hi - xs[i + QK / 2]).abs() <= step + 1e-4);
        }
    }

    #[test]
    fn gemv_close_to_dense() {
        let mut rng = Rng::new(3);
        let q: Vec<i8> = (0..16 * 256).map(|_| rng.next_ternary() as i8).collect();
        let t = TernaryWeights::from_ternary(q, 16, 256, 0.07);
        let x: Vec<f32> = (0..256).map(|_| rng.next_gaussian()).collect();
        let kern = Q40Kernel;
        let packed = kern.quantize(&t);
        let p = kern.prepare(&x, 256);
        let mut out = vec![0f32; 16];
        kern.gemv(&packed, &p, &mut out);
        let wd = t.dequantize();
        for r in 0..16 {
            let want: f32 = (0..256).map(|i| wd[r * 256 + i] * x[i]).sum();
            assert!((out[r] - want).abs() < 0.2 + 0.05 * want.abs(), "row {r}: {} vs {want}", out[r]);
        }
    }
}
