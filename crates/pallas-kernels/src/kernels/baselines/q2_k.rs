//! llama.cpp **Q2_K**: 2-bit K-quants. Super-blocks of 256 weights with 16
//! sub-blocks of 16; each sub-block has a 4-bit scale and a 4-bit min,
//! both further scaled by two f16 super-block factors `d` and `dmin`:
//!
//! `w ≈ d·sc·q − dmin·mn`
//!
//! Layout per super-block: 16 scale/min bytes + 64 quant bytes + 2×f16 =
//! 84 bytes → **2.625 bpw**.
//!
//! The paper (§2.3) cites Q2_K as the bit-wise MAD representative whose
//! *multi-step dequantization* (two scale levels + min offset) costs extra
//! latency on ternary models — visible in the kernel benches.

use crate::kernels::quant::{quantize_act_blocked_into, TernaryWeights};
use crate::kernels::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};
use pallas_core::util::{f16_to_f32, f32_to_f16};

pub struct Q2KKernel;

pub const QK: usize = 256;
pub const SUB: usize = 16; // sub-block length
/// 16 scale bytes + 64 quant bytes + d + dmin.
pub const BLOCK_BYTES: usize = 16 + QK / 4 + 4;

impl Kernel for Q2KKernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: QuantType::Q2K,
            name: "Q2_K",
            class: KernelClass::MadBased,
            element_wise: false,
            bpw: BLOCK_BYTES as f64 * 8.0 / QK as f64, // 2.625
            lossless: false,
            k_multiple: QK,
            ternary_native: false,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let (m, k) = (w.m, w.k);
        assert_eq!(k % QK, 0, "Q2_K requires K % 256 == 0");
        let blocks_per_row = k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        let mut data = vec![0u8; m * row_bytes];
        let deq = w.dequantize();
        for r in 0..m {
            for b in 0..blocks_per_row {
                let xs = &deq[r * k + b * QK..r * k + (b + 1) * QK];
                let blk = &mut data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                pack_block_q2_k(xs, blk);
            }
        }
        QTensor { qtype: QuantType::Q2K, m, k, data, scale: w.scale, sparse: None }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let blocks_per_row = t.k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            for b in 0..blocks_per_row {
                let blk = &t.data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                let d = f16_to_f32(u16::from_le_bytes([blk[80], blk[81]]));
                let dmin = f16_to_f32(u16::from_le_bytes([blk[82], blk[83]]));
                for s in 0..SUB {
                    let sc = (blk[s] & 0xf) as f32;
                    let mn = (blk[s] >> 4) as f32;
                    for j in 0..SUB {
                        let idx = s * SUB + j;
                        let q = (blk[16 + idx / 4] >> (2 * (idx % 4))) & 0x3;
                        out.push(d * sc * q as f32 - dmin * mn);
                    }
                }
            }
        }
        out
    }

    fn prepare_kind(&self, _k: usize) -> PrepareKind {
        PrepareKind::Blocked { block_len: QK }
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        match dst {
            PreparedRowMut::Blocked { q, d, bsums } => quantize_act_blocked_into(x, QK, q, d, bsums),
            _ => panic!("Q2_K expects a blocked destination"),
        }
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let (actq, actd, _abs, block_len) = match p {
            PreparedRow::Blocked { q, d, bsums, block_len } => (q, d, bsums, block_len),
            _ => panic!("Q2_K expects Q8_K activations"),
        };
        assert_eq!(block_len, QK);
        let blocks_per_row = t.k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        for (o, r) in out.iter_mut().zip(rows) {
            let mut sum = 0f32;
            for b in 0..blocks_per_row {
                let blk = &t.data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                let d = f16_to_f32(u16::from_le_bytes([blk[80], blk[81]]));
                let dmin = f16_to_f32(u16::from_le_bytes([blk[82], blk[83]]));
                let aq = &actq[b * QK..(b + 1) * QK];
                // The multi-step path: per sub-block integer dot with a
                // 4-bit scale, plus a min-offset correction using the
                // sub-block activation sum.
                let mut isum = 0i32; // Σ sc·(q·a) over sub-blocks
                let mut msum = 0i32; // Σ mn·Σa over sub-blocks
                for s in 0..SUB {
                    let sc = (blk[s] & 0xf) as i32;
                    let mn = (blk[s] >> 4) as i32;
                    let mut ssum = 0i32;
                    let mut asum = 0i32;
                    let qbase = 16 + s * SUB / 4;
                    for j4 in 0..SUB / 4 {
                        // SAFETY: qbase + j4 < 16 + SUB·SUB/4 ≤ BLOCK_BYTES,
                        // and `blk` is exactly one BLOCK_BYTES slice.
                        let byte = unsafe { *blk.get_unchecked(qbase + j4) };
                        let a = &aq[s * SUB + j4 * 4..];
                        ssum += ((byte & 0x3) as i32) * a[0] as i32;
                        ssum += (((byte >> 2) & 0x3) as i32) * a[1] as i32;
                        ssum += (((byte >> 4) & 0x3) as i32) * a[2] as i32;
                        ssum += (((byte >> 6) & 0x3) as i32) * a[3] as i32;
                        asum += a[0] as i32 + a[1] as i32 + a[2] as i32 + a[3] as i32;
                    }
                    isum += sc * ssum;
                    msum += mn * asum;
                }
                sum += (d * isum as f32 - dmin * msum as f32) * actd[b];
            }
            *o = sum;
        }
    }
}

/// Quantize one 256-value super-block to Q2_K (simplified llama.cpp
/// algorithm: per-sub-block affine fit to [0,3], 4-bit scale/min grid).
pub fn pack_block_q2_k(xs: &[f32], blk: &mut [u8]) {
    debug_assert_eq!(xs.len(), QK);
    debug_assert_eq!(blk.len(), BLOCK_BYTES);
    // Per-sub-block float scale/min, with a small scale search like
    // llama.cpp's make_qkx2_quants (a fixed (max−min)/3 fit is very lossy
    // on ternary data: the zero level falls between grid points).
    let mut scales = [0f32; SUB];
    let mut mins = [0f32; SUB];
    for s in 0..SUB {
        let sub = &xs[s * SUB..(s + 1) * SUB];
        let min = sub.iter().cloned().fold(f32::INFINITY, f32::min).min(0.0);
        let max = sub.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let range = (max - min).max(0.0);
        let mut best = (f32::INFINITY, 0f32);
        for steps in 1..=6 {
            let scale = range / (steps as f32 * 0.5 + 0.5); // range/1 .. range/3.5
            if scale <= 0.0 {
                best = (0.0, 0.0);
                break;
            }
            let sse: f32 = sub
                .iter()
                .map(|&v| {
                    let q = (((v - min) / scale).round()).clamp(0.0, 3.0);
                    let back = q * scale + min;
                    (back - v) * (back - v)
                })
                .sum();
            if sse < best.0 {
                best = (sse, scale);
            }
        }
        scales[s] = best.1;
        mins[s] = -min;
    }
    let max_scale = scales.iter().cloned().fold(0f32, f32::max);
    let max_min = mins.iter().cloned().fold(0f32, f32::max);
    let d = f16_to_f32(f32_to_f16(if max_scale > 0.0 { max_scale / 15.0 } else { 0.0 }));
    let dmin = f16_to_f32(f32_to_f16(if max_min > 0.0 { max_min / 15.0 } else { 0.0 }));
    blk[80..82].copy_from_slice(&f32_to_f16(d).to_le_bytes());
    blk[82..84].copy_from_slice(&f32_to_f16(dmin).to_le_bytes());
    for s in 0..SUB {
        let sc4 = if d > 0.0 { ((scales[s] / d).round() as i32).clamp(0, 15) } else { 0 };
        let mn4 = if dmin > 0.0 { ((mins[s] / dmin).round() as i32).clamp(0, 15) } else { 0 };
        blk[s] = (sc4 as u8) | ((mn4 as u8) << 4);
        let eff_scale = d * sc4 as f32;
        let eff_min = dmin * mn4 as f32;
        let sub = &xs[s * SUB..(s + 1) * SUB];
        for (j, &v) in sub.iter().enumerate() {
            let q = if eff_scale > 0.0 {
                (((v + eff_min) / eff_scale).round() as i32).clamp(0, 3)
            } else {
                0
            };
            let idx = s * SUB + j;
            blk[16 + idx / 4] |= (q as u8) << (2 * (idx % 4));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.0625)
    }

    #[test]
    fn bpw_is_2_625() {
        let t = random_ternary(2, 512, 1);
        let packed = Q2KKernel.quantize(&t);
        assert!((packed.bits_per_weight() - 2.625).abs() < 1e-9);
    }

    #[test]
    fn dequant_error_bounded_on_ternary() {
        let t = random_ternary(2, 256, 2);
        let packed = Q2KKernel.quantize(&t);
        let back = Q2KKernel.dequantize(&packed);
        let want = t.dequantize();
        // K-quants on ternary data land within one quantization step.
        for (g, w) in back.iter().zip(want.iter()) {
            assert!((g - w).abs() < 0.08 * 0.0625 * 3.0 + 0.02, "{g} vs {w}");
        }
    }

    #[test]
    fn gemv_close_to_dense() {
        let (m, k) = (8, 512);
        let t = random_ternary(m, k, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let packed = Q2KKernel.quantize(&t);
        let p = Q2KKernel.prepare(&x, k);
        let mut out = vec![0f32; m];
        Q2KKernel.gemv(&packed, &p, &mut out);
        // gemv must agree with its own dequantization (the format loss is
        // accounted separately in dequant_error_bounded_on_ternary).
        let wd = Q2KKernel.dequantize(&packed);
        for r in 0..m {
            let want: f32 = (0..k).map(|i| wd[r * k + i] * x[i]).sum();
            assert!((out[r] - want).abs() < 0.05 * want.abs().max(1.0), "row {r}: {} vs {want}", out[r]);
        }
    }
}
