//! F32 reference MAD kernel: dense f32 weights, raw f32 activations.
//! This is the "full-precision path" quality evals compare against and the
//! slowest speed baseline (16→32-bit storage puts it off the paper's
//! charts for big models — the Table 7 "N/A" rows).

use crate::kernels::quant::TernaryWeights;
use crate::kernels::{
    simd, Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor,
    QuantType,
};

pub struct F32Kernel;

impl Kernel for F32Kernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: QuantType::F32,
            name: "F32",
            class: KernelClass::MadBased,
            element_wise: false,
            bpw: 32.0,
            lossless: false, // full precision but NOT the training-scheme integer path
            k_multiple: 1,
            ternary_native: true,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let deq = w.dequantize();
        let mut data = vec![0u8; deq.len() * 4];
        for (chunk, v) in data.chunks_exact_mut(4).zip(deq.iter()) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        QTensor { qtype: QuantType::F32, m: w.m, k: w.k, data, scale: w.scale, sparse: None }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        t.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    fn prepare_kind(&self, _k: usize) -> PrepareKind {
        PrepareKind::Raw
    }

    /// No preprocessing: the batched path borrows the raw activation row
    /// (no copy); only the standalone `prepare` clones.
    fn prepare_row_into(&self, x: &[f32], k: usize, _dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let x = match p {
            PreparedRow::Raw(x) => x,
            _ => panic!("F32 expects raw activations"),
        };
        simd::note_call(simd::active_level());
        let row_bytes = t.k * 4;
        for (o, r) in out.iter_mut().zip(rows) {
            let wrow = &t.data[r * row_bytes..(r + 1) * row_bytes];
            *o = dot_f32_bytes(wrow, x);
        }
    }
}

/// f32 dot product over little-endian weight bytes — the shared
/// lane-blocked primitive, so the vector tiers (AVX2/NEON loads straight
/// off the byte stream) are bit-identical to the scalar reference.
#[inline]
pub fn dot_f32_bytes(wrow: &[u8], x: &[f32]) -> f32 {
    pallas_core::simd::ops::dot_f32_le(wrow, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    #[test]
    fn exact_on_dequantized_weights() {
        let mut rng = Rng::new(1);
        let q: Vec<i8> = (0..4 * 64).map(|_| rng.next_ternary() as i8).collect();
        let t = TernaryWeights::from_ternary(q, 4, 64, 0.5);
        let x: Vec<f32> = (0..64).map(|_| rng.next_gaussian()).collect();
        let kern = F32Kernel;
        let packed = kern.quantize(&t);
        assert_eq!(kern.dequantize(&packed), t.dequantize());
        let p = kern.prepare(&x, 64);
        let mut out = vec![0f32; 4];
        kern.gemv(&packed, &p, &mut out);
        let wd = t.dequantize();
        for r in 0..4 {
            // The shared 8-lane accumulation order of simd::ops.
            let mut acc = [0f32; 8];
            for i in 0..64 {
                acc[i & 7] += wd[r * 64 + i] * x[i];
            }
            let a = (acc[0] + acc[4]) + (acc[1] + acc[5]);
            let b = (acc[2] + acc[6]) + (acc[3] + acc[7]);
            assert_eq!(out[r], a + b);
        }
    }
}
