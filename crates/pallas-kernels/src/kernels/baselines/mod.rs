//! Baseline kernels the paper compares against (§2.3, §4.1.2):
//!
//! * [`f32_mad`] / [`f16_mad`] — full-precision MAD path ("Float16" in the
//!   paper; our reference CPU has no native f16 FMA so F16 stores half
//!   weights and widens on the fly, exactly like llama.cpp on AVX2).
//! * [`q4_0`] — llama.cpp general 4-bit format (bit-wise MAD).
//! * [`q2_k`] — llama.cpp K-quants 2-bit format: the multi-step
//!   dequantization the paper calls out as a ternary-hostile cost.
//! * [`tq1_0`] / [`tq2_0`] — llama.cpp element-wise MAD ternary formats
//!   (bpw 1.69 / 2.06) with per-block Q8_K activations (not lossless).
//! * [`tmac`] — a T-MAC-style *bit-wise* LUT kernel (2-bit, g=4): the
//!   prior state of the art TL improves upon.

pub mod f16_mad;
pub mod f32_mad;
pub mod q2_k;
pub mod q4_0;
pub mod tmac;
pub mod tq1_0;
pub mod tq2_0;
