//! llama.cpp **TQ1_0**: the densest ternary MAD format — base-3 packing,
//! 5 trits per byte. Blocks of 256 weights:
//!
//! * 48 bytes × 5 trits  = 240 weights
//! * 4 bytes  × 4 trits  = 16 weights
//! * 2 bytes f16 scale
//!
//! 54 bytes / 256 weights = **1.6875 bpw** — the bpw twin of TL2 that the
//! paper benchmarks MAD-vs-LUT against (§4.1.2, Appendix B.3).
//!
//! Decoding uses llama.cpp's fixed-point multiply trick: a byte `b`
//! encoding trits `t0..t4` is stored pre-scaled so that iterating
//! `b *= 3` yields the next trit in the top bits — one multiply and shift
//! per weight instead of div/mod.

use crate::kernels::quant::{quantize_act_blocked_into, TernaryWeights};
use crate::kernels::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};
use pallas_core::util::{f16_to_f32, f32_to_f16};

pub struct Tq10Kernel;

pub const QK: usize = 256;
/// 48 five-trit bytes + 4 four-trit bytes + f16 scale.
pub const BLOCK_BYTES: usize = 48 + 4 + 2;

/// Powers of three for trit packing.
const POW3: [u16; 6] = [1, 3, 9, 27, 81, 243];

impl Kernel for Tq10Kernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: QuantType::Tq10,
            name: "TQ1_0",
            class: KernelClass::MadBased,
            element_wise: true,
            bpw: BLOCK_BYTES as f64 * 8.0 / QK as f64, // 1.6875
            lossless: false,
            k_multiple: QK,
            ternary_native: true,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let (m, k) = (w.m, w.k);
        assert_eq!(k % QK, 0, "TQ1_0 requires K % 256 == 0");
        let blocks_per_row = k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        let mut data = vec![0u8; m * row_bytes];
        let dbits = f32_to_f16(w.scale).to_le_bytes();
        for r in 0..m {
            let row = w.row(r);
            for b in 0..blocks_per_row {
                let src = &row[b * QK..(b + 1) * QK];
                let blk = &mut data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                // 48 bytes of 5 trits (weights 0..240)
                for (i, chunk) in src[..240].chunks_exact(5).enumerate() {
                    blk[i] = pack_trits(chunk);
                }
                // 4 bytes of 4 trits (weights 240..256)
                for (i, chunk) in src[240..].chunks_exact(4).enumerate() {
                    blk[48 + i] = pack_trits(chunk);
                }
                blk[52..].copy_from_slice(&dbits);
            }
        }
        QTensor { qtype: QuantType::Tq10, m, k, data, scale: w.scale, sparse: None }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let blocks_per_row = t.k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            for b in 0..blocks_per_row {
                let blk = &t.data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                let d = f16_to_f32(u16::from_le_bytes([blk[52], blk[53]]));
                for &byte in &blk[..48] {
                    let mut q = byte as u16;
                    for _ in 0..5 {
                        q *= 3;
                        out.push((((q >> 8) & 0x3) as i32 - 1) as f32 * d);
                        q &= 0xff;
                    }
                }
                for &byte in &blk[48..52] {
                    // 4-trit bytes are packed as ceil(v·256/3⁴); the same
                    // ×3 pop-from-top trick walks their digits.
                    let mut q = byte as u16;
                    for _ in 0..4 {
                        q *= 3;
                        out.push((((q >> 8) & 0x3) as i32 - 1) as f32 * d);
                        q &= 0xff;
                    }
                }
            }
        }
        out
    }

    fn prepare_kind(&self, _k: usize) -> PrepareKind {
        PrepareKind::Blocked { block_len: QK }
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        match dst {
            PreparedRowMut::Blocked { q, d, bsums } => quantize_act_blocked_into(x, QK, q, d, bsums),
            _ => panic!("TQ1_0 expects a blocked destination"),
        }
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let (actq, actd, bsums, block_len) = match p {
            PreparedRow::Blocked { q, d, bsums, block_len } => (q, d, bsums, block_len),
            _ => panic!("TQ1_0 expects Q8_K activations"),
        };
        assert_eq!(block_len, QK);
        let blocks_per_row = t.k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        for (o, r) in out.iter_mut().zip(rows) {
            let mut sum = 0f32;
            for b in 0..blocks_per_row {
                let blk = &t.data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                let d = f16_to_f32(u16::from_le_bytes([blk[52], blk[53]]));
                let aq = &actq[b * QK..(b + 1) * QK];
                let mut isum = 0i32;
                // 5-trit bytes: the multiply-shift decode is the hot loop.
                for (i, &byte) in blk[..48].iter().enumerate() {
                    let mut q = byte as u16;
                    let base = i * 5;
                    for j in 0..5 {
                        q = q.wrapping_mul(3);
                        let trit = ((q >> 8) & 0x3) as i32; // 0, 1, 2
                        // SAFETY: base + j < 48·5 = 240 ≤ QK and aq holds
                        // one QK-entry block.
                        isum += trit * unsafe { *aq.get_unchecked(base + j) } as i32;
                        q &= 0xff;
                    }
                }
                for (i, &byte) in blk[48..52].iter().enumerate() {
                    let mut q = byte as u16;
                    let base = 240 + i * 4;
                    for j in 0..4 {
                        q = q.wrapping_mul(3);
                        let trit = ((q >> 8) & 0x3) as i32;
                        // SAFETY: base + j < 240 + 4·4 = 256 = QK and aq
                        // holds one QK-entry block.
                        isum += trit * unsafe { *aq.get_unchecked(base + j) } as i32;
                        q &= 0xff;
                    }
                }
                isum -= bsums[b];
                sum += isum as f32 * d * actd[b];
            }
            *o = sum;
        }
    }
}

/// Pack up to 5 trits into one byte in llama.cpp's fixed-point encoding:
/// value = Σ tᵢ·3^(4−i) for 5 trits (or Σ tᵢ·3^(3−i) for 4), then scaled
/// by 256/3^n (rounded up) so repeated ×3 pops trits from the top byte.
pub fn pack_trits(trits: &[i8]) -> u8 {
    let n = trits.len();
    debug_assert!(n == 4 || n == 5);
    let mut v = 0u32;
    for (i, &t) in trits.iter().enumerate() {
        v += ((t + 1) as u32) * POW3[n - 1 - i] as u32;
    }
    // ceil(v * 256 / 3^n): the canonical llama.cpp TQ1_0 fixed-point form.
    ((v * 256 + (POW3[n] as u32 - 1)) / POW3[n] as u32) as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.0625)
    }

    #[test]
    fn pack_trits_decodes_by_multiply_shift() {
        // Every 5-trit pattern must decode exactly via the ×3 trick.
        for pattern in 0..3usize.pow(5) {
            let mut trits = [0i8; 5];
            let mut rest = pattern;
            for d in (0..5).rev() {
                trits[d] = (rest % 3) as i8 - 1;
                rest /= 3;
            }
            let byte = pack_trits(&trits);
            let mut q = byte as u16;
            for (j, &want) in trits.iter().enumerate() {
                q = q.wrapping_mul(3);
                let got = ((q >> 8) & 0x3) as i32 - 1;
                assert_eq!(got, want as i32, "pattern {pattern} trit {j}");
                q &= 0xff;
            }
        }
    }

    #[test]
    fn pack_4_trits_decodes() {
        for pattern in 0..3usize.pow(4) {
            let mut trits = [0i8; 4];
            let mut rest = pattern;
            for d in (0..4).rev() {
                trits[d] = (rest % 3) as i8 - 1;
                rest /= 3;
            }
            let byte = pack_trits(&trits);
            let mut q = byte as u16;
            for (j, &want) in trits.iter().enumerate() {
                q = q.wrapping_mul(3);
                assert_eq!(((q >> 8) & 0x3) as i32 - 1, want as i32, "pattern {pattern} trit {j}");
                q &= 0xff;
            }
        }
    }

    #[test]
    fn bpw_is_1_69() {
        let t = random_ternary(2, 512, 1);
        let packed = Tq10Kernel.quantize(&t);
        assert!((packed.bits_per_weight() - 1.6875).abs() < 1e-9);
    }

    #[test]
    fn ternary_round_trip_exact() {
        let t = random_ternary(3, 512, 2);
        let packed = Tq10Kernel.quantize(&t);
        assert_eq!(Tq10Kernel.dequantize(&packed), t.dequantize());
    }

    #[test]
    fn gemv_close_to_dense() {
        let (m, k) = (8, 768);
        let t = random_ternary(m, k, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let packed = Tq10Kernel.quantize(&t);
        let p = Tq10Kernel.prepare(&x, k);
        let mut out = vec![0f32; m];
        Tq10Kernel.gemv(&packed, &p, &mut out);
        let wd = t.dequantize();
        for r in 0..m {
            let want: f32 = (0..k).map(|i| wd[r * k + i] * x[i]).sum();
            assert!((out[r] - want).abs() < 0.02 * want.abs().max(1.0), "row {r}");
        }
    }
}
