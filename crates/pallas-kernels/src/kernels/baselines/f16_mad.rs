//! F16 MAD kernel — the paper's "Float16" baseline (llama.cpp `F16`):
//! weights stored as IEEE half (16 bpw), widened to f32 in the inner loop
//! and multiply-added against raw f32 activations.

use crate::kernels::quant::TernaryWeights;
use crate::kernels::{
    simd, Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor,
    QuantType,
};
use pallas_core::util::{f16_to_f32, f32_to_f16};

pub struct F16Kernel;

impl Kernel for F16Kernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: QuantType::F16,
            name: "F16",
            class: KernelClass::MadBased,
            element_wise: false,
            bpw: 16.0,
            lossless: false,
            k_multiple: 1,
            ternary_native: true, // ternary·scale values are exactly representable in f16
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let mut data = vec![0u8; w.m * w.k * 2];
        for (chunk, &q) in data.chunks_exact_mut(2).zip(w.q.iter()) {
            let h = f32_to_f16(q as f32 * w.scale);
            chunk.copy_from_slice(&h.to_le_bytes());
        }
        QTensor { qtype: QuantType::F16, m: w.m, k: w.k, data, scale: w.scale, sparse: None }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        t.data
            .chunks_exact(2)
            .map(|c| f16_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect()
    }

    fn prepare_kind(&self, _k: usize) -> PrepareKind {
        PrepareKind::Raw
    }

    /// No preprocessing: the batched path borrows the raw activation row
    /// (no copy); only the standalone `prepare` clones.
    fn prepare_row_into(&self, x: &[f32], k: usize, _dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let x = match p {
            PreparedRow::Raw(x) => x,
            _ => panic!("F16 expects raw activations"),
        };
        simd::note_call(simd::active_level());
        let row_bytes = t.k * 2;
        for (o, r) in out.iter_mut().zip(rows) {
            let wrow = &t.data[r * row_bytes..(r + 1) * row_bytes];
            *o = dot_f16(wrow, x);
        }
    }
}

/// Inner loop: widen f16→f32 in the loop (F16C `vcvtph2ps` on AVX2, the
/// 64K table elsewhere — both exact IEEE widenings) and multiply-add via
/// the shared lane-blocked primitive, so every tier is bit-identical.
/// Mirrors llama.cpp's `ggml_vec_dot_f16` (+ `ggml_table_f32_f16` for
/// the table fallback). Also the LM head's inner loop (`DenseF16`).
#[inline]
pub fn dot_f16(wrow: &[u8], x: &[f32]) -> f32 {
    pallas_core::simd::ops::dot_f16_le(wrow, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    #[test]
    fn ternary_values_survive_f16() {
        let mut rng = Rng::new(2);
        let q: Vec<i8> = (0..256).map(|_| rng.next_ternary() as i8).collect();
        let t = TernaryWeights::from_ternary(q, 2, 128, 0.03125); // exact power of 2
        let kern = F16Kernel;
        let packed = kern.quantize(&t);
        assert_eq!(packed.bits_per_weight(), 16.0);
        assert_eq!(kern.dequantize(&packed), t.dequantize());
    }

    #[test]
    fn gemv_matches_f64_reference() {
        let mut rng = Rng::new(3);
        let q: Vec<i8> = (0..8 * 96).map(|_| rng.next_ternary() as i8).collect();
        let t = TernaryWeights::from_ternary(q, 8, 96, 0.0417);
        let x: Vec<f32> = (0..96).map(|_| rng.next_gaussian()).collect();
        let kern = F16Kernel;
        let packed = kern.quantize(&t);
        let p = kern.prepare(&x, 96);
        let mut out = vec![0f32; 8];
        kern.gemv(&packed, &p, &mut out);
        let wd = kern.dequantize(&packed);
        for r in 0..8 {
            let want: f64 =
                (0..96).map(|i| wd[r * 96 + i] as f64 * x[i] as f64).sum();
            assert!((out[r] as f64 - want).abs() < 1e-3, "row {r}");
        }
    }
}
