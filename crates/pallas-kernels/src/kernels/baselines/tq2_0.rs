//! llama.cpp **TQ2_0**: element-wise MAD ternary format. Blocks of 256
//! weights: 64 bytes of 2-bit codes + f16 scale = 66 bytes → 2.06 bpw.
//! Activations are per-block Q8_K — which is exactly why it is *not*
//! lossless for BitNet b1.58 (§2.3): the per-block activation scales
//! diverge from the per-tensor training scheme.

use crate::kernels::quant::{quantize_act_blocked_into, TernaryWeights};
use crate::kernels::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};
use pallas_core::util::{f16_to_f32, f32_to_f16};

pub struct Tq20Kernel;

/// Block length (matches Q8_K activation blocks).
pub const QK: usize = 256;
/// 2-bit codes (4/byte) + f16 scale.
pub const BLOCK_BYTES: usize = QK / 4 + 2;

impl Kernel for Tq20Kernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: QuantType::Tq20,
            name: "TQ2_0",
            class: KernelClass::MadBased,
            element_wise: true,
            bpw: BLOCK_BYTES as f64 * 8.0 / QK as f64, // 2.0625
            lossless: false,
            // Paper §3.2.2: "TQ2_0 only supports multiples of 256".
            k_multiple: QK,
            ternary_native: true,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let (m, k) = (w.m, w.k);
        assert_eq!(k % QK, 0, "TQ2_0 requires K % 256 == 0");
        let blocks_per_row = k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        let mut data = vec![0u8; m * row_bytes];
        let dbits = f32_to_f16(w.scale).to_le_bytes();
        for r in 0..m {
            let row = w.row(r);
            for b in 0..blocks_per_row {
                let blk = &mut data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                for (byte_i, quad) in row[b * QK..(b + 1) * QK].chunks_exact(4).enumerate() {
                    let mut byte = 0u8;
                    for (j, &t) in quad.iter().enumerate() {
                        byte |= (((t + 1) as u8) & 0x3) << (2 * j);
                    }
                    blk[byte_i] = byte;
                }
                blk[QK / 4..].copy_from_slice(&dbits);
            }
        }
        QTensor { qtype: QuantType::Tq20, m, k, data, scale: w.scale, sparse: None }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let blocks_per_row = t.k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            for b in 0..blocks_per_row {
                let blk = &t.data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                let d = f16_to_f32(u16::from_le_bytes([blk[QK / 4], blk[QK / 4 + 1]]));
                for byte_i in 0..QK / 4 {
                    let byte = blk[byte_i];
                    for j in 0..4 {
                        out.push((((byte >> (2 * j)) & 0x3) as i32 - 1) as f32 * d);
                    }
                }
            }
        }
        out
    }

    fn prepare_kind(&self, _k: usize) -> PrepareKind {
        PrepareKind::Blocked { block_len: QK }
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        match dst {
            PreparedRowMut::Blocked { q, d, bsums } => quantize_act_blocked_into(x, QK, q, d, bsums),
            _ => panic!("TQ2_0 expects a blocked destination"),
        }
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let (actq, actd, bsums, block_len) = match p {
            PreparedRow::Blocked { q, d, bsums, block_len } => (q, d, bsums, block_len),
            _ => panic!("TQ2_0 expects Q8_K activations"),
        };
        assert_eq!(block_len, QK);
        let blocks_per_row = t.k / QK;
        let row_bytes = blocks_per_row * BLOCK_BYTES;
        for (o, r) in out.iter_mut().zip(rows) {
            let mut sum = 0f32;
            for b in 0..blocks_per_row {
                let blk = &t.data[r * row_bytes + b * BLOCK_BYTES..][..BLOCK_BYTES];
                let d = f16_to_f32(u16::from_le_bytes([blk[QK / 4], blk[QK / 4 + 1]]));
                let aq = &actq[b * QK..(b + 1) * QK];
                // Σ a·(code−1) = Σ a·code − Σa (per block).
                let mut isum = 0i32;
                for (byte_i, quad) in aq.chunks_exact(4).enumerate() {
                    // SAFETY: aq has QK entries so byte_i < QK/4, and the
                    // block stores QK/4 packed bytes before the scale.
                    let byte = unsafe { *blk.get_unchecked(byte_i) };
                    isum += ((byte & 0x3) as i32) * quad[0] as i32;
                    isum += (((byte >> 2) & 0x3) as i32) * quad[1] as i32;
                    isum += (((byte >> 4) & 0x3) as i32) * quad[2] as i32;
                    isum += (((byte >> 6) & 0x3) as i32) * quad[3] as i32;
                }
                isum -= bsums[b];
                sum += isum as f32 * d * actd[b];
            }
            *o = sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.0625) // power of two → exact f16
    }

    #[test]
    fn bpw_is_2_06() {
        let t = random_ternary(2, 512, 1);
        let packed = Tq20Kernel.quantize(&t);
        assert!((packed.bits_per_weight() - 2.0625).abs() < 1e-9);
    }

    #[test]
    fn ternary_round_trip_exact() {
        let t = random_ternary(3, 256, 2);
        let packed = Tq20Kernel.quantize(&t);
        assert_eq!(Tq20Kernel.dequantize(&packed), t.dequantize());
    }

    #[test]
    fn gemv_close_to_dense() {
        let (m, k) = (8, 512);
        let t = random_ternary(m, k, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let packed = Tq20Kernel.quantize(&t);
        let p = Tq20Kernel.prepare(&x, k);
        let mut out = vec![0f32; m];
        Tq20Kernel.gemv(&packed, &p, &mut out);
        let wd = t.dequantize();
        for r in 0..m {
            let want: f32 = (0..k).map(|i| wd[r * k + i] * x[i]).sum();
            assert!((out[r] - want).abs() < 0.02 * want.abs().max(1.0), "row {r}");
        }
    }

    #[test]
    fn not_lossless_vs_training_scheme() {
        // Activations whose dynamic range varies across 256-blocks make the
        // per-block path diverge from the per-tensor training scheme.
        use crate::kernels::quant::{quantize_act_int8, training_scheme_ref_row};
        let (m, k) = (4, 512);
        let t = random_ternary(m, k, 5);
        let mut rng = Rng::new(6);
        let mut x: Vec<f32> = (0..k).map(|_| rng.next_gaussian() * 0.05).collect();
        x[10] = 4.0; // spike only in block 0
        let act = quantize_act_int8(&x);
        let packed = Tq20Kernel.quantize(&t);
        let p = Tq20Kernel.prepare(&x, k);
        let mut out = vec![0f32; m];
        Tq20Kernel.gemv(&packed, &p, &mut out);
        let any_diff = (0..m).any(|r| {
            out[r] != training_scheme_ref_row(t.row(r), t.scale, &act)
        });
        assert!(any_diff, "TQ2_0 should NOT reproduce the training scheme bit-for-bit");
    }
}
