//! T-MAC-style **bit-wise LUT** kernel (Wei et al. 2024) — the prior LUT
//! state of the art the paper's TL kernels improve upon.
//!
//! Ternary weights are stored as 2-bit codes `w+1 ∈ {0,1,2}` split into two
//! bit-planes (bpw 2 — the spatial inefficiency §2.3 calls out). Each plane
//! is processed in groups of g=4 bits; a 16-entry LUT per group of 4
//! activations holds the subset sums `Σ a_j·bit_j`; results from the two
//! planes combine as `R = 2·Σ(a·b1) + Σ(a·b0) − Σa` (paper Fig. 4 (2):
//! lookup, then *bit-shift and accumulate*).
//!
//! Cost per weight: 2 lookups / 4 weights = 0.5, vs TL2's 1/3 — and 2 bpw
//! of traffic vs TL2's 1.67. Element-wise beats bit-wise on both axes,
//! which is the paper's Appendix A.3 claim; the benches measure it.
//!
//! Like T-MAC, tables are requantized to int8 (with per-block scales),
//! so the kernel is *not* lossless (§3.2.1).

use crate::kernels::quant::{quantize_act_int8_into, TernaryWeights};
use crate::kernels::tl1::{requantize_tables_into, LUT_BLOCK_GROUPS, LUT_W};
use crate::kernels::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};

pub struct TmacKernel;

impl Kernel for TmacKernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: QuantType::Tmac,
            name: "TMAC",
            class: KernelClass::LutBased,
            element_wise: false,
            bpw: 2.0,
            lossless: false,
            k_multiple: 8,
            ternary_native: true,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let (m, k) = (w.m, w.k);
        assert_eq!(k % 8, 0, "TMAC requires K % 8 == 0");
        let plane_bytes = k / 8;
        let row_bytes = 2 * plane_bytes;
        let mut data = vec![0u8; m * row_bytes];
        for r in 0..m {
            let row = w.row(r);
            let (p0, p1) = data[r * row_bytes..(r + 1) * row_bytes].split_at_mut(plane_bytes);
            for (i, &t) in row.iter().enumerate() {
                let code = (t + 1) as u8; // 0..2
                p0[i / 8] |= (code & 1) << (i % 8);
                p1[i / 8] |= ((code >> 1) & 1) << (i % 8);
            }
        }
        QTensor { qtype: QuantType::Tmac, m, k, data, scale: w.scale, sparse: None }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let plane_bytes = t.k / 8;
        let row_bytes = 2 * plane_bytes;
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            let (p0, p1) = t.data[r * row_bytes..(r + 1) * row_bytes].split_at(plane_bytes);
            for i in 0..t.k {
                let b0 = (p0[i / 8] >> (i % 8)) & 1;
                let b1 = (p1[i / 8] >> (i % 8)) & 1;
                let code = (b1 << 1) | b0;
                out.push((code as i32 - 1) as f32 * t.scale);
            }
        }
        out
    }

    fn prepare_kind(&self, k: usize) -> PrepareKind {
        PrepareKind::BitLut { groups: k / 4, block_groups: LUT_BLOCK_GROUPS }
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        match dst {
            PreparedRowMut::BitLut { aq, tmp16, tables, block_scales, scale, act_sum } => {
                let (s, sum) = quantize_act_int8_into(x, aq);
                build_subset_tables_into(aq, tmp16);
                requantize_tables_into(tmp16, LUT_BLOCK_GROUPS, tables, block_scales);
                *scale = s;
                *act_sum = sum;
            }
            _ => panic!("TMAC expects a bit-wise LUT destination"),
        }
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let (tables, block_scales, block_groups, scale, act_sum) = match p {
            PreparedRow::BitLut { tables, block_scales, block_groups, scale, act_sum } => {
                (tables, block_scales, block_groups, scale, act_sum)
            }
            _ => panic!("TMAC expects a bit-wise LUT activation"),
        };
        let plane_bytes = t.k / 8;
        let row_bytes = 2 * plane_bytes;
        let combined = t.scale / scale;
        for (o, r) in out.iter_mut().zip(rows) {
            let (p0, p1) = t.data[r * row_bytes..(r + 1) * row_bytes].split_at(plane_bytes);
            let mut facc = 0f32;
            // One scale block covers `block_groups` 4-activation groups =
            // block_groups/2 plane bytes.
            let bytes_per_block = block_groups / 2;
            let mut blk = 0usize;
            for (c0, c1) in p0.chunks(bytes_per_block).zip(p1.chunks(bytes_per_block)) {
                let mut acc0 = 0i32;
                let mut acc1 = 0i32;
                let base = blk * block_groups * LUT_W;
                let mut g = 0usize;
                for (&b0, &b1) in c0.iter().zip(c1.iter()) {
                    // SAFETY: tables holds block_groups LUT_W-entry tables
                    // per block and nibble codes are < LUT_W, so every
                    // index below is in bounds.
                    let t0a = unsafe { *tables.get_unchecked(base + g * LUT_W + (b0 & 0xf) as usize) };
                    // SAFETY: as above.
                    let t1a = unsafe { *tables.get_unchecked(base + g * LUT_W + (b1 & 0xf) as usize) };
                    // SAFETY: as above.
                    let t0b =
                        unsafe { *tables.get_unchecked(base + (g + 1) * LUT_W + (b0 >> 4) as usize) };
                    // SAFETY: as above.
                    let t1b =
                        unsafe { *tables.get_unchecked(base + (g + 1) * LUT_W + (b1 >> 4) as usize) };
                    acc0 += t0a as i32 + t0b as i32;
                    acc1 += t1a as i32 + t1b as i32;
                    g += 2;
                }
                // Bit-shift and accumulate: plane 1 carries weight 2.
                facc += (acc0 + 2 * acc1) as f32 * block_scales[blk];
                blk += 1;
            }
            *o = (facc - act_sum as f32) * combined;
        }
    }
}

/// Build the bit-wise subset-sum tables: one 16-entry table per group of 4
/// activations, `table[s] = Σ_{j: s_j=1} a[4g+j]`, computed incrementally
/// (2^g adds instead of g·2^g).
pub fn build_subset_tables(aq: &[i8]) -> Vec<i16> {
    let mut tables = vec![0i16; (aq.len() / 4) * LUT_W];
    build_subset_tables_into(aq, &mut tables);
    tables
}

/// Allocation-free [`build_subset_tables`]: fills the caller-owned table
/// buffer (`(aq.len()/4) * LUT_W` entries).
pub fn build_subset_tables_into(aq: &[i8], tables: &mut [i16]) {
    debug_assert_eq!(aq.len() % 4, 0);
    let groups = aq.len() / 4;
    debug_assert_eq!(tables.len(), groups * LUT_W);
    tables.fill(0);
    for g in 0..groups {
        let t = &mut tables[g * LUT_W..(g + 1) * LUT_W];
        for j in 0..4 {
            let a = aq[4 * g + j] as i16;
            let stride = 1usize << j;
            for s in 0..stride {
                t[s | stride] = t[s] + a;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.05)
    }

    #[test]
    fn subset_tables_enumerate_sums() {
        let aq = [1i8, 10, 100, -50];
        let t = build_subset_tables(&aq);
        assert_eq!(t[0b0000], 0);
        assert_eq!(t[0b0001], 1);
        assert_eq!(t[0b0010], 10);
        assert_eq!(t[0b0100], 100);
        assert_eq!(t[0b1000], -50);
        assert_eq!(t[0b1111], 61);
        assert_eq!(t[0b1010], -40);
    }

    #[test]
    fn bit_planes_round_trip() {
        let t = random_ternary(4, 128, 1);
        let packed = TmacKernel.quantize(&t);
        assert_eq!(packed.bits_per_weight(), 2.0);
        assert_eq!(TmacKernel.dequantize(&packed), t.dequantize());
    }

    #[test]
    fn gemv_close_to_dense() {
        let (m, k) = (16, 1024);
        let t = random_ternary(m, k, 2);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let packed = TmacKernel.quantize(&t);
        let p = TmacKernel.prepare(&x, k);
        let mut out = vec![0f32; m];
        TmacKernel.gemv(&packed, &p, &mut out);
        let wd = t.dequantize();
        for r in 0..m {
            let want: f32 = (0..k).map(|i| wd[r * k + i] * x[i]).sum();
            assert!((out[r] - want).abs() < 0.05 * want.abs().max(1.0), "row {r}: {} vs {want}", out[r]);
        }
    }

    #[test]
    fn partial_trailing_block() {
        // 24 groups (not a multiple of LUT_BLOCK_GROUPS=32).
        let k = 96;
        let t = random_ternary(4, k, 4);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let packed = TmacKernel.quantize(&t);
        let p = TmacKernel.prepare(&x, k);
        let mut out = vec![0f32; 4];
        TmacKernel.gemv(&packed, &p, &mut out);
        let wd = t.dequantize();
        for r in 0..4 {
            let want: f32 = (0..k).map(|i| wd[r * k + i] * x[i]).sum();
            assert!((out[r] - want).abs() < 0.08 * want.abs().max(1.0), "row {r}");
        }
    }
}
