//! **I2_S** — "Int2 with a Scale" (paper §3.2.2): element-wise MAD-based
//! kernel that stores ternary weights as 2-bit codes with a single
//! per-tensor scale, and consumes *per-tensor* int8 activations — exactly
//! the BitNet b1.58 training computation, hence **lossless**.
//!
//! Layout: row-major, 4 weights per byte, code `w+1 ∈ {0,1,2}` in 2 bits
//! (little-end first within the byte). The paper requires K to be a
//! multiple of 128; the implementation unrolls in 16-weight (4-byte)
//! steps and accumulates in i32 (no overflow: |a|≤127, |w|≤1,
//! K·127 < 2^31 for any realistic K).

use super::quant::{quantize_act_int8_into, TernaryWeights};
use super::simd::{self, SimdLevel};
use super::sparse;
use super::{
    Kernel, KernelClass, KernelInfo, PrepareKind, PreparedRow, PreparedRowMut, QTensor, QuantType,
};

pub struct I2SKernel;

/// Weights per packed byte.
const WPB: usize = 4;

/// Weights per sparse-elision block: one K-alignment unit (32 bytes).
pub const SPARSE_BLOCK_WEIGHTS: usize = 128;

impl Kernel for I2SKernel {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            qtype: QuantType::I2S,
            name: "I2_S",
            class: KernelClass::MadBased,
            element_wise: true,
            bpw: 2.0,
            lossless: true,
            // Paper: "supports mpGEMM dimensions K that are multiples of
            // 128, while TQ2_0 only supports multiples of 256".
            k_multiple: 128,
            ternary_native: true,
        }
    }

    fn quantize(&self, w: &TernaryWeights) -> QTensor {
        let (m, k) = (w.m, w.k);
        assert_eq!(k % self.info().k_multiple, 0, "I2_S requires K % 128 == 0");
        let row_bytes = k / WPB;
        let mut data = vec![0u8; m * row_bytes];
        for r in 0..m {
            let src = w.row(r);
            let dst = &mut data[r * row_bytes..(r + 1) * row_bytes];
            for (b, chunk) in src.chunks_exact(WPB).enumerate() {
                let mut byte = 0u8;
                for (j, &t) in chunk.iter().enumerate() {
                    byte |= (((t + 1) as u8) & 0x3) << (2 * j);
                }
                dst[b] = byte;
            }
        }
        let bounds = sparse::uniform_bounds(k, SPARSE_BLOCK_WEIGHTS);
        let sparse = sparse::maybe_index(&w.q, m, k, &bounds);
        QTensor { qtype: QuantType::I2S, m, k, data, scale: w.scale, sparse }
    }

    fn dequantize(&self, t: &QTensor) -> Vec<f32> {
        let row_bytes = t.k / WPB;
        let mut out = Vec::with_capacity(t.m * t.k);
        for r in 0..t.m {
            for b in 0..row_bytes {
                let byte = t.data[r * row_bytes + b];
                for j in 0..WPB {
                    let code = (byte >> (2 * j)) & 0x3;
                    out.push((code as i32 - 1) as f32 * t.scale);
                }
            }
        }
        out
    }

    fn prepare_kind(&self, _k: usize) -> PrepareKind {
        PrepareKind::Int8
    }

    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>) {
        debug_assert_eq!(x.len(), k);
        match dst {
            PreparedRowMut::Int8 { q, scale, sum } => {
                let (s, sm) = quantize_act_int8_into(x, q);
                *scale = s;
                *sum = sm;
            }
            _ => panic!("I2_S expects a per-tensor int8 destination"),
        }
    }

    fn simd_levels(&self) -> &'static [SimdLevel] {
        simd::KERNEL_LEVELS
    }

    fn sparse_capable(&self) -> bool {
        true
    }

    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>) {
        let (q, scale, sum) = match p {
            PreparedRow::Int8 { q, scale, sum } => (q, scale, sum),
            _ => panic!("I2_S expects per-tensor int8 activations"),
        };
        debug_assert_eq!(q.len(), t.k);
        let row_bytes = t.k / WPB;
        let combined = t.scale / scale;
        let level = simd::active_level();
        simd::note_call(level);
        if let Some(idx) = &t.sparse {
            #[cfg(target_arch = "x86_64")]
            if level == SimdLevel::Avx2 {
                // SAFETY: AVX2 verified by the active dispatch level; the
                // packed rows match `q.len() / 4` bytes.
                unsafe {
                    simd::avx2::gemv_rows_i2s_sparse(&t.data, q, combined, out, rows, idx);
                }
                return;
            }
            #[cfg(target_arch = "aarch64")]
            if level == SimdLevel::Neon {
                // SAFETY: NEON verified by the active dispatch level; the
                // packed rows match `q.len() / 4` bytes.
                unsafe {
                    simd::neon::gemv_rows_i2s_sparse(&t.data, q, combined, out, rows, idx);
                }
                return;
            }
            let mut elided = 0u64;
            for (o, r) in out.iter_mut().zip(rows) {
                let wrow = &t.data[r * row_bytes..(r + 1) * row_bytes];
                *o = gemv_row_i2s_sparse(wrow, q, idx, r, &mut elided) as f32 * combined;
            }
            sparse::note_elided(level, elided);
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if level == SimdLevel::Avx2 {
            // SAFETY: AVX2 verified by the active dispatch level; the
            // packed rows match `q.len() / 4` bytes and `sum` is Σq.
            unsafe {
                simd::avx2::gemv_rows_i2s(&t.data, q, sum, combined, out, rows);
            }
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if level == SimdLevel::Neon {
            // SAFETY: NEON verified by the active dispatch level; the
            // packed rows match `q.len() / 4` bytes and `sum` is Σq.
            unsafe {
                simd::neon::gemv_rows_i2s(&t.data, q, sum, combined, out, rows);
            }
            return;
        }
        for (o, r) in out.iter_mut().zip(rows) {
            let wrow = &t.data[r * row_bytes..(r + 1) * row_bytes];
            *o = gemv_row_i2s(wrow, q, sum) as f32 * combined;
        }
    }
}

/// Inner loop: `Σ a[k] * (code[k] - 1)` = `Σ a·code - Σ a`.
/// Computing `Σ a·code` with unsigned codes and subtracting the
/// activation sum once mirrors the AVX2 `maddubs` (u8×i8) structure the
/// paper's implementation uses, and lets the compiler vectorize the body.
#[inline]
fn gemv_row_i2s(wrow: &[u8], aq: &[i8], act_sum: i32) -> i32 {
    let mut acc = 0i32;
    // 4 bytes (16 weights) per step; chunks_exact guarantees alignment of
    // the loop body so LLVM unrolls/vectorizes it.
    let mut k = 0usize;
    for b4 in wrow.chunks_exact(4) {
        let a = &aq[k..k + 16];
        let mut local = 0i32;
        for (bi, &byte) in b4.iter().enumerate() {
            let base = bi * 4;
            local += (byte & 0x3) as i32 * a[base] as i32;
            local += ((byte >> 2) & 0x3) as i32 * a[base + 1] as i32;
            local += ((byte >> 4) & 0x3) as i32 * a[base + 2] as i32;
            local += ((byte >> 6) & 0x3) as i32 * a[base + 3] as i32;
        }
        acc += local;
        k += 16;
    }
    acc - act_sum
}

/// Sparse inner loop: accumulate `Σ a·(code − 1)` = `Σ a·w` directly
/// over nonzero blocks only. A zero block contributes exactly 0 to that
/// sum, and both this form and the dense `Σ a·code − Σ a` compute the
/// same exact i32 (no overflow either way), so skipping zero blocks —
/// with no activation-sum bookkeeping at all — stays bit-identical to
/// [`gemv_row_i2s`].
#[inline]
fn gemv_row_i2s_sparse(
    wrow: &[u8],
    aq: &[i8],
    idx: &sparse::SparseIndex,
    row: usize,
    elided: &mut u64,
) -> i32 {
    const BLOCK_BYTES: usize = SPARSE_BLOCK_WEIGHTS / WPB;
    let mut acc = 0i32;
    for blk in 0..idx.blocks_per_row() {
        if !idx.is_nonzero(row, blk) {
            *elided += 1;
            continue;
        }
        let b0 = blk * BLOCK_BYTES;
        let b1 = (b0 + BLOCK_BYTES).min(wrow.len());
        let mut k = b0 * WPB;
        for b4 in wrow[b0..b1].chunks_exact(4) {
            let a = &aq[k..k + 16];
            let mut local = 0i32;
            for (bi, &byte) in b4.iter().enumerate() {
                let base = bi * 4;
                local += ((byte & 0x3) as i32 - 1) * a[base] as i32;
                local += (((byte >> 2) & 0x3) as i32 - 1) * a[base + 1] as i32;
                local += (((byte >> 4) & 0x3) as i32 - 1) * a[base + 2] as i32;
                local += (((byte >> 6) & 0x3) as i32 - 1) * a[base + 3] as i32;
            }
            acc += local;
            k += 16;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::quant::training_scheme_ref_row;
    use crate::kernels::Prepared;
    use pallas_core::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.031)
    }

    #[test]
    fn pack_unpack_identity() {
        let t = random_ternary(8, 256, 1);
        let k = I2SKernel;
        let packed = k.quantize(&t);
        assert_eq!(packed.bits_per_weight(), 2.0);
        let back = k.dequantize(&packed);
        let want = t.dequantize();
        assert_eq!(back, want);
    }

    #[test]
    fn matches_training_scheme_bit_for_bit() {
        let (m, kk) = (16, 1024);
        let t = random_ternary(m, kk, 2);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..kk).map(|_| rng.next_gaussian()).collect();
        let kern = I2SKernel;
        let packed = kern.quantize(&t);
        let p = kern.prepare(&x, kk);
        let act = match &p {
            Prepared::Int8(a) => a.clone(),
            _ => unreachable!(),
        };
        let mut out = vec![0f32; m];
        kern.gemv(&packed, &p, &mut out);
        for r in 0..m {
            let want = training_scheme_ref_row(t.row(r), t.scale, &act);
            assert_eq!(out[r], want, "row {r} must be bit-identical");
        }
    }

    #[test]
    fn all_zero_weights_give_zero() {
        let t = TernaryWeights::from_ternary(vec![0i8; 4 * 128], 4, 128, 1.0);
        let kern = I2SKernel;
        let packed = kern.quantize(&t);
        let x: Vec<f32> = (0..128).map(|i| i as f32).collect();
        let p = kern.prepare(&x, 128);
        let mut out = vec![7f32; 4];
        kern.gemv(&packed, &p, &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn extreme_activations_no_overflow() {
        // Worst case: all |a| = 127, all w = ±1, K large.
        let kk = 8192;
        let q: Vec<i8> = (0..kk).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let t = TernaryWeights::from_ternary(q, 1, kk, 1.0);
        let x: Vec<f32> = (0..kk).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let kern = I2SKernel;
        let packed = kern.quantize(&t);
        let p = kern.prepare(&x, kk);
        let mut out = vec![0f32; 1];
        kern.gemv(&packed, &p, &mut out);
        // Σ xq*wq = 127*8192 (every term +127·1 or −127·−1), scale 1/127
        assert_eq!(out[0], 8192.0);
    }

    #[test]
    #[should_panic(expected = "K % 128")]
    fn rejects_unaligned_k() {
        let t = random_ternary(2, 100, 4);
        I2SKernel.quantize(&t);
    }
}
