//! Shared machinery for element-wise LUT-based (ELUT) kernels — paper §3.1
//! and Appendix A/D.
//!
//! Terminology (paper Fig. 4, Eq. 3): weights are grouped `g` at a time;
//! each group's value pattern is a *code*; a lookup table built from the
//! activations maps code → partial sum `Σ_j a_j · w_j`. With *element-wise
//! mirror consolidation* (§3.1.1) only the non-negative half of the code
//! space is tabulated and a 1-bit sign recovers the other half
//! (`x = sign ⊕ (sign + x)`, Eq. 5).

/// Number of distinct codes for cardinality C, group g: `C^g`.
pub const fn code_count(c: usize, g: usize) -> usize {
    let mut n = 1;
    let mut i = 0;
    while i < g {
        n *= c;
        i += 1;
    }
    n
}

/// Mirror-consolidated (half) table size: `ceil(C^g / 2)` for symmetric
/// alphabets (the all-zero code maps to itself).
pub const fn half_code_count(c: usize, g: usize) -> usize {
    code_count(c, g) / 2 + 1
}

/// Bits per weight of the *bit-wise* representation (paper Table 3):
/// `ceil(log2(C)) * g / g = ceil(log2(C))`.
pub fn bitwise_bpw(c: usize) -> f64 {
    (usize::BITS - (c - 1).leading_zeros()) as f64
}

/// Bits per weight of the *element-wise* representation (paper Table 3):
/// index bits for the (possibly mirrored) table plus the sign bit, per g
/// weights. Mirror consolidation applies when the half table fits 16
/// entries but the full table does not (the SIMD 128-bit constraint).
pub fn elementwise_bpw(c: usize, g: usize) -> f64 {
    let full = code_count(c, g);
    if full <= 16 {
        // Full enumeration indexable by 4 bits (or fewer); round up to the
        // bit width actually needed.
        let idx_bits = (usize::BITS - (full - 1).leading_zeros()) as f64;
        idx_bits / g as f64
    } else {
        let half = half_code_count(c, g);
        assert!(half <= 16, "half table must fit a 16-entry shuffle");
        // 4-bit index + 1-bit sign per group.
        5.0 / g as f64
    }
}

/// Decode a base-C code into `g` digits, most-significant first, each
/// mapped to a symmetric alphabet value via `alphabet`.
pub fn decode_code(code: usize, c: usize, g: usize, alphabet: &[i8]) -> Vec<i8> {
    assert_eq!(alphabet.len(), c);
    let mut digits = vec![0i8; g];
    let mut rest = code;
    for d in (0..g).rev() {
        digits[d] = alphabet[rest % c];
        rest /= c;
    }
    assert_eq!(rest, 0, "code out of range");
    digits
}

/// Encode `g` alphabet values into a base-C code (inverse of
/// [`decode_code`]).
pub fn encode_code(vals: &[i8], c: usize, alphabet: &[i8]) -> usize {
    let mut code = 0usize;
    for &v in vals {
        let digit = alphabet.iter().position(|&a| a == v).expect("value in alphabet");
        code = code * c + digit;
    }
    code
}

/// Mirror consolidation for symmetric alphabets ordered so that
/// `alphabet[i] == -alphabet[c-1-i]` (e.g. ternary `[-1, 0, 1]`):
/// the mirror of code `x` (negating every digit) is `C^g - 1 - x`.
/// Codes above the midpoint are "positive"; return (sign, half_index)
/// where `half_index ∈ 0..=mid` and sign is 1 for the negative half.
///
/// For ternary g=3 this reproduces paper Table 6 exactly: mid = 13,
/// (1,1,1) → (0, 13), (-1,-1,-1) → (1, 13), (0,0,0) → (0, 0).
pub fn mirror_split(code: usize, c: usize, g: usize) -> (u8, usize) {
    let full = code_count(c, g);
    let mid = (full - 1) / 2; // all-zero code for odd alphabets
    if code >= mid {
        (0, code - mid)
    } else {
        (1, mid - code)
    }
}

/// Inverse of [`mirror_split`].
pub fn mirror_join(sign: u8, half_index: usize, c: usize, g: usize) -> usize {
    let mid = (code_count(c, g) - 1) / 2;
    if sign == 0 {
        mid + half_index
    } else {
        mid - half_index
    }
}

/// The paper's 1-bit sign operation (Eq. 5): `x = sign ⊕ (sign + x)` with
/// the sign broadcast to an all-ones mask. Branch-free conditional negate,
/// exactly what `vpsignb`-less SIMD code does.
#[inline(always)]
pub fn sign_apply_i16(x: i16, sign_bit: u8) -> i16 {
    let mask = -(sign_bit as i16); // 0 or -1 (all ones)
    (x.wrapping_add(mask)) ^ mask
}

/// Same trick on i32 accumulators.
#[inline(always)]
pub fn sign_apply_i32(x: i32, sign_bit: u8) -> i32 {
    let mask = -(sign_bit as i32);
    (x.wrapping_add(mask)) ^ mask
}

/// Requantize an i16 LUT block to i8 with a single power-free scale —
/// the `_0` fast path (T-MAC-style table quantization, §3.2.1). Returns
/// the scale such that `i16 ≈ i8 * scale`.
pub fn requantize_lut_block(src: &[i16], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let max_abs = src.iter().fold(0i32, |m, &v| m.max((v as i32).abs()));
    if max_abs == 0 {
        dst.fill(0);
        return 0.0;
    }
    let scale = max_abs as f32 / 127.0;
    let inv = 127.0 / max_abs as f32;
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = ((s as f32) * inv).round() as i8;
    }
    scale
}

#[cfg(test)]
mod tests {
    use super::*;

    const TERNARY: [i8; 3] = [-1, 0, 1];

    #[test]
    fn code_counts() {
        assert_eq!(code_count(3, 2), 9);
        assert_eq!(code_count(3, 3), 27);
        assert_eq!(half_code_count(3, 3), 14); // 27/2+1 → 14 entries (0..=13)
        assert_eq!(code_count(4, 2), 16);
        assert_eq!(code_count(5, 2), 25);
        assert_eq!(half_code_count(5, 2), 13);
    }

    #[test]
    fn table3_bpw_values() {
        // Paper Table 3 rows: (C, g, bpw_bitwise, bpw_elementwise)
        assert_eq!(bitwise_bpw(3), 2.0);
        assert!((elementwise_bpw(3, 3) - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(bitwise_bpw(4), 2.0);
        assert_eq!(elementwise_bpw(4, 2), 2.0);
        assert_eq!(bitwise_bpw(5), 3.0);
        assert_eq!(elementwise_bpw(5, 2), 2.5);
    }

    #[test]
    fn codes_round_trip() {
        for code in 0..27 {
            let d = decode_code(code, 3, 3, &TERNARY);
            assert_eq!(encode_code(&d, 3, &TERNARY), code);
        }
    }

    #[test]
    fn mirror_matches_paper_table6() {
        // v = 9*(w0+1) + 3*(w1+1) + (w2+1) with digits in {-1,0,1}
        let code_of = |w: [i8; 3]| encode_code(&w, 3, &TERNARY);
        assert_eq!(mirror_split(code_of([0, 0, 0]), 3, 3), (0, 0));
        assert_eq!(mirror_split(code_of([1, 1, 1]), 3, 3), (0, 13));
        assert_eq!(mirror_split(code_of([-1, -1, -1]), 3, 3), (1, 13));
        assert_eq!(mirror_split(code_of([1, 1, -1]), 3, 3), (0, 11));
        assert_eq!(mirror_split(code_of([-1, -1, 1]), 3, 3), (1, 11));
    }

    #[test]
    fn mirror_split_join_round_trip() {
        for code in 0..27 {
            let (s, h) = mirror_split(code, 3, 3);
            assert_eq!(mirror_join(s, h, 3, 3), code);
            assert!(h <= 13);
        }
        for code in 0..25 {
            let (s, h) = mirror_split(code, 5, 2);
            assert_eq!(mirror_join(s, h, 5, 2), code);
        }
    }

    #[test]
    fn mirror_negates_digits() {
        // sign=1 half must decode to the negation of the sign=0 half.
        for h in 0..=13usize {
            let pos = decode_code(mirror_join(0, h, 3, 3), 3, 3, &TERNARY);
            let neg = decode_code(mirror_join(1, h, 3, 3), 3, 3, &TERNARY);
            for (p, n) in pos.iter().zip(neg.iter()) {
                assert_eq!(*p, -*n);
            }
        }
    }

    #[test]
    fn sign_op_equation5() {
        for x in [-300i16, -1, 0, 1, 5, 123, 300] {
            assert_eq!(sign_apply_i16(x, 0), x);
            assert_eq!(sign_apply_i16(x, 1), -x);
        }
        for x in [-100_000i32, -1, 0, 7, 100_000] {
            assert_eq!(sign_apply_i32(x, 0), x);
            assert_eq!(sign_apply_i32(x, 1), -x);
        }
    }

    #[test]
    fn lut_requantization_error_bounded() {
        let src: Vec<i16> = (-8..8).map(|i| (i * 37) as i16).collect();
        let mut dst = vec![0i8; src.len()];
        let scale = requantize_lut_block(&src, &mut dst);
        for (&s, &d) in src.iter().zip(dst.iter()) {
            let back = d as f32 * scale;
            assert!((back - s as f32).abs() <= scale * 0.5 + 1e-3);
        }
    }

    #[test]
    fn lut_requantization_zero_block() {
        let src = vec![0i16; 16];
        let mut dst = vec![0i8; 16];
        assert_eq!(requantize_lut_block(&src, &mut dst), 0.0);
        assert!(dst.iter().all(|&v| v == 0));
    }
}
