//! Shared quantization primitives.
//!
//! Three schemes appear throughout the paper:
//!
//! * **absmean ternary weight quantization** — BitNet b1.58 training
//!   scheme: one per-tensor scale `s = mean(|W|)`, weights
//!   `round(W/s)` clamped to {-1, 0, 1}.
//! * **per-tensor int8 activation quantization** — BitNet b1.58 training
//!   scheme: `s = 127 / max|x|`, `xq = clamp(round(x*s), -127..127)`.
//!   Kernels that preserve this exactly (I2_S, TL1_1, TL2_1) are lossless.
//! * **per-block activation quantization** (llama.cpp `Q8_K` with block
//!   length 256, `Q8_0` with block length 32) — what TQ1_0/TQ2_0/Q4_0/Q2_K
//!   consume. Using these *breaks* the training scheme, which is precisely
//!   the paper's argument for why llama.cpp kernels are not lossless.

use pallas_core::util::{f16_to_f32, f32_to_f16};

/// Ternary weight tensor in unpacked form: values in {-1, 0, 1} plus one
/// per-tensor scale. This is the canonical interchange between the model
/// layer and every kernel's packer.
#[derive(Clone, Debug)]
pub struct TernaryWeights {
    /// Row-major M×K values, each in {-1, 0, 1}, stored as i8.
    pub q: Vec<i8>,
    pub m: usize,
    pub k: usize,
    /// Per-tensor scale (the absmean `s`): `W ≈ q * scale`.
    pub scale: f32,
}

impl TernaryWeights {
    /// BitNet b1.58 absmean quantization of a dense f32 weight matrix.
    pub fn absmean_quantize(w: &[f32], m: usize, k: usize) -> TernaryWeights {
        assert_eq!(w.len(), m * k);
        let n = (m * k) as f64;
        let mean_abs = (w.iter().map(|v| v.abs() as f64).sum::<f64>() / n).max(1e-8) as f32;
        let inv = 1.0 / mean_abs;
        let q = w
            .iter()
            .map(|&v| (v * inv).round().clamp(-1.0, 1.0) as i8)
            .collect();
        TernaryWeights { q, m, k, scale: mean_abs }
    }

    /// Build directly from ternary values (used by the synthetic generator).
    pub fn from_ternary(q: Vec<i8>, m: usize, k: usize, scale: f32) -> TernaryWeights {
        assert_eq!(q.len(), m * k);
        debug_assert!(q.iter().all(|&v| (-1..=1).contains(&v)));
        TernaryWeights { q, m, k, scale }
    }

    /// Dequantize back to f32 (tests / Float16 baseline path).
    pub fn dequantize(&self) -> Vec<f32> {
        self.q.iter().map(|&v| v as f32 * self.scale).collect()
    }

    pub fn row(&self, r: usize) -> &[i8] {
        &self.q[r * self.k..(r + 1) * self.k]
    }
}

/// Per-tensor int8 activation quantization (BitNet b1.58 scheme).
#[derive(Clone, Debug)]
pub struct ActInt8 {
    pub q: Vec<i8>,
    /// `x ≈ q / scale`, i.e. scale = 127 / max|x|.
    pub scale: f32,
    /// Σ q — several kernels need the activation sum for offset correction.
    pub sum: i32,
}

/// Quantize activations with one per-tensor scale, exactly as BitNet b1.58
/// training does (round-half-away like `jnp.round`? No — BitNet uses
/// round-to-nearest; we use Rust `round` = half-away-from-zero and mirror
/// the same function on the Python side so the two stacks agree bit-for-bit).
pub fn quantize_act_int8(x: &[f32]) -> ActInt8 {
    let mut q = vec![0i8; x.len()];
    let (scale, sum) = quantize_act_int8_into(x, &mut q);
    ActInt8 { q, scale, sum }
}

/// Allocation-free [`quantize_act_int8`]: writes the quants into the
/// caller-owned `q` (same length as `x`) and returns `(scale, Σq)` —
/// bit-identical math to the allocating form (the lossless kernels
/// depend on it).
///
/// Dispatches to the AVX2/NEON rounding kernels when the active SIMD
/// level allows; those paths are bit-identical to the scalar loop for
/// finite inputs (`rust/tests/simd_identity.rs` covers the whole
/// prepare-then-gemv pipeline at every level).
pub fn quantize_act_int8_into(x: &[f32], q: &mut [i8]) -> (f32, i32) {
    assert_eq!(q.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_level() == super::simd::SimdLevel::Avx2 {
        // SAFETY: AVX2 verified by the active dispatch level; the
        // lengths were asserted equal above.
        return unsafe { super::simd::avx2::quantize_act_int8(x, q) };
    }
    #[cfg(target_arch = "aarch64")]
    if super::simd::active_level() == super::simd::SimdLevel::Neon {
        // SAFETY: NEON verified by the active dispatch level; the
        // lengths were asserted equal above.
        return unsafe { super::simd::neon::quantize_act_int8(x, q) };
    }
    quantize_act_int8_scalar(x, q)
}

/// The scalar reference body of [`quantize_act_int8_into`] — the
/// bit-identity anchor the vector paths are tested against.
fn quantize_act_int8_scalar(x: &[f32], q: &mut [i8]) -> (f32, i32) {
    let max_abs = x.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-5);
    let scale = 127.0 / max_abs;
    let mut sum = 0i32;
    for (qv, &v) in q.iter_mut().zip(x.iter()) {
        let t = (v * scale).round().clamp(-127.0, 127.0) as i8;
        *qv = t;
        sum += t as i32;
    }
    (scale, sum)
}

/// llama.cpp-style per-block int8 activations. Block length 256 (`Q8_K`)
/// for TQ1_0/TQ2_0/Q2_K, block length 32 (`Q8_0`) for Q4_0.
#[derive(Clone, Debug)]
pub struct ActBlocked {
    pub q: Vec<i8>,
    /// One dequant scale per block: `x ≈ q * d`.
    pub d: Vec<f32>,
    /// Per-block sums of q (used by offset-corrected kernels).
    pub bsums: Vec<i32>,
    pub block_len: usize,
}

/// Quantize activations into per-block int8 with the given block length.
/// `x.len()` must be a multiple of `block_len`.
pub fn quantize_act_blocked(x: &[f32], block_len: usize) -> ActBlocked {
    let n_blocks = x.len() / block_len.max(1);
    let mut q = vec![0i8; x.len()];
    let mut d = vec![0f32; n_blocks];
    let mut bsums = vec![0i32; n_blocks];
    quantize_act_blocked_into(x, block_len, &mut q, &mut d, &mut bsums);
    ActBlocked { q, d, bsums, block_len }
}

/// Allocation-free [`quantize_act_blocked`]: writes into caller-owned
/// buffers (which may hold stale data from a previous batch — every slot
/// is overwritten, including all-zero blocks).
pub fn quantize_act_blocked_into(
    x: &[f32],
    block_len: usize,
    q: &mut [i8],
    d: &mut [f32],
    bsums: &mut [i32],
) {
    assert!(block_len > 0 && x.len() % block_len == 0, "len {} % block {}", x.len(), block_len);
    let n_blocks = x.len() / block_len;
    assert_eq!(q.len(), x.len());
    assert_eq!(d.len(), n_blocks);
    assert_eq!(bsums.len(), n_blocks);
    for b in 0..n_blocks {
        let xs = &x[b * block_len..(b + 1) * block_len];
        let max_abs = xs.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        if max_abs == 0.0 {
            // All-zero block: clear explicitly (the buffer is reused).
            d[b] = 0.0;
            bsums[b] = 0;
            q[b * block_len..(b + 1) * block_len].fill(0);
            continue;
        }
        // Round-trip the scale through f16, as llama.cpp stores block scales
        // in f16 — part of why the blocked path is not lossless.
        let dv = f16_to_f32(f32_to_f16(max_abs / 127.0));
        d[b] = dv;
        let inv = 1.0 / dv;
        let mut sum = 0i32;
        for (i, &v) in xs.iter().enumerate() {
            let qv = (v * inv).round().clamp(-127.0, 127.0) as i8;
            q[b * block_len + i] = qv;
            sum += qv as i32;
        }
        bsums[b] = sum;
    }
}

/// The integer-exact "training scheme" reference result for one GEMV row:
/// `Σ xq[k] * wq[k]` with i64 accumulation, then the two scales applied.
/// Lossless kernels must reproduce this value *bit-for-bit* (see
/// rust/tests/lossless.rs).
pub fn training_scheme_ref_row(wq: &[i8], w_scale: f32, act: &ActInt8) -> f32 {
    assert_eq!(wq.len(), act.q.len());
    let mut acc = 0i64;
    for (&w, &a) in wq.iter().zip(act.q.iter()) {
        acc += (w as i64) * (a as i64);
    }
    // Apply the combined scale in one multiply — the same float-op order
    // every kernel uses, so "lossless" can be asserted with `==`.
    (acc as f32) * (w_scale / act.scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    #[test]
    fn absmean_reproduces_ternary_exactly() {
        // A weight matrix that is already ternary*scale must round-trip.
        let mut rng = Rng::new(1);
        let scale = 0.037f32;
        let q: Vec<i8> = (0..1024).map(|_| rng.next_ternary() as i8).collect();
        // absmean of |q*scale| = scale * (nonzero fraction); rounding W/s
        // with s = that mean still lands on the right trit only when the
        // ratio is within [0.5, 1.5] — with ~50% zeros the ratio is ~2.
        // So test with a ternary-friendly matrix: all-nonzero values.
        let qd: Vec<i8> = (0..1024).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let w: Vec<f32> = qd.iter().map(|&v| v as f32 * scale).collect();
        let t = TernaryWeights::absmean_quantize(&w, 32, 32);
        assert_eq!(t.q, qd);
        assert!((t.scale - scale).abs() < 1e-6);
        let _ = q;
    }

    #[test]
    fn absmean_clamps_to_unit() {
        let w = vec![10.0f32, -10.0, 0.0, 0.1];
        let t = TernaryWeights::absmean_quantize(&w, 1, 4);
        assert!(t.q.iter().all(|&v| (-1..=1).contains(&v)));
        assert_eq!(t.q[0], 1);
        assert_eq!(t.q[1], -1);
        assert_eq!(t.q[2], 0);
    }

    #[test]
    fn act_int8_round_trip_error_bounded() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..512).map(|_| rng.next_gaussian()).collect();
        let a = quantize_act_int8(&x);
        let max_abs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let step = max_abs / 127.0;
        for (&xv, &qv) in x.iter().zip(a.q.iter()) {
            let back = qv as f32 / a.scale;
            assert!((back - xv).abs() <= 0.5 * step + 1e-6, "{xv} vs {back}");
        }
        assert_eq!(a.sum, a.q.iter().map(|&v| v as i32).sum::<i32>());
    }

    #[test]
    fn act_quant_vector_paths_match_scalar_bitwise() {
        use crate::kernels::simd::{self, SimdLevel};
        // A max of exactly 127.0 makes scale == 1.0, so the planted *.5
        // values are exact rounding ties — the inputs where a
        // nearest-even vector rounding would diverge from Rust's
        // half-away-from-zero `round`.
        let mut x = vec![127.0f32, -0.5, 0.5, 2.5, -2.5, 3.5, -3.5, 1.25, -126.5];
        let mut rng = Rng::new(11);
        x.extend((0..250).map(|_| rng.next_gaussian() * 20.0));
        let mut want = vec![0i8; x.len()];
        let want_meta =
            simd::with_level(SimdLevel::Scalar, || quantize_act_int8_into(&x, &mut want));
        for level in simd::available_levels() {
            let mut got = vec![0i8; x.len()];
            let got_meta = simd::with_level(level, || quantize_act_int8_into(&x, &mut got));
            assert_eq!(got_meta, want_meta, "scale/sum @ {}", level.name());
            assert_eq!(got, want, "quants @ {}", level.name());
        }
    }

    #[test]
    fn act_blocked_block_independence() {
        // Changing one block must not affect another block's quants.
        let mut x = vec![0.5f32; 512];
        let a1 = quantize_act_blocked(&x, 256);
        x[300] = 100.0;
        let a2 = quantize_act_blocked(&x, 256);
        assert_eq!(&a1.q[..256], &a2.q[..256], "block 0 unchanged");
        assert_ne!(&a1.q[256..], &a2.q[256..], "block 1 rescaled");
    }

    #[test]
    fn act_blocked_zero_block() {
        let x = vec![0.0f32; 256];
        let a = quantize_act_blocked(&x, 256);
        assert!(a.q.iter().all(|&v| v == 0));
        assert_eq!(a.d[0], 0.0);
    }

    #[test]
    fn blocked_vs_tensor_quant_disagree() {
        // The crux of the paper's lossless argument: per-block and
        // per-tensor quantization yield different integers when the
        // dynamic range varies across blocks.
        let mut rng = Rng::new(3);
        let mut x: Vec<f32> = (0..512).map(|_| rng.next_gaussian() * 0.1).collect();
        x[0] = 8.0; // spike in block 0 only
        let t = quantize_act_int8(&x);
        let b = quantize_act_blocked(&x, 256);
        // block 1 has small range: per-block uses finer scale than per-tensor
        let differs = (256..512).any(|i| {
            let tv = t.q[i] as f32 / t.scale;
            let bv = b.q[i] as f32 * b.d[1];
            (tv - bv).abs() > 1e-6
        });
        assert!(differs);
    }

    #[test]
    fn training_ref_is_integer_exact() {
        let wq = vec![1i8, -1, 0, 1];
        let act = ActInt8 { q: vec![100, 50, 25, -128i8 as i8], scale: 2.0, sum: 0 };
        let r = training_scheme_ref_row(&wq, 0.5, &act);
        // (100 - 50 + 0 - 128) * (0.5 / 2.0) = -78 * 0.25
        assert_eq!(r, -78.0 * 0.25);
    }
}
