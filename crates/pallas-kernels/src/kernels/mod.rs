//! The ternary mpGEMM library — the paper's core contribution (§3, Table 1)
//! plus every baseline the evaluation compares against (§4, Table 7).
//!
//! | kernel | class | unit | bpw | lossless |
//! |--------|-------|------|-----|----------|
//! | `TL1_0`/`TL1_1` | LUT  | element-wise | 2.00 | ✗ / ✓ |
//! | `TL2_0`/`TL2_1` | LUT  | element-wise | 1.67 | ✗ / ✓ |
//! | `I2_S`          | MAD  | element-wise | 2.00 | ✓ |
//! | `TMAC` (stand-in)| LUT | bit-wise     | 2.00 | ✗ |
//! | `TQ1_0`         | MAD  | element-wise | 1.69 | ✗ |
//! | `TQ2_0`         | MAD  | element-wise | 2.06 | ✗ |
//! | `Q4_0`          | MAD  | bit-wise     | 4.50 | ✗ |
//! | `Q2_K`          | MAD  | bit-wise     | 2.63 | ✗ |
//! | `F16`           | MAD  | —            | 16.0 | — (full-precision baseline) |
//! | `ELUT4`/`ELUT5` | LUT  | element-wise | 2.00/2.50 | ✗ (appendix A extension) |
//!
//! All kernels consume the same [`quant::TernaryWeights`] (or raw f32 for
//! the general-purpose baselines) and produce f32 outputs, so they are
//! interchangeable inside the model and the quality/speed harnesses.
//!
//! ## Two-phase mpGEMM (Algorithms 1–2)
//!
//! Every kernel splits into a **preprocessing** phase (activation
//! quantization + LUT construction) and an **accumulation** phase. Since
//! the prepare-once refactor the preprocessing artifact is first-class:
//!
//! * [`PreparedBatch`] holds all `n` activation rows of one matmul input,
//!   prepared in parallel into flat, reusable buffers
//!   ([`PreparedBatch::build`] recycles capacity across calls — decode
//!   steady state allocates nothing).
//! * [`PreparedActivations`] caches batches per [`QuantType`] for one
//!   layer input, so projections that share an input (wq/wk/wv, gate/up)
//!   pay preprocessing **once**, not once per projection.
//! * [`matmul_prepared`] runs accumulation as a single 2-D tiled
//!   fork/join over (activation rows × weight rows) instead of one
//!   fork/join barrier per activation row.

pub mod baselines;
pub mod counters;
pub mod elut;
pub mod i2s;
pub mod lut;
pub mod quant;
pub mod simd;
pub mod sparse;
pub mod tl1;
pub mod tl2;
pub mod tuner;

pub use simd::SimdLevel;
pub use tuner::{Dispatch, DispatchPlan, Role, TuningProfile};

use pallas_core::threadpool::ThreadPool;
use quant::{ActBlocked, ActInt8, TernaryWeights};

/// Every quantization type / kernel in the library (paper Table 1 +
/// baselines + appendix ELUT extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantType {
    /// f32 reference MAD path (stands in for llama.cpp Float32).
    F32,
    /// f16-stored weights, f32 MAD — the paper's "Float16" baseline.
    F16,
    /// llama.cpp Q4_0: 4-bit blocks of 32, general-purpose.
    Q40,
    /// llama.cpp Q2_K: 2-bit K-quants, multi-step dequant (§2.3).
    Q2K,
    /// llama.cpp TQ1_0: base-3 packed ternary, bpw 1.69, element-wise MAD.
    Tq10,
    /// llama.cpp TQ2_0: 2-bit ternary, bpw 2.06, element-wise MAD.
    Tq20,
    /// T-MAC style bit-wise LUT (2-bit, g=4, int8-requantized tables).
    Tmac,
    /// Paper TL1, int8-requantized LUT (fast, near-lossless).
    Tl10,
    /// Paper TL1, pack-and-unpack int16 LUT (lossless).
    Tl11,
    /// Paper TL2, mirror-consolidated g=3, int8 LUT (fast, bpw 1.67).
    Tl20,
    /// Paper TL2, int16 LUT (lossless, bpw 1.67).
    Tl21,
    /// Paper I2_S: element-wise MAD, per-tensor scales (lossless).
    I2S,
    /// Appendix ELUT with weight cardinality C=4 (alphabet ±1, ±3).
    Elut4,
    /// Appendix ELUT with weight cardinality C=5 (alphabet -2..2).
    Elut5,
}

/// Computational strategy (paper §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelClass {
    MadBased,
    LutBased,
}

/// Metadata describing a kernel (regenerates paper Table 1).
#[derive(Clone, Debug)]
pub struct KernelInfo {
    pub qtype: QuantType,
    /// Paper-facing name, e.g. "TL2_0".
    pub name: &'static str,
    pub class: KernelClass,
    /// Element-wise kernels exploit weight cardinality; bit-wise do not.
    pub element_wise: bool,
    /// Nominal bits per weight of the storage format.
    pub bpw: f64,
    /// Exactly reproduces the BitNet b1.58 training-scheme computation.
    pub lossless: bool,
    /// K must be a multiple of this for the kernel to apply.
    pub k_multiple: usize,
    /// Supports arbitrary ternary weights (false for general formats that
    /// merely *store* ternary models, e.g. Q4_0).
    pub ternary_native: bool,
}

impl QuantType {
    pub const ALL: [QuantType; 14] = [
        QuantType::F32,
        QuantType::F16,
        QuantType::Q40,
        QuantType::Q2K,
        QuantType::Tq10,
        QuantType::Tq20,
        QuantType::Tmac,
        QuantType::Tl10,
        QuantType::Tl11,
        QuantType::Tl20,
        QuantType::Tl21,
        QuantType::I2S,
        QuantType::Elut4,
        QuantType::Elut5,
    ];

    /// The set the paper's Table 7 sweeps (ternary-relevant kernels).
    pub const TABLE7: [QuantType; 8] = [
        QuantType::F16,
        QuantType::Q40,
        QuantType::Tmac,
        QuantType::Tq10,
        QuantType::Tq20,
        QuantType::Tl10,
        QuantType::Tl20,
        QuantType::I2S,
    ];

    pub fn name(&self) -> &'static str {
        kernel_for(*self).info().name
    }

    pub fn parse(s: &str) -> Option<QuantType> {
        QuantType::ALL
            .iter()
            .copied()
            .find(|q| q.name().eq_ignore_ascii_case(s))
    }
}

/// Prepared (quantized / tabulated) activations for **one** row, owned —
/// the "preprocessing stage" artifact of Algorithms 1 and 2 in its
/// standalone form (single-row decode, tests, examples). The batched hot
/// path stores the same data flat in a [`PreparedBatch`] and hands
/// kernels borrowed [`PreparedRow`] views instead.
pub enum Prepared {
    /// No quantization (F32/F16 baselines). Owned copy; the batched path
    /// borrows the caller's row instead (see [`PreparedRow::Raw`]).
    Raw(Vec<f32>),
    /// Per-tensor int8 (BitNet training scheme).
    Int8(ActInt8),
    /// Per-block int8 (llama.cpp Q8_0 / Q8_K).
    Blocked(ActBlocked),
    /// Element-wise LUT, int16 entries (lossless TL path). `tables` holds
    /// `k/g` tables of 16 entries each; `scale` is the activation scale.
    LutI16 { tables: Vec<i16>, scale: f32 },
    /// Element-wise LUT requantized to int8 with one scale per k-block
    /// (fast TL path). `block_groups` = LUT groups per scale block.
    LutI8 { tables: Vec<i8>, block_scales: Vec<f32>, block_groups: usize, scale: f32 },
    /// Bit-wise LUT (T-MAC stand-in): int8 tables over 4-activation groups
    /// + per-block scales + activation sum for offset correction.
    BitLut { tables: Vec<i8>, block_scales: Vec<f32>, block_groups: usize, scale: f32, act_sum: i32 },
}

impl Prepared {
    /// Borrowed view of this prepared row — what [`Kernel::gemv_rows`]
    /// consumes (the batched path produces these without owning copies).
    pub fn as_row(&self) -> PreparedRow<'_> {
        match self {
            Prepared::Raw(x) => PreparedRow::Raw(x),
            Prepared::Int8(a) => PreparedRow::Int8 { q: &a.q, scale: a.scale, sum: a.sum },
            Prepared::Blocked(a) => {
                PreparedRow::Blocked { q: &a.q, d: &a.d, bsums: &a.bsums, block_len: a.block_len }
            }
            Prepared::LutI16 { tables, scale } => {
                PreparedRow::LutI16 { tables, scale: *scale }
            }
            Prepared::LutI8 { tables, block_scales, block_groups, scale } => PreparedRow::LutI8 {
                tables,
                block_scales,
                block_groups: *block_groups,
                scale: *scale,
            },
            Prepared::BitLut { tables, block_scales, block_groups, scale, act_sum } => {
                PreparedRow::BitLut {
                    tables,
                    block_scales,
                    block_groups: *block_groups,
                    scale: *scale,
                    act_sum: *act_sum,
                }
            }
        }
    }
}

/// Borrowed view of one prepared activation row — the accumulation-phase
/// input. The F32/F16 `Raw` case borrows the caller's activation slice
/// directly (no copy in the hot path).
#[derive(Clone, Copy)]
pub enum PreparedRow<'p> {
    /// Raw f32 activations (F32/F16 baselines).
    Raw(&'p [f32]),
    /// Per-tensor int8 quants + scale + Σq.
    Int8 { q: &'p [i8], scale: f32, sum: i32 },
    /// Per-block int8 quants with per-block dequant scales and sums.
    Blocked { q: &'p [i8], d: &'p [f32], bsums: &'p [i32], block_len: usize },
    /// Element-wise int16 LUT (lossless TL path).
    LutI16 { tables: &'p [i16], scale: f32 },
    /// Element-wise int8 LUT with per-block requantization scales.
    LutI8 { tables: &'p [i8], block_scales: &'p [f32], block_groups: usize, scale: f32 },
    /// Bit-wise int8 LUT (T-MAC) + activation sum for offset correction.
    BitLut { tables: &'p [i8], block_scales: &'p [f32], block_groups: usize, scale: f32, act_sum: i32 },
}

/// Mutable, preallocated destination for one row's preprocessing —
/// [`Kernel::prepare_row_into`] writes here instead of allocating. The
/// LUT variants carry scratch areas (`aq` for the quantized activations,
/// `tmp16` for pre-requantization tables) so no kernel needs a heap
/// allocation on the prepare path.
pub enum PreparedRowMut<'p> {
    /// F32/F16: nothing to store (accumulation borrows the raw row).
    Raw,
    /// Per-tensor int8 destination.
    Int8 { q: &'p mut [i8], scale: &'p mut f32, sum: &'p mut i32 },
    /// Per-block int8 destination.
    Blocked { q: &'p mut [i8], d: &'p mut [f32], bsums: &'p mut [i32] },
    /// int16 LUT destination (`aq` is scratch for the quantized row).
    LutI16 { aq: &'p mut [i8], tables: &'p mut [i16], scale: &'p mut f32 },
    /// int8 LUT destination (`tmp16` is scratch for the int16 tables
    /// before requantization).
    LutI8 {
        aq: &'p mut [i8],
        tmp16: &'p mut [i16],
        tables: &'p mut [i8],
        block_scales: &'p mut [f32],
        scale: &'p mut f32,
    },
    /// Bit-wise LUT destination (T-MAC).
    BitLut {
        aq: &'p mut [i8],
        tmp16: &'p mut [i16],
        tables: &'p mut [i8],
        block_scales: &'p mut [f32],
        scale: &'p mut f32,
        act_sum: &'p mut i32,
    },
}

/// The shape class of a kernel's preprocessing artifact for a given K —
/// what sizes the reusable [`PreparedBatch`] buffers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrepareKind {
    /// No storage (F32/F16 borrow the raw row).
    Raw,
    /// Per-tensor int8: k quants + scale + sum per row.
    Int8,
    /// Per-block int8: k quants + k/block_len scales/sums per row.
    Blocked { block_len: usize },
    /// int16 LUT: `groups` tables of [`tl1::LUT_W`] entries per row.
    LutI16 { groups: usize },
    /// int8 LUT: as `LutI16` plus ⌈groups/block_groups⌉ block scales.
    LutI8 { groups: usize, block_groups: usize },
    /// Bit-wise int8 LUT (T-MAC): as `LutI8` plus the activation sum.
    BitLut { groups: usize, block_groups: usize },
}

/// A packed weight tensor in some kernel's storage format.
pub struct QTensor {
    pub qtype: QuantType,
    pub m: usize,
    pub k: usize,
    /// Packed bytes, layout private to the kernel (row-major by weight row).
    pub data: Vec<u8>,
    /// Per-tensor weight scale (absmean `s`), where applicable.
    pub scale: f32,
    /// Block-skip layout for sparsity-aware elision: present when the
    /// kernel measured enough zero blocks at pack time (or the mode
    /// forced it). The dense packed bytes above are unchanged; kernels
    /// that understand the index elide zero blocks in `gemv_rows`,
    /// everything else (dequantize, dense consumers) ignores it.
    pub sparse: Option<sparse::SparseIndex>,
}

impl QTensor {
    /// Achieved bits per weight of this packed tensor (regenerates the bpw
    /// column of Table 1 / Table 3 from real storage, not constants).
    pub fn bits_per_weight(&self) -> f64 {
        (self.data.len() as f64 * 8.0) / (self.m * self.k) as f64
    }

    /// Bytes that one GEMV must read from the weight side.
    pub fn weight_bytes(&self) -> usize {
        self.data.len()
    }

    /// NUMA-localize the packed bytes: rebuild `data` so each node's row
    /// share ([`pallas_core::topology::Topology::row_ranges`], the same
    /// split [`matmul_prepared`] routes by) is first-touched — and thus
    /// physically backed — by that node. The bytes are copied verbatim,
    /// so every kernel reads exactly the values it packed; no-op on
    /// single-node pools, rowless tensors, or layouts whose packed bytes
    /// don't divide evenly by row (none of ours today).
    pub fn numa_localize(&mut self, pool: &ThreadPool) {
        let n_nodes = pool.n_nodes();
        if n_nodes <= 1 || self.m == 0 || self.data.is_empty() || self.data.len() % self.m != 0 {
            return;
        }
        let row_bytes = self.data.len() / self.m;
        let mut fresh: Vec<u8> = Vec::with_capacity(self.data.len());
        let dst = SendMut(fresh.as_mut_ptr());
        let src = &self.data;
        for (node, r) in pool.topology().row_ranges(self.m).iter().enumerate() {
            let lo = r.start * row_bytes;
            let hi = r.end * row_bytes;
            if lo == hi {
                continue;
            }
            pool.run_on_node(node, || {
                let dst = &dst;
                // SAFETY: `dst` points into `fresh`'s reserved (uninit)
                // capacity of `data.len()` bytes; each node writes the
                // disjoint `lo..hi` range, and `run_on_node` completes
                // before `fresh` is touched again or dropped.
                unsafe {
                    std::ptr::copy_nonoverlapping(src.as_ptr().add(lo), dst.0.add(lo), hi - lo);
                }
            });
        }
        // SAFETY: the loop above wrote every byte of `0..data.len()` —
        // the row ranges tile `0..m` exactly — so the buffer is fully
        // initialized.
        unsafe {
            fresh.set_len(self.data.len());
        }
        self.data = fresh;
    }
}

/// The kernel interface. One implementation per [`QuantType`].
pub trait Kernel: Send + Sync {
    fn info(&self) -> KernelInfo;

    /// Pack ternary weights into this kernel's storage format.
    fn quantize(&self, w: &TernaryWeights) -> QTensor;

    /// Reconstruct effective f32 weights (tests, quality eval).
    fn dequantize(&self, t: &QTensor) -> Vec<f32>;

    /// The preprocessing artifact shape for reduction dim `k` — drives
    /// [`PreparedBatch`] buffer sizing.
    fn prepare_kind(&self, k: usize) -> PrepareKind;

    /// Quantize activations and (for LUT kernels) build lookup tables —
    /// Algorithm 1/2 "preprocessing" phase — writing into caller-owned
    /// storage (`dst` matches [`Kernel::prepare_kind`]). Performs no heap
    /// allocation. `x.len() == k`.
    fn prepare_row_into(&self, x: &[f32], k: usize, dst: PreparedRowMut<'_>);

    /// Standalone (allocating) preprocessing of one row. Convenience for
    /// tests and single-row paths; the batched hot path goes through
    /// [`PreparedBatch::build`] instead.
    fn prepare(&self, x: &[f32], k: usize) -> Prepared {
        assert_eq!(x.len(), k);
        match self.prepare_kind(k) {
            PrepareKind::Raw => Prepared::Raw(x.to_vec()),
            PrepareKind::Int8 => {
                let mut q = vec![0i8; k];
                let (mut scale, mut sum) = (0f32, 0i32);
                self.prepare_row_into(
                    x,
                    k,
                    PreparedRowMut::Int8 { q: &mut q, scale: &mut scale, sum: &mut sum },
                );
                Prepared::Int8(ActInt8 { q, scale, sum })
            }
            PrepareKind::Blocked { block_len } => {
                let blocks = k / block_len;
                let mut q = vec![0i8; k];
                let mut d = vec![0f32; blocks];
                let mut bsums = vec![0i32; blocks];
                self.prepare_row_into(
                    x,
                    k,
                    PreparedRowMut::Blocked { q: &mut q, d: &mut d, bsums: &mut bsums },
                );
                Prepared::Blocked(ActBlocked { q, d, bsums, block_len })
            }
            PrepareKind::LutI16 { groups } => {
                let mut aq = vec![0i8; k];
                let mut tables = vec![0i16; groups * tl1::LUT_W];
                let mut scale = 0f32;
                self.prepare_row_into(
                    x,
                    k,
                    PreparedRowMut::LutI16 { aq: &mut aq, tables: &mut tables, scale: &mut scale },
                );
                Prepared::LutI16 { tables, scale }
            }
            PrepareKind::LutI8 { groups, block_groups } => {
                let mut aq = vec![0i8; k];
                let mut tmp16 = vec![0i16; groups * tl1::LUT_W];
                let mut tables = vec![0i8; groups * tl1::LUT_W];
                let mut block_scales = vec![0f32; pallas_core::util::ceil_div(groups, block_groups)];
                let mut scale = 0f32;
                self.prepare_row_into(
                    x,
                    k,
                    PreparedRowMut::LutI8 {
                        aq: &mut aq,
                        tmp16: &mut tmp16,
                        tables: &mut tables,
                        block_scales: &mut block_scales,
                        scale: &mut scale,
                    },
                );
                Prepared::LutI8 { tables, block_scales, block_groups, scale }
            }
            PrepareKind::BitLut { groups, block_groups } => {
                let mut aq = vec![0i8; k];
                let mut tmp16 = vec![0i16; groups * tl1::LUT_W];
                let mut tables = vec![0i8; groups * tl1::LUT_W];
                let mut block_scales = vec![0f32; pallas_core::util::ceil_div(groups, block_groups)];
                let mut scale = 0f32;
                let mut act_sum = 0i32;
                self.prepare_row_into(
                    x,
                    k,
                    PreparedRowMut::BitLut {
                        aq: &mut aq,
                        tmp16: &mut tmp16,
                        tables: &mut tables,
                        block_scales: &mut block_scales,
                        scale: &mut scale,
                        act_sum: &mut act_sum,
                    },
                );
                Prepared::BitLut { tables, block_scales, block_groups, scale, act_sum }
            }
        }
    }

    /// The SIMD tiers this kernel has explicit implementations for on
    /// the compile target. Scalar-only by default; the vectorized
    /// kernels (TL1/TL2/I2_S/ELUT) override with [`simd::KERNEL_LEVELS`].
    /// The tuner measures each tier in here that the host can run.
    fn simd_levels(&self) -> &'static [SimdLevel] {
        const SCALAR_ONLY: &[SimdLevel] = &[SimdLevel::Scalar];
        SCALAR_ONLY
    }

    /// Whether this kernel can emit (and elide through) the block-skip
    /// sparse layout at pack time. The ternary LUT/I2_S kernels
    /// override to `true`; the tuner only measures the sparse axis for
    /// kernels that report it.
    fn sparse_capable(&self) -> bool {
        false
    }

    /// Compute `out[r] = Σ_k x[k] * W[r,k]` for `r` in `rows` —
    /// Algorithm 1/2 "accumulation" phase.
    fn gemv_rows(&self, t: &QTensor, p: PreparedRow<'_>, out: &mut [f32], rows: std::ops::Range<usize>);

    /// Full single-row GEMV.
    fn gemv(&self, t: &QTensor, p: &Prepared, out: &mut [f32]) {
        assert_eq!(out.len(), t.m);
        self.gemv_rows(t, p.as_row(), out, 0..t.m);
    }
}

/// Look up the kernel implementation for a quant type.
pub fn kernel_for(q: QuantType) -> &'static dyn Kernel {
    match q {
        QuantType::F32 => &baselines::f32_mad::F32Kernel,
        QuantType::F16 => &baselines::f16_mad::F16Kernel,
        QuantType::Q40 => &baselines::q4_0::Q40Kernel,
        QuantType::Q2K => &baselines::q2_k::Q2KKernel,
        QuantType::Tq10 => &baselines::tq1_0::Tq10Kernel,
        QuantType::Tq20 => &baselines::tq2_0::Tq20Kernel,
        QuantType::Tmac => &baselines::tmac::TmacKernel,
        QuantType::Tl10 => &tl1::TL1_0,
        QuantType::Tl11 => &tl1::TL1_1,
        QuantType::Tl20 => &tl2::TL2_0,
        QuantType::Tl21 => &tl2::TL2_1,
        QuantType::I2S => &i2s::I2SKernel,
        QuantType::Elut4 => &elut::ELUT4,
        QuantType::Elut5 => &elut::ELUT5,
    }
}

/// All kernel infos (regenerates paper Table 1).
pub fn library_table() -> Vec<KernelInfo> {
    QuantType::ALL.iter().map(|&q| kernel_for(q).info()).collect()
}

// ---------------------------------------------------------------------------
// Batched preprocessing: flat per-batch storage + per-input cache
// ---------------------------------------------------------------------------

/// All `n` activation rows of one matmul input, preprocessed into flat
/// recyclable buffers. Built in parallel by [`PreparedBatch::build`];
/// [`PreparedBatch::row`] hands out borrowed [`PreparedRow`] views for
/// the accumulation phase. Rebuilding with the same shape class reuses
/// every buffer (zero heap allocation in steady state).
pub struct PreparedBatch {
    qtype: QuantType,
    k: usize,
    n: usize,
    kind: BatchKind,
}

enum BatchKind {
    /// Never built.
    Empty,
    /// F32/F16: rows are borrowed from the caller's activations.
    Raw,
    Int8 {
        q: Vec<i8>,
        scales: Vec<f32>,
        sums: Vec<i32>,
    },
    Blocked {
        q: Vec<i8>,
        d: Vec<f32>,
        bsums: Vec<i32>,
        block_len: usize,
    },
    LutI16 {
        aq: Vec<i8>,
        tables: Vec<i16>,
        scales: Vec<f32>,
        stride: usize,
    },
    LutI8 {
        aq: Vec<i8>,
        tmp16: Vec<i16>,
        tables: Vec<i8>,
        block_scales: Vec<f32>,
        scales: Vec<f32>,
        stride: usize,
        sblocks: usize,
        block_groups: usize,
    },
    BitLut {
        aq: Vec<i8>,
        tmp16: Vec<i16>,
        tables: Vec<i8>,
        block_scales: Vec<f32>,
        scales: Vec<f32>,
        act_sums: Vec<i32>,
        stride: usize,
        sblocks: usize,
        block_groups: usize,
    },
}

/// Resize to `len` preserving capacity where possible; counts a fresh
/// allocation when capacity must grow. Existing contents are left in
/// place (every consumer fully overwrites its region during
/// `prepare_row_into`), so the steady-state rebuild writes nothing here
/// — no redundant memset in the hot path.
fn ensure_len<T: Copy + Default>(v: &mut Vec<T>, len: usize, allocs: &mut u64) {
    if v.capacity() < len {
        *allocs += 1;
    }
    v.resize(len, T::default());
}

impl PreparedBatch {
    /// An empty batch (no buffers yet); [`PreparedBatch::build`] sizes it.
    pub fn new() -> PreparedBatch {
        PreparedBatch { qtype: QuantType::F32, k: 0, n: 0, kind: BatchKind::Empty }
    }

    /// The kernel this batch was prepared for.
    pub fn qtype(&self) -> QuantType {
        self.qtype
    }

    /// Activation rows held.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Reduction dimension.
    pub fn k(&self) -> usize {
        self.k
    }

    /// (Re)build this batch for `kernel` over the `n`×`k` activations
    /// `x`, preparing rows in parallel on `pool`. Buffers are reused
    /// whenever the shape class matches; returns the number of fresh
    /// buffer allocations (0 in steady state).
    pub fn build(
        &mut self,
        kernel: &dyn Kernel,
        x: &[f32],
        k: usize,
        n: usize,
        pool: &ThreadPool,
    ) -> u64 {
        assert_eq!(x.len(), n * k);
        let mut allocs = 0u64;
        // Row chunks double as the scratch-region count: chunk c owns
        // scratch region c (aq/tmp16), so scratch scales with the worker
        // count, not with n.
        let chunks = (pool.size() * 2).min(n).max(1);
        self.ensure_kind(kernel.prepare_kind(k), k, n, chunks, &mut allocs);
        self.qtype = kernel.info().qtype;
        self.k = k;
        self.n = n;
        if n == 0 {
            return allocs;
        }
        let rows_per = pallas_core::util::ceil_div(n, chunks);
        match &mut self.kind {
            BatchKind::Empty => unreachable!("ensure_kind materializes a kind"),
            BatchKind::Raw => {}
            BatchKind::Int8 { q, scales, sums } => {
                let qp = SendMut(q.as_mut_ptr());
                let sp = SendMut(scales.as_mut_ptr());
                let up = SendMut(sums.as_mut_ptr());
                pool.parallel_for(chunks, |c| {
                    let (qp, sp, up) = (&qp, &sp, &up);
                    let lo = c * rows_per;
                    if lo >= n {
                        return;
                    }
                    let hi = ((c + 1) * rows_per).min(n);
                    for i in lo..hi {
                        // SAFETY: each row i writes disjoint ranges.
                        let q = unsafe { std::slice::from_raw_parts_mut(qp.0.add(i * k), k) };
                        // SAFETY: as above.
                        let scale = unsafe { &mut *sp.0.add(i) };
                        // SAFETY: as above.
                        let sum = unsafe { &mut *up.0.add(i) };
                        kernel.prepare_row_into(
                            &x[i * k..(i + 1) * k],
                            k,
                            PreparedRowMut::Int8 { q, scale, sum },
                        );
                    }
                });
            }
            BatchKind::Blocked { q, d, bsums, block_len } => {
                let nb = k / *block_len;
                let qp = SendMut(q.as_mut_ptr());
                let dp = SendMut(d.as_mut_ptr());
                let bp = SendMut(bsums.as_mut_ptr());
                pool.parallel_for(chunks, |c| {
                    let (qp, dp, bp) = (&qp, &dp, &bp);
                    let lo = c * rows_per;
                    if lo >= n {
                        return;
                    }
                    let hi = ((c + 1) * rows_per).min(n);
                    for i in lo..hi {
                        // SAFETY: each row i writes disjoint ranges.
                        let q = unsafe { std::slice::from_raw_parts_mut(qp.0.add(i * k), k) };
                        // SAFETY: as above.
                        let d = unsafe { std::slice::from_raw_parts_mut(dp.0.add(i * nb), nb) };
                        // SAFETY: as above.
                        let bsums =
                            unsafe { std::slice::from_raw_parts_mut(bp.0.add(i * nb), nb) };
                        kernel.prepare_row_into(
                            &x[i * k..(i + 1) * k],
                            k,
                            PreparedRowMut::Blocked { q, d, bsums },
                        );
                    }
                });
            }
            BatchKind::LutI16 { aq, tables, scales, stride } => {
                let stride = *stride;
                let ap = SendMut(aq.as_mut_ptr());
                let tp = SendMut(tables.as_mut_ptr());
                let sp = SendMut(scales.as_mut_ptr());
                pool.parallel_for(chunks, |c| {
                    let (ap, tp, sp) = (&ap, &tp, &sp);
                    let lo = c * rows_per;
                    if lo >= n {
                        return;
                    }
                    let hi = ((c + 1) * rows_per).min(n);
                    for i in lo..hi {
                        // SAFETY: each row i writes disjoint output ranges;
                        // scratch region c belongs to this chunk alone.
                        let aq = unsafe { std::slice::from_raw_parts_mut(ap.0.add(c * k), k) };
                        // SAFETY: as above.
                        let tables = unsafe {
                            std::slice::from_raw_parts_mut(tp.0.add(i * stride), stride)
                        };
                        // SAFETY: as above.
                        let scale = unsafe { &mut *sp.0.add(i) };
                        kernel.prepare_row_into(
                            &x[i * k..(i + 1) * k],
                            k,
                            PreparedRowMut::LutI16 { aq, tables, scale },
                        );
                    }
                });
            }
            BatchKind::LutI8 { aq, tmp16, tables, block_scales, scales, stride, sblocks, .. } => {
                let (stride, sblocks) = (*stride, *sblocks);
                let ap = SendMut(aq.as_mut_ptr());
                let mp = SendMut(tmp16.as_mut_ptr());
                let tp = SendMut(tables.as_mut_ptr());
                let bp = SendMut(block_scales.as_mut_ptr());
                let sp = SendMut(scales.as_mut_ptr());
                pool.parallel_for(chunks, |c| {
                    let (ap, mp, tp, bp, sp) = (&ap, &mp, &tp, &bp, &sp);
                    let lo = c * rows_per;
                    if lo >= n {
                        return;
                    }
                    let hi = ((c + 1) * rows_per).min(n);
                    for i in lo..hi {
                        // SAFETY: each row i writes disjoint output ranges;
                        // scratch region c belongs to this chunk alone.
                        let aq = unsafe { std::slice::from_raw_parts_mut(ap.0.add(c * k), k) };
                        // SAFETY: as above.
                        let tmp16 = unsafe {
                            std::slice::from_raw_parts_mut(mp.0.add(c * stride), stride)
                        };
                        // SAFETY: as above.
                        let tables = unsafe {
                            std::slice::from_raw_parts_mut(tp.0.add(i * stride), stride)
                        };
                        // SAFETY: as above.
                        let block_scales = unsafe {
                            std::slice::from_raw_parts_mut(bp.0.add(i * sblocks), sblocks)
                        };
                        // SAFETY: as above.
                        let scale = unsafe { &mut *sp.0.add(i) };
                        kernel.prepare_row_into(
                            &x[i * k..(i + 1) * k],
                            k,
                            PreparedRowMut::LutI8 { aq, tmp16, tables, block_scales, scale },
                        );
                    }
                });
            }
            BatchKind::BitLut {
                aq,
                tmp16,
                tables,
                block_scales,
                scales,
                act_sums,
                stride,
                sblocks,
                ..
            } => {
                let (stride, sblocks) = (*stride, *sblocks);
                let ap = SendMut(aq.as_mut_ptr());
                let mp = SendMut(tmp16.as_mut_ptr());
                let tp = SendMut(tables.as_mut_ptr());
                let bp = SendMut(block_scales.as_mut_ptr());
                let sp = SendMut(scales.as_mut_ptr());
                let up = SendMut(act_sums.as_mut_ptr());
                pool.parallel_for(chunks, |c| {
                    let (ap, mp, tp, bp, sp, up) = (&ap, &mp, &tp, &bp, &sp, &up);
                    let lo = c * rows_per;
                    if lo >= n {
                        return;
                    }
                    let hi = ((c + 1) * rows_per).min(n);
                    for i in lo..hi {
                        // SAFETY: each row i writes disjoint output ranges;
                        // scratch region c belongs to this chunk alone.
                        let aq = unsafe { std::slice::from_raw_parts_mut(ap.0.add(c * k), k) };
                        // SAFETY: as above.
                        let tmp16 = unsafe {
                            std::slice::from_raw_parts_mut(mp.0.add(c * stride), stride)
                        };
                        // SAFETY: as above.
                        let tables = unsafe {
                            std::slice::from_raw_parts_mut(tp.0.add(i * stride), stride)
                        };
                        // SAFETY: as above.
                        let block_scales = unsafe {
                            std::slice::from_raw_parts_mut(bp.0.add(i * sblocks), sblocks)
                        };
                        // SAFETY: as above.
                        let scale = unsafe { &mut *sp.0.add(i) };
                        // SAFETY: as above.
                        let act_sum = unsafe { &mut *up.0.add(i) };
                        kernel.prepare_row_into(
                            &x[i * k..(i + 1) * k],
                            k,
                            PreparedRowMut::BitLut {
                                aq,
                                tmp16,
                                tables,
                                block_scales,
                                scale,
                                act_sum,
                            },
                        );
                    }
                });
            }
        }
        allocs
    }

    /// Switch/resize the storage to `want`, reusing buffers when the
    /// shape class matches. `scratch_rows` is the number of concurrent
    /// build chunks — per-row scratch (`aq`, `tmp16`) is sized by it, not
    /// by `n`, so transient workspace stays O(threads) after a long
    /// prefill chunk.
    fn ensure_kind(
        &mut self,
        want: PrepareKind,
        k: usize,
        n: usize,
        scratch_rows: usize,
        allocs: &mut u64,
    ) {
        match want {
            PrepareKind::Raw => {
                if !matches!(self.kind, BatchKind::Raw) {
                    self.kind = BatchKind::Raw;
                }
            }
            PrepareKind::Int8 => {
                if !matches!(self.kind, BatchKind::Int8 { .. }) {
                    *allocs += 1;
                    self.kind =
                        BatchKind::Int8 { q: Vec::new(), scales: Vec::new(), sums: Vec::new() };
                }
                if let BatchKind::Int8 { q, scales, sums } = &mut self.kind {
                    ensure_len(q, n * k, allocs);
                    ensure_len(scales, n, allocs);
                    ensure_len(sums, n, allocs);
                }
            }
            PrepareKind::Blocked { block_len } => {
                if !matches!(&self.kind, BatchKind::Blocked { block_len: bl, .. } if *bl == block_len)
                {
                    *allocs += 1;
                    self.kind = BatchKind::Blocked {
                        q: Vec::new(),
                        d: Vec::new(),
                        bsums: Vec::new(),
                        block_len,
                    };
                }
                let nb = n * (k / block_len);
                if let BatchKind::Blocked { q, d, bsums, .. } = &mut self.kind {
                    ensure_len(q, n * k, allocs);
                    ensure_len(d, nb, allocs);
                    ensure_len(bsums, nb, allocs);
                }
            }
            PrepareKind::LutI16 { groups } => {
                let stride = groups * tl1::LUT_W;
                if !matches!(self.kind, BatchKind::LutI16 { .. }) {
                    *allocs += 1;
                    self.kind = BatchKind::LutI16 {
                        aq: Vec::new(),
                        tables: Vec::new(),
                        scales: Vec::new(),
                        stride,
                    };
                }
                if let BatchKind::LutI16 { aq, tables, scales, stride: s } = &mut self.kind {
                    *s = stride;
                    ensure_len(aq, scratch_rows * k, allocs);
                    ensure_len(tables, n * stride, allocs);
                    ensure_len(scales, n, allocs);
                }
            }
            PrepareKind::LutI8 { groups, block_groups } => {
                let stride = groups * tl1::LUT_W;
                let sblocks = pallas_core::util::ceil_div(groups, block_groups);
                if !matches!(&self.kind, BatchKind::LutI8 { block_groups: bg, .. } if *bg == block_groups)
                {
                    *allocs += 1;
                    self.kind = BatchKind::LutI8 {
                        aq: Vec::new(),
                        tmp16: Vec::new(),
                        tables: Vec::new(),
                        block_scales: Vec::new(),
                        scales: Vec::new(),
                        stride,
                        sblocks,
                        block_groups,
                    };
                }
                if let BatchKind::LutI8 {
                    aq,
                    tmp16,
                    tables,
                    block_scales,
                    scales,
                    stride: st,
                    sblocks: sb,
                    ..
                } = &mut self.kind
                {
                    *st = stride;
                    *sb = sblocks;
                    ensure_len(aq, scratch_rows * k, allocs);
                    ensure_len(tmp16, scratch_rows * stride, allocs);
                    ensure_len(tables, n * stride, allocs);
                    ensure_len(block_scales, n * sblocks, allocs);
                    ensure_len(scales, n, allocs);
                }
            }
            PrepareKind::BitLut { groups, block_groups } => {
                let stride = groups * tl1::LUT_W;
                let sblocks = pallas_core::util::ceil_div(groups, block_groups);
                if !matches!(&self.kind, BatchKind::BitLut { block_groups: bg, .. } if *bg == block_groups)
                {
                    *allocs += 1;
                    self.kind = BatchKind::BitLut {
                        aq: Vec::new(),
                        tmp16: Vec::new(),
                        tables: Vec::new(),
                        block_scales: Vec::new(),
                        scales: Vec::new(),
                        act_sums: Vec::new(),
                        stride,
                        sblocks,
                        block_groups,
                    };
                }
                if let BatchKind::BitLut {
                    aq,
                    tmp16,
                    tables,
                    block_scales,
                    scales,
                    act_sums,
                    stride: st,
                    sblocks: sb,
                    ..
                } = &mut self.kind
                {
                    *st = stride;
                    *sb = sblocks;
                    ensure_len(aq, scratch_rows * k, allocs);
                    ensure_len(tmp16, scratch_rows * stride, allocs);
                    ensure_len(tables, n * stride, allocs);
                    ensure_len(block_scales, n * sblocks, allocs);
                    ensure_len(scales, n, allocs);
                    ensure_len(act_sums, n, allocs);
                }
            }
        }
    }

    /// Borrowed view of prepared row `i`. `x` must be the activation
    /// matrix the batch was built from (the Raw kind borrows its rows).
    pub fn row<'p>(&'p self, i: usize, x: &'p [f32]) -> PreparedRow<'p> {
        assert!(i < self.n, "row {i} out of {n}", n = self.n);
        let k = self.k;
        match &self.kind {
            BatchKind::Empty => panic!("PreparedBatch::row before build"),
            BatchKind::Raw => PreparedRow::Raw(&x[i * k..(i + 1) * k]),
            BatchKind::Int8 { q, scales, sums } => PreparedRow::Int8 {
                q: &q[i * k..(i + 1) * k],
                scale: scales[i],
                sum: sums[i],
            },
            BatchKind::Blocked { q, d, bsums, block_len } => {
                let nb = k / block_len;
                PreparedRow::Blocked {
                    q: &q[i * k..(i + 1) * k],
                    d: &d[i * nb..(i + 1) * nb],
                    bsums: &bsums[i * nb..(i + 1) * nb],
                    block_len: *block_len,
                }
            }
            BatchKind::LutI16 { tables, scales, stride, .. } => PreparedRow::LutI16 {
                tables: &tables[i * stride..(i + 1) * stride],
                scale: scales[i],
            },
            BatchKind::LutI8 { tables, block_scales, scales, stride, sblocks, block_groups, .. } => {
                PreparedRow::LutI8 {
                    tables: &tables[i * stride..(i + 1) * stride],
                    block_scales: &block_scales[i * sblocks..(i + 1) * sblocks],
                    block_groups: *block_groups,
                    scale: scales[i],
                }
            }
            BatchKind::BitLut {
                tables,
                block_scales,
                scales,
                act_sums,
                stride,
                sblocks,
                block_groups,
                ..
            } => PreparedRow::BitLut {
                tables: &tables[i * stride..(i + 1) * stride],
                block_scales: &block_scales[i * sblocks..(i + 1) * sblocks],
                block_groups: *block_groups,
                scale: scales[i],
                act_sum: act_sums[i],
            },
        }
    }
}

impl Default for PreparedBatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Prepare-cache counters (cumulative; snapshot via
/// [`PreparedActivations::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrepareStats {
    /// Requests served from an already-prepared batch (a projection
    /// sharing its input with an earlier one, e.g. wk/wv after wq).
    pub hits: u64,
    /// Requests that ran preprocessing (once per input × kernel).
    pub misses: u64,
    /// Fresh buffer allocations across all builds (0 growth = steady
    /// state is allocation-free).
    pub buffer_allocs: u64,
    /// Builds that fully reused existing buffer capacity.
    pub buffer_reuses: u64,
}

struct ActSlot {
    qtype: QuantType,
    /// Generation the slot's batch was built for.
    generation: u64,
    built: bool,
    batch: PreparedBatch,
}

/// Per-input cache of [`PreparedBatch`]es, keyed by [`QuantType`] —
/// dispatch can pick different winners per role, so heterogeneous
/// packings coexist. Call [`PreparedActivations::begin_input`] once per
/// new layer input (e.g. the normed hidden state wq/wk/wv share), then
/// [`PreparedActivations::get_or_prepare`] from every consuming
/// projection: the first call prepares, the rest hit the cache. Slots
/// (and their buffers) persist across inputs, so decode steady state
/// performs zero heap allocations in the prepare path.
pub struct PreparedActivations {
    generation: u64,
    slots: Vec<ActSlot>,
    stats: PrepareStats,
}

impl PreparedActivations {
    pub fn new() -> PreparedActivations {
        PreparedActivations { generation: 0, slots: Vec::new(), stats: PrepareStats::default() }
    }

    /// Invalidate cached batches: the next `get_or_prepare` per kernel
    /// re-prepares (into the same buffers). Call once per layer input.
    pub fn begin_input(&mut self) {
        self.generation += 1;
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PrepareStats {
        self.stats
    }

    /// The prepared batch for `kernel` over the current input `x`
    /// (`n`×`k`), preparing it on first request since the last
    /// [`PreparedActivations::begin_input`].
    pub fn get_or_prepare(
        &mut self,
        kernel: &dyn Kernel,
        x: &[f32],
        k: usize,
        n: usize,
        pool: &ThreadPool,
    ) -> &PreparedBatch {
        let qtype = kernel.info().qtype;
        let idx = match self.slots.iter().position(|s| s.qtype == qtype) {
            Some(i) => i,
            None => {
                self.slots.push(ActSlot {
                    qtype,
                    generation: 0,
                    built: false,
                    batch: PreparedBatch::new(),
                });
                self.slots.len() - 1
            }
        };
        let generation = self.generation;
        let slot = &mut self.slots[idx];
        if slot.built && slot.generation == generation && slot.batch.k() == k && slot.batch.n() == n
        {
            self.stats.hits += 1;
        } else {
            let allocs = slot.batch.build(kernel, x, k, n, pool);
            slot.generation = generation;
            slot.built = true;
            self.stats.misses += 1;
            if allocs == 0 {
                self.stats.buffer_reuses += 1;
            } else {
                self.stats.buffer_allocs += allocs;
            }
        }
        &self.slots[idx].batch
    }
}

impl Default for PreparedActivations {
    fn default() -> Self {
        Self::new()
    }
}

/// Accumulation over an already-prepared batch: one 2-D tiled fork/join
/// over (activation-row chunks × weight-row chunks), so an n-row matmul
/// pays a single barrier instead of n. `x` must be the activation matrix
/// the batch was built from.
pub fn matmul_prepared(
    kernel: &dyn Kernel,
    t: &QTensor,
    batch: &PreparedBatch,
    x: &[f32],
    n: usize,
    out: &mut [f32],
    pool: &ThreadPool,
) {
    assert_eq!(batch.n(), n, "batch rows");
    assert_eq!(batch.k(), t.k, "batch K");
    assert_eq!(batch.qtype(), kernel.info().qtype, "batch kernel");
    assert_eq!(x.len(), n * t.k);
    assert_eq!(out.len(), n * t.m);
    let m = t.m;
    if n == 0 || m == 0 {
        return;
    }
    // Tile the (n × m) output: ~4 tiles per thread for load balance, with
    // activation-row tiles first (better weight reuse within a tile).
    let target = (pool.size() * 4).max(1);
    let a_tiles = n.min(target);
    let w_tiles = pallas_core::util::ceil_div(target, a_tiles).min(m).max(1);
    let rows_per_a = pallas_core::util::ceil_div(n, a_tiles);
    let rows_per_w = pallas_core::util::ceil_div(m, w_tiles);
    if pool.n_nodes() > 1 {
        return matmul_prepared_placed(kernel, t, batch, x, n, out, pool, a_tiles, w_tiles);
    }
    let out_ptr = SendPtr(out.as_mut_ptr());
    pool.parallel_for(a_tiles * w_tiles, |c| {
        // Capture the whole wrapper (edition-2021 closures would
        // otherwise capture the raw-pointer field, which is !Sync).
        let out_ptr = &out_ptr;
        let ai = c / w_tiles;
        let wi = c % w_tiles;
        let a_lo = ai * rows_per_a;
        let w_lo = wi * rows_per_w;
        if a_lo >= n || w_lo >= m {
            return;
        }
        let a_hi = ((ai + 1) * rows_per_a).min(n);
        let w_hi = ((wi + 1) * rows_per_w).min(m);
        for i in a_lo..a_hi {
            let row = batch.row(i, x);
            // SAFETY: tiles write disjoint ranges of out.
            let slice = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(i * m + w_lo), w_hi - w_lo)
            };
            kernel.gemv_rows(t, row, slice, w_lo..w_hi);
        }
    });
}

/// NUMA-routed accumulation: weight-row tiles are cut *within* each
/// node's row share ([`pallas_core::topology::Topology::row_ranges`] —
/// the same split [`QTensor::numa_localize`] first-touched by) and each
/// chunk is queued on the node owning its rows, so the weight-side
/// stream reads local memory. Every output element is still produced by
/// exactly one `gemv_rows` call with the same k-accumulation order, so
/// results are bit-identical to the unplaced path.
#[allow(clippy::too_many_arguments)]
fn matmul_prepared_placed(
    kernel: &dyn Kernel,
    t: &QTensor,
    batch: &PreparedBatch,
    x: &[f32],
    n: usize,
    out: &mut [f32],
    pool: &ThreadPool,
    a_tiles: usize,
    w_tiles: usize,
) {
    let m = t.m;
    let n_nodes = pool.n_nodes();
    let per_node = pallas_core::util::ceil_div(w_tiles, n_nodes).max(1);
    // (w_lo, w_hi, node) tiles, node-aligned.
    let mut wtiles: Vec<(usize, usize, usize)> = Vec::new();
    for (node, r) in pool.topology().row_ranges(m).iter().enumerate() {
        if r.is_empty() {
            continue;
        }
        let tiles = per_node.min(r.len());
        let rows = pallas_core::util::ceil_div(r.len(), tiles);
        let mut lo = r.start;
        while lo < r.end {
            let hi = (lo + rows).min(r.end);
            wtiles.push((lo, hi, node));
            lo = hi;
        }
    }
    let rows_per_a = pallas_core::util::ceil_div(n, a_tiles);
    let n_wtiles = wtiles.len();
    let out_ptr = SendPtr(out.as_mut_ptr());
    pool.parallel_for_placed(
        a_tiles * n_wtiles,
        |c| wtiles[c % n_wtiles].2,
        |c| {
            // Capture the whole wrapper (edition-2021 closures would
            // otherwise capture the raw-pointer field, which is !Sync).
            let out_ptr = &out_ptr;
            let (w_lo, w_hi, _) = wtiles[c % n_wtiles];
            let ai = c / n_wtiles;
            let a_lo = ai * rows_per_a;
            if a_lo >= n {
                return;
            }
            let a_hi = ((ai + 1) * rows_per_a).min(n);
            for i in a_lo..a_hi {
                let row = batch.row(i, x);
                // SAFETY: tiles write disjoint ranges of out.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(i * m + w_lo), w_hi - w_lo)
                };
                kernel.gemv_rows(t, row, slice, w_lo..w_hi);
            }
        },
    );
}

/// Multi-row, multi-threaded matmul: `out[(n, m)] = X[(n, k)] · Wᵀ`.
/// Convenience wrapper that builds a fresh [`PreparedBatch`] and runs
/// [`matmul_prepared`]; callers with an input shared across projections
/// (or a steady-state loop) should hold a [`PreparedActivations`] and
/// call the two phases explicitly to amortize preprocessing.
pub fn matmul(
    kernel: &dyn Kernel,
    t: &QTensor,
    x: &[f32],
    n: usize,
    out: &mut [f32],
    pool: &ThreadPool,
) {
    assert_eq!(x.len(), n * t.k);
    assert_eq!(out.len(), n * t.m);
    let mut batch = PreparedBatch::new();
    batch.build(kernel, x, t.k, n, pool);
    matmul_prepared(kernel, t, &batch, x, n, out, pool);
}

/// Pointer wrapper to move a raw pointer into the pool closure.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: the pointer targets a buffer owned by the caller that outlives
// the parallel region, and tasks write disjoint ranges of it.
unsafe impl Send for SendPtr {}
// SAFETY: as above.
unsafe impl Sync for SendPtr {}

/// Typed variant of [`SendPtr`] for the batch-build buffers.
#[derive(Clone, Copy)]
struct SendMut<T>(*mut T);
// SAFETY: the pointer targets a buffer owned by the caller that outlives
// the parallel region, and tasks write disjoint ranges of it.
unsafe impl<T> Send for SendMut<T> {}
// SAFETY: as above.
unsafe impl<T> Sync for SendMut<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use pallas_core::util::Rng;

    /// Reference f64 GEMV over dequantized weights and raw activations.
    fn dense_ref(w: &[f32], m: usize, k: usize, x: &[f32]) -> Vec<f32> {
        (0..m)
            .map(|r| {
                w[r * k..(r + 1) * k]
                    .iter()
                    .zip(x.iter())
                    .map(|(&wv, &xv)| wv as f64 * xv as f64)
                    .sum::<f64>() as f32
            })
            .collect()
    }

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.0625)
    }

    /// NUMA routing + weight localization must be bit-identical to the
    /// plain path for every kernel: same values, different placement.
    #[test]
    fn numa_placed_matmul_is_bit_identical() {
        use pallas_core::topology::Topology;
        let (m, k, n) = (96, 512, 3);
        let t = random_ternary(m, k, 21);
        let mut rng = Rng::new(22);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let plain = ThreadPool::new(4);
        let placed = ThreadPool::with_topology(4, Topology::mock(2));
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            if k % kern.info().k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let mut localized = kern.quantize(&t);
            localized.numa_localize(&placed);
            assert_eq!(localized.data, packed.data, "{qt:?}: localize must not alter bytes");
            let mut out_plain = vec![0f32; n * m];
            matmul(kern, &packed, &x, n, &mut out_plain, &plain);
            let mut out_placed = vec![0f32; n * m];
            matmul(kern, &localized, &x, n, &mut out_placed, &placed);
            assert_eq!(
                out_plain.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                out_placed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{qt:?}: NUMA-placed matmul diverged"
            );
        }
        let stats = placed.numa_stats();
        assert!(stats.chunks.iter().sum::<u64>() > 0);
    }

    /// Every kernel must approximate the dense reference within a
    /// quantization-error bound on random ternary weights.
    #[test]
    fn all_kernels_match_dense_reference() {
        let (m, k) = (64, 512);
        let t = random_ternary(m, k, 9);
        let wd = t.dequantize();
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let reference = dense_ref(&wd, m, k, &x);
        let ref_norm = reference.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();

        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            if k % kern.info().k_multiple != 0 {
                continue;
            }
            let qt_tensor = kern.quantize(&t);
            let p = kern.prepare(&x, k);
            let mut out = vec![0f32; m];
            kern.gemv(&qt_tensor, &p, &mut out);
            let err = out
                .iter()
                .zip(reference.iter())
                .map(|(a, b)| ((*a - *b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let rel = err / ref_norm.max(1e-12);
            // Int8 activation quantization alone gives ~1e-3 relative error;
            // blocky baselines (Q2_K) are the loosest.
            let bound = match qt {
                QuantType::Q2K => 0.12,
                // Q4_0's asymmetric grid maps the −amax side to ±7/8 of
                // its value — up to ~12% error on exact-ternary data.
                QuantType::Q40 => 0.12,
                QuantType::Elut4 | QuantType::Elut5 => 0.08,
                // Bit-wise LUT requantizes subset-sum tables whose dynamic
                // range (up to 4·127) is wider than TL's pair/trio sums.
                QuantType::Tmac => 0.04,
                _ => 0.02,
            };
            assert!(rel < bound, "{}: rel err {rel:.5} >= {bound}", kern.info().name);
        }
    }

    /// Storage bpw must match the nominal Table-1 values.
    #[test]
    fn bpw_matches_table1() {
        let t = random_ternary(32, 3072, 11);
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            if t.k % kern.info().k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let got = packed.bits_per_weight();
            let want = kern.info().bpw;
            assert!(
                (got - want).abs() / want < 0.02,
                "{}: measured bpw {got:.3} vs nominal {want:.3}",
                kern.info().name
            );
        }
    }

    /// dequantize(quantize(w)) must preserve ternary values exactly for all
    /// ternary-native kernels.
    #[test]
    fn ternary_native_round_trip() {
        let t = random_ternary(16, 768, 12);
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            let info = kern.info();
            if !info.ternary_native || t.k % info.k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let back = kern.dequantize(&packed);
            let want = t.dequantize();
            for (i, (a, b)) in back.iter().zip(want.iter()).enumerate() {
                assert!((a - b).abs() < 1e-6, "{} idx {i}: {a} vs {b}", info.name);
            }
        }
    }

    /// matmul (threaded, batched prepare) must equal gemv row-by-row
    /// (serial, per-row prepare).
    #[test]
    fn threaded_matmul_matches_serial() {
        let (m, k, n) = (48, 256, 3);
        let t = random_ternary(m, k, 13);
        let mut rng = Rng::new(14);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(4);
        for qt in [QuantType::I2S, QuantType::Tl20, QuantType::Tq20, QuantType::F16] {
            let kern = kernel_for(qt);
            if k % kern.info().k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let mut out_par = vec![0f32; n * m];
            matmul(kern, &packed, &x, n, &mut out_par, &pool);
            for i in 0..n {
                let p = kern.prepare(&x[i * k..(i + 1) * k], k);
                let mut out_ser = vec![0f32; m];
                kern.gemv(&packed, &p, &mut out_ser);
                assert_eq!(&out_par[i * m..(i + 1) * m], &out_ser[..], "{qt:?} row {i}");
            }
        }
    }

    /// The prepare cache shares one batch across consumers of the same
    /// input and invalidates on `begin_input`.
    #[test]
    fn prepared_activations_cache_hits_and_invalidates() {
        let (m, k, n) = (16, 256, 2);
        let t = random_ternary(m, k, 15);
        let kern = kernel_for(QuantType::Tl21);
        let packed = kern.quantize(&t);
        let pool = ThreadPool::new(2);
        let mut rng = Rng::new(16);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let mut acts = PreparedActivations::new();
        acts.begin_input();
        let mut out_a = vec![0f32; n * m];
        {
            let batch = acts.get_or_prepare(kern, &x, k, n, &pool);
            matmul_prepared(kern, &packed, batch, &x, n, &mut out_a, &pool);
        }
        let mut out_b = vec![0f32; n * m];
        {
            let batch = acts.get_or_prepare(kern, &x, k, n, &pool);
            matmul_prepared(kern, &packed, batch, &x, n, &mut out_b, &pool);
        }
        assert_eq!(out_a, out_b);
        assert_eq!(acts.stats().misses, 1, "one prepare per input");
        assert_eq!(acts.stats().hits, 1, "second consumer hits");
        // A new input invalidates; the rebuild reuses the buffers.
        acts.begin_input();
        let x2: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        {
            let batch = acts.get_or_prepare(kern, &x2, k, n, &pool);
            matmul_prepared(kern, &packed, batch, &x2, n, &mut out_b, &pool);
        }
        assert_eq!(acts.stats().misses, 2);
        assert_eq!(acts.stats().buffer_reuses, 1, "steady-state rebuild is allocation-free");
        let mut out_ref = vec![0f32; n * m];
        matmul(kern, &packed, &x2, n, &mut out_ref, &pool);
        assert_eq!(out_b, out_ref);
    }

    #[test]
    fn quant_type_parse_round_trip() {
        for qt in QuantType::ALL {
            assert_eq!(QuantType::parse(qt.name()), Some(qt));
        }
        assert_eq!(QuantType::parse("tl2_0"), Some(QuantType::Tl20));
        assert_eq!(QuantType::parse("nope"), None);
    }

    #[test]
    fn library_table_has_expected_properties() {
        let table = library_table();
        assert_eq!(table.len(), QuantType::ALL.len());
        let tl2 = table.iter().find(|i| i.name == "TL2_0").unwrap();
        assert!(tl2.element_wise && tl2.class == KernelClass::LutBased && !tl2.lossless);
        let i2s = table.iter().find(|i| i.name == "I2_S").unwrap();
        assert!(i2s.lossless && i2s.class == KernelClass::MadBased);
        let tmac = table.iter().find(|i| i.name == "TMAC").unwrap();
        assert!(!tmac.element_wise);
    }
}
