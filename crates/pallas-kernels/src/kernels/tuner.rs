//! Auto-tuned kernel dispatch (upstream bitnet.cpp's `kernel_tuning`
//! utility, reconstructed): micro-benchmark every applicable kernel for
//! the matmul shapes a model actually runs, persist the winners in a
//! [`TuningProfile`], and route every `model::BitLinear` through
//! a [`Dispatch`] policy that either pins one kernel (`Fixed`) or selects
//! per shape from the profile (`Auto`).
//!
//! Why this exists: the paper's speedups (§4, Table 7) come from picking
//! the right mpGEMM kernel per machine *and* per matrix shape — TL2's
//! 1.67 bpw wins when decode is memory-bound, I2_S/TL1 win where the
//! LUT preprocessing dominates, and the crossover moves with m, k, batch
//! size and thread count. Upstream reports 20–30% extra throughput from
//! hardware-specific selection; this module makes that selection
//! measured rather than guessed.
//!
//! Flow:
//! 1. `bitnet tune --preset <p> --out profile.json` runs [`tune`] over the
//!    preset's projection shapes and writes the profile (JSON via
//!    [`pallas_core::util::Json`]).
//! 2. `bitnet run --qtype auto --tune-profile profile.json` loads it into
//!    `Dispatch::Auto`, and each layer packs with the per-shape winner.
//!
//! Fallback semantics are documented on [`TuningProfile::select`] and in
//! `docs/tuning.md`.
#![deny(missing_docs)]

use super::simd::{self, SimdLevel};
use super::sparse::{self, SparseMode};
use super::{kernel_for, QuantType};
use crate::perf::calibrate::{calibrate_kernel_shape, calibrate_kernel_shape_sparse, KernelRate};
use pallas_core::util::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Profile file format version written by [`TuningProfile::to_json`]
/// (bump on breaking schema changes). Older versions in
/// [`SUPPORTED_PROFILE_VERSIONS`] still load, with the fields they lack
/// defaulting to empty — see `docs/tuning.md` for the migration table.
pub const PROFILE_VERSION: u64 = 4;

/// Profile versions [`TuningProfile::from_json`] accepts. v1 files (PR 1)
/// carry only the per-shape `entries`; v2 adds optional `overrides` and
/// `e2e` sections; v3 records the SIMD level each measurement ran at and
/// the level the per-shape winner used (older files load with every
/// level defaulting to `scalar`); v4 records whether each measurement ran
/// the block-skip sparse layout and whether the per-shape winner did
/// (older files load with `sparse`/`best_sparse` defaulting to false —
/// every pre-v4 measurement was dense by construction).
pub const SUPPORTED_PROFILE_VERSIONS: [u64; 4] = [1, 2, 3, 4];

/// The projection a ternary matmul serves inside a transformer layer —
/// the per-layer dispatch key alongside the (m, k, n) shape. `Qkv`
/// covers the three attention input projections (wq/wk/wv always share
/// a phase regime); the rest are one projection each.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    /// Attention input projections wq/wk/wv.
    Qkv,
    /// Attention output projection wo.
    O,
    /// FFN gate projection.
    Gate,
    /// FFN up projection.
    Up,
    /// FFN down projection.
    Down,
}

impl Role {
    /// Every role, in layer-forward order.
    pub const ALL: [Role; 5] = [Role::Qkv, Role::O, Role::Gate, Role::Up, Role::Down];

    /// Profile-facing name (the `role` field of an override entry).
    pub fn name(&self) -> &'static str {
        match self {
            Role::Qkv => "qkv",
            Role::O => "o",
            Role::Gate => "gate",
            Role::Up => "up",
            Role::Down => "down",
        }
    }

    /// Parse a profile-facing role name.
    pub fn parse(s: &str) -> Option<Role> {
        Role::ALL.iter().copied().find(|r| r.name().eq_ignore_ascii_case(s))
    }
}

/// A v2-profile per-layer override: pin `(layer, role)` at batch `n` to a
/// specific kernel, taking precedence over the per-shape `entries`. Batch
/// resolution follows the same largest-tuned-n ≤ n rule as shape entries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerOverride {
    /// Zero-based transformer layer index.
    pub layer: usize,
    /// Which projection of that layer.
    pub role: Role,
    /// Activation batch rows this override was chosen for.
    pub n: usize,
    /// The kernel to run.
    pub qtype: QuantType,
}

/// One end-to-end layer-composition measurement recorded by
/// `bitnet tune --e2e` (informational: per-shape winners can compose
/// differently than they measure in isolation — cache pressure from one
/// layer's tables evicts the next layer's weights).
#[derive(Clone, Debug, PartialEq)]
pub struct E2eEntry {
    /// What was measured, e.g. `auto` or `fixed(I2_S)`.
    pub label: String,
    /// Prefill throughput, prompt tokens per second.
    pub prefill_tok_s: f64,
    /// Decode throughput, generated tokens per second.
    pub decode_tok_s: f64,
}

/// One timed kernel on one shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measurement {
    /// The kernel measured.
    pub qtype: QuantType,
    /// The SIMD dispatch level the kernel ran at (v3 profiles; older
    /// files load as `scalar`).
    pub simd: SimdLevel,
    /// Whether the kernel ran its block-skip sparse layout on the
    /// calibration tensor (v4 profiles; older files load as false).
    /// Sparse measurements use a ~60%-zero-block synthetic tensor, so
    /// they record what the kernel does when elision has real work to
    /// skip — see `docs/tuning.md`.
    pub sparse: bool,
    /// Mean wall time of one matmul call, microseconds.
    pub us_per_matmul: f64,
    /// Weights streamed per second (`m·k / secs_per_call`), in units of
    /// 1e9 weights — the tuner's ranking metric (higher is better).
    pub gweights_per_s: f64,
}

/// Tuning result for one (m, k, batch) matmul shape.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningEntry {
    /// Output features (weight rows).
    pub m: usize,
    /// Input features (weight cols / reduction dim).
    pub k: usize,
    /// Activation batch rows the measurement used.
    pub n: usize,
    /// Fraction of observed traffic this batch width served when the
    /// sweep was trace-driven (`tune --trace`); 1.0 for the fixed
    /// `--batches` sweep, where every width is tuned unconditionally.
    /// Informational: the per-shape winner is the winner regardless of
    /// frequency — the field records which entries carry real traffic
    /// (and how much was dropped by a `--trace-widths` cap).
    pub weight: f64,
    /// The fastest measured kernel for this shape.
    pub best: QuantType,
    /// The SIMD level `best` won at. Selection degrades when the serving
    /// host can't run it — see [`TuningProfile::select_traced`].
    pub best_simd: SimdLevel,
    /// Whether `best` won on its block-skip sparse layout. Selection
    /// degrades when sparse packing is disabled on the serving host
    /// (`RUST_PALLAS_SPARSE=off` / `--sparse off`) — see
    /// [`TuningProfile::select_traced`].
    pub best_sparse: bool,
    /// All measurements, fastest first (kept for inspection/debugging).
    pub measurements: Vec<Measurement>,
}

/// A machine- and shape-specific kernel selection table, serializable to
/// a JSON profile file.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningProfile {
    /// Thread count the measurements were taken with (selection quality
    /// degrades if the serving thread count differs; the CLI warns).
    pub threads: usize,
    /// Fallback kernel for shapes absent from the profile.
    pub default: QuantType,
    /// Per-shape winners.
    pub entries: Vec<TuningEntry>,
    /// v2: per-layer overrides, consulted before `entries` when the
    /// caller knows its (layer, role) position ([`TuningProfile::select_for`]).
    pub overrides: Vec<LayerOverride>,
    /// v2: end-to-end layer-composition measurements (`tune --e2e`),
    /// informational.
    pub e2e: Vec<E2eEntry>,
}

impl TuningProfile {
    /// An empty profile that always falls back to `default`.
    pub fn empty(default: QuantType, threads: usize) -> TuningProfile {
        TuningProfile {
            threads,
            default,
            entries: Vec::new(),
            overrides: Vec::new(),
            e2e: Vec::new(),
        }
    }

    /// The per-batch-width traffic fractions this profile was tuned at:
    /// one row per distinct `n` across `entries` (the `weight` field is
    /// per width, so the first entry at each width carries it), widths
    /// ascending, normalized to sum to 1. Fixed `--batches` sweeps store
    /// weight 1.0 per width and normalize to uniform. Empty for a
    /// profile with no entries. `run`/`serve` compare this against the
    /// live `ServingTrace` to warn when traffic drifts from what was
    /// tuned (`ServingTrace::drift_l1`).
    pub fn weighted_widths(&self) -> Vec<(usize, f64)> {
        let mut per_n: Vec<(usize, f64)> = Vec::new();
        for e in &self.entries {
            if !per_n.iter().any(|&(n, _)| n == e.n) {
                per_n.push((e.n, e.weight));
            }
        }
        per_n.sort_unstable_by_key(|&(n, _)| n);
        let total: f64 = per_n.iter().map(|&(_, w)| w).sum();
        if total > 0.0 {
            for e in per_n.iter_mut() {
                e.1 /= total;
            }
        }
        per_n
    }

    /// Select the kernel for an `m`×`k` matmul at batch size `n`.
    ///
    /// Resolution order (documented contract, see docs/tuning.md):
    /// 1. the entry matching (m, k) with the **largest tuned batch ≤ n**
    ///    (decode at n=1 uses the n=1 entry; a batch of 6 uses the n=4
    ///    entry when 1 and 4 were tuned);
    /// 2. if every tuned batch for (m, k) exceeds `n`, the smallest one;
    /// 3. if (m, k) was never tuned at all, [`TuningProfile::default`].
    pub fn select(&self, m: usize, k: usize, n: usize) -> QuantType {
        self.select_traced(m, k, n).0
    }

    /// [`TuningProfile::select`], also reporting whether resolution fell
    /// through to the untuned `default` (true = case 3, a fallback worth
    /// surfacing — see [`DispatchPlan`]) **or** degraded because the
    /// entry's winner was measured at a SIMD level this host cannot run
    /// (a profile tuned on an AVX2 box loaded on a machine without it,
    /// or under a forced `--simd scalar`), **or** because the winner was
    /// measured on its block-skip sparse layout but sparse packing is
    /// disabled here (`RUST_PALLAS_SPARSE=off` / `--sparse off` — no
    /// tensor will carry the index the winner was tuned with). A
    /// degraded entry re-ranks to the fastest of its measurements that
    /// are both usable (SIMD) and runnable (dense when sparse is off),
    /// keeping the choice measured rather than guessed; it falls back to
    /// the recorded winner's kernel only when no such measurement exists
    /// (hand-edited profiles) — the kernel itself still runs, just on
    /// its scalar/dense path.
    pub fn select_traced(&self, m: usize, k: usize, n: usize) -> (QuantType, bool) {
        let mut below: Option<&TuningEntry> = None;
        let mut above: Option<&TuningEntry> = None;
        for e in self.entries.iter().filter(|e| e.m == m && e.k == k) {
            if e.n <= n {
                if below.map_or(true, |b| e.n > b.n) {
                    below = Some(e);
                }
            } else if above.map_or(true, |a| e.n < a.n) {
                above = Some(e);
            }
        }
        match below.or(above) {
            Some(e) => {
                let sparse_ok = !e.best_sparse || sparse::enabled();
                if simd::usable(e.best_simd) && sparse_ok {
                    (e.best, false)
                } else {
                    let degraded = e
                        .measurements
                        .iter()
                        .filter(|m| simd::usable(m.simd) && (!m.sparse || sparse::enabled()))
                        .min_by(|a, b| {
                            a.us_per_matmul.partial_cmp(&b.us_per_matmul).expect("finite")
                        })
                        .map(|m| m.qtype)
                        .unwrap_or(e.best);
                    (degraded, true)
                }
            }
            None => (self.default, true),
        }
    }

    /// Layer-aware selection: per-layer `overrides` for (layer, role)
    /// resolve first (same largest-tuned-n ≤ n batch rule), then the
    /// per-shape `entries`, then `default`. The bool reports a default
    /// fallback exactly as in [`TuningProfile::select_traced`].
    pub fn select_for(
        &self,
        layer: usize,
        role: Role,
        m: usize,
        k: usize,
        n: usize,
    ) -> (QuantType, bool) {
        let mut below: Option<&LayerOverride> = None;
        let mut above: Option<&LayerOverride> = None;
        for o in self.overrides.iter().filter(|o| o.layer == layer && o.role == role) {
            if o.n <= n {
                if below.map_or(true, |b| o.n > b.n) {
                    below = Some(o);
                }
            } else if above.map_or(true, |a| o.n < a.n) {
                above = Some(o);
            }
        }
        if let Some(o) = below.or(above) {
            return (o.qtype, false);
        }
        self.select_traced(m, k, n)
    }

    /// Serialize to the JSON profile schema.
    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|e| {
                let ms = e
                    .measurements
                    .iter()
                    .map(|m| {
                        Json::Obj(vec![
                            ("kernel".into(), Json::Str(m.qtype.name().into())),
                            ("simd".into(), Json::Str(m.simd.name().into())),
                            ("sparse".into(), Json::Bool(m.sparse)),
                            ("us_per_matmul".into(), Json::Num(m.us_per_matmul)),
                            ("gweights_per_s".into(), Json::Num(m.gweights_per_s)),
                        ])
                    })
                    .collect();
                Json::Obj(vec![
                    ("m".into(), Json::Num(e.m as f64)),
                    ("k".into(), Json::Num(e.k as f64)),
                    ("n".into(), Json::Num(e.n as f64)),
                    ("weight".into(), Json::Num(e.weight)),
                    ("best".into(), Json::Str(e.best.name().into())),
                    ("best_simd".into(), Json::Str(e.best_simd.name().into())),
                    ("best_sparse".into(), Json::Bool(e.best_sparse)),
                    ("measurements".into(), Json::Arr(ms)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("version".into(), Json::Num(PROFILE_VERSION as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("default".into(), Json::Str(self.default.name().into())),
            ("entries".into(), Json::Arr(entries)),
        ];
        if !self.overrides.is_empty() {
            let os = self
                .overrides
                .iter()
                .map(|o| {
                    Json::Obj(vec![
                        ("layer".into(), Json::Num(o.layer as f64)),
                        ("role".into(), Json::Str(o.role.name().into())),
                        ("n".into(), Json::Num(o.n as f64)),
                        ("kernel".into(), Json::Str(o.qtype.name().into())),
                    ])
                })
                .collect();
            fields.push(("overrides".into(), Json::Arr(os)));
        }
        if !self.e2e.is_empty() {
            let es = self
                .e2e
                .iter()
                .map(|e| {
                    Json::Obj(vec![
                        ("label".into(), Json::Str(e.label.clone())),
                        ("prefill_tok_s".into(), Json::Num(e.prefill_tok_s)),
                        ("decode_tok_s".into(), Json::Num(e.decode_tok_s)),
                    ])
                })
                .collect();
            fields.push(("e2e".into(), Json::Arr(es)));
        }
        Json::Obj(fields)
    }

    /// Parse from the JSON profile schema. Every version listed in
    /// [`SUPPORTED_PROFILE_VERSIONS`] loads; v1 files migrate by leaving
    /// the sections they predate (`overrides`, `e2e`) empty. Anything
    /// else is a clear error, not a field-order guess.
    pub fn from_json(v: &Json) -> Result<TuningProfile> {
        let version = v.get("version").and_then(Json::as_usize).context("profile: version")?;
        if !SUPPORTED_PROFILE_VERSIONS.contains(&(version as u64)) {
            bail!(
                "unsupported profile version {version} (supported: {:?}); \
                 regenerate with `bitnet tune --out <path>`",
                SUPPORTED_PROFILE_VERSIONS
            );
        }
        let threads = v.get("threads").and_then(Json::as_usize).context("profile: threads")?;
        let default = parse_qtype(v.get("default").and_then(Json::as_str).context("profile: default")?)?;
        let mut entries = Vec::new();
        for (i, e) in v
            .get("entries")
            .and_then(Json::as_array)
            .context("profile: entries")?
            .iter()
            .enumerate()
        {
            let field = |name: &str| {
                e.get(name).and_then(Json::as_usize).with_context(|| format!("entry {i}: {name}"))
            };
            let best = parse_qtype(
                e.get("best").and_then(Json::as_str).with_context(|| format!("entry {i}: best"))?,
            )?;
            let mut measurements = Vec::new();
            if let Some(ms) = e.get("measurements").and_then(Json::as_array) {
                for m in ms {
                    let (Some(kname), Some(us), Some(gw)) = (
                        m.get("kernel").and_then(Json::as_str),
                        m.get("us_per_matmul").and_then(Json::as_f64),
                        m.get("gweights_per_s").and_then(Json::as_f64),
                    ) else {
                        bail!("entry {i}: malformed measurement");
                    };
                    measurements.push(Measurement {
                        qtype: parse_qtype(kname)?,
                        simd: parse_simd(m.get("simd").and_then(Json::as_str), i)?,
                        // Optional field: pre-v4 measurements were all
                        // dense.
                        sparse: m.get("sparse").and_then(Json::as_bool).unwrap_or(false),
                        us_per_matmul: us,
                        gweights_per_s: gw,
                    });
                }
            }
            entries.push(TuningEntry {
                m: field("m")?,
                k: field("k")?,
                n: field("n")?,
                // Optional field: profiles written before trace-driven
                // tuning (and hand-edited ones) default to weight 1.0.
                weight: e.get("weight").and_then(Json::as_f64).unwrap_or(1.0),
                best,
                best_simd: parse_simd(e.get("best_simd").and_then(Json::as_str), i)?,
                // Optional field: pre-v4 winners were all dense.
                best_sparse: e.get("best_sparse").and_then(Json::as_bool).unwrap_or(false),
                measurements,
            });
        }
        let mut overrides = Vec::new();
        if let Some(os) = v.get("overrides").and_then(Json::as_array) {
            for (i, o) in os.iter().enumerate() {
                let role_name = o
                    .get("role")
                    .and_then(Json::as_str)
                    .with_context(|| format!("override {i}: role"))?;
                let role = Role::parse(role_name)
                    .with_context(|| format!("override {i}: unknown role {role_name:?}"))?;
                overrides.push(LayerOverride {
                    layer: o
                        .get("layer")
                        .and_then(Json::as_usize)
                        .with_context(|| format!("override {i}: layer"))?,
                    role,
                    n: o
                        .get("n")
                        .and_then(Json::as_usize)
                        .with_context(|| format!("override {i}: n"))?,
                    qtype: parse_qtype(
                        o.get("kernel")
                            .and_then(Json::as_str)
                            .with_context(|| format!("override {i}: kernel"))?,
                    )?,
                });
            }
        }
        let mut e2e = Vec::new();
        if let Some(es) = v.get("e2e").and_then(Json::as_array) {
            for (i, e) in es.iter().enumerate() {
                e2e.push(E2eEntry {
                    label: e
                        .get("label")
                        .and_then(Json::as_str)
                        .with_context(|| format!("e2e {i}: label"))?
                        .to_string(),
                    prefill_tok_s: e
                        .get("prefill_tok_s")
                        .and_then(Json::as_f64)
                        .with_context(|| format!("e2e {i}: prefill_tok_s"))?,
                    decode_tok_s: e
                        .get("decode_tok_s")
                        .and_then(Json::as_f64)
                        .with_context(|| format!("e2e {i}: decode_tok_s"))?,
                });
            }
        }
        Ok(TuningProfile { threads, default, entries, overrides, e2e })
    }

    /// Write the profile to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing profile {}", path.display()))
    }

    /// Load a profile from a JSON file.
    pub fn load(path: &Path) -> Result<TuningProfile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading profile {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing profile {}", path.display()))?;
        Self::from_json(&v)
    }
}

fn parse_qtype(name: &str) -> Result<QuantType> {
    QuantType::parse(name).with_context(|| format!("unknown kernel {name:?} in profile"))
}

/// Parse an optional profile SIMD-level field: absent (v1/v2 files)
/// defaults to `scalar`; present but unknown is a clear error.
fn parse_simd(name: Option<&str>, entry: usize) -> Result<SimdLevel> {
    match name {
        None => Ok(SimdLevel::Scalar),
        Some(s) => SimdLevel::parse(s)
            .with_context(|| format!("entry {entry}: unknown simd level {s:?} in profile")),
    }
}

/// How a model picks the kernel for each of its ternary projections.
#[derive(Clone, Debug)]
pub enum Dispatch {
    /// Every projection uses this kernel (the pre-tuner behavior).
    Fixed(QuantType),
    /// Per-shape selection from a measured profile.
    Auto(TuningProfile),
}

impl Dispatch {
    /// The kernel for an `m`×`k` projection at decode batch `n`.
    pub fn select(&self, m: usize, k: usize, n: usize) -> QuantType {
        match self {
            Dispatch::Fixed(q) => *q,
            Dispatch::Auto(p) => p.select(m, k, n),
        }
    }

    /// Layer-aware selection (see [`TuningProfile::select_for`]). The
    /// bool reports that an `Auto` profile had no entry for the shape and
    /// fell back to its default; `Fixed` never falls back.
    pub fn select_for(
        &self,
        layer: usize,
        role: Role,
        m: usize,
        k: usize,
        n: usize,
    ) -> (QuantType, bool) {
        match self {
            Dispatch::Fixed(q) => (*q, false),
            Dispatch::Auto(p) => p.select_for(layer, role, m, k, n),
        }
    }

    /// A representative kernel (what `Transformer::qtype` reports): the
    /// fixed kernel, or the profile's selection for the given shape.
    pub fn representative(&self, m: usize, k: usize) -> QuantType {
        self.select(m, k, 1)
    }

    /// One-line human description for logs.
    pub fn describe(&self) -> String {
        match self {
            Dispatch::Fixed(q) => format!("fixed({})", q.name()),
            Dispatch::Auto(p) => format!(
                "auto({} tuned shapes, {} layer overrides, default {}, tuned @ {} threads)",
                p.entries.len(),
                p.overrides.len(),
                p.default.name(),
                p.threads
            ),
        }
    }
}

/// The per-call kernel resolver the model's hot path consults: wraps a
/// [`Dispatch`] policy with the call-site context (layer index, [`Role`],
/// effective batch `n`) and observability — untuned-shape fallbacks are
/// counted (surfaced as `dispatch_fallbacks` in the engine metrics) and,
/// in verbose mode, logged once per (m, k, n) instead of silently
/// inheriting the profile default.
///
/// Construction-time packing picks each layer's *primary* kernel through
/// the same plan at n=1; `forward_batch` re-resolves per call with the
/// real batch width, which is what routes prefill (n = chunk length) and
/// batched decode (n = batch width) to different kernels than
/// single-sequence decode (n=1) — the paper's prefill/decode split.
pub struct DispatchPlan {
    dispatch: Dispatch,
    verbose: bool,
    fallback_count: AtomicU64,
    degraded_count: AtomicU64,
    /// (m, k, n) shapes whose fallback was already logged (verbose only).
    logged: Mutex<HashSet<(usize, usize, usize)>>,
    /// (m, k, n) shapes whose degradation was already logged (verbose only).
    logged_degraded: Mutex<HashSet<(usize, usize, usize)>>,
}

impl DispatchPlan {
    /// Wrap a dispatch policy (non-verbose).
    pub fn new(dispatch: Dispatch) -> DispatchPlan {
        DispatchPlan {
            dispatch,
            verbose: false,
            fallback_count: AtomicU64::new(0),
            degraded_count: AtomicU64::new(0),
            logged: Mutex::new(HashSet::new()),
            logged_degraded: Mutex::new(HashSet::new()),
        }
    }

    /// Enable once-per-shape fallback logging to stderr.
    pub fn with_verbose(mut self, verbose: bool) -> DispatchPlan {
        self.verbose = verbose;
        self
    }

    /// The wrapped policy.
    pub fn dispatch(&self) -> &Dispatch {
        &self.dispatch
    }

    /// One-line human description for logs (delegates to the policy).
    pub fn describe(&self) -> String {
        self.dispatch.describe()
    }

    /// Resolve the kernel for one matmul call, recording fallbacks.
    pub fn select(&self, layer: usize, role: Role, m: usize, k: usize, n: usize) -> QuantType {
        let (q, fell_back) = self.dispatch.select_for(layer, role, m, k, n);
        if fell_back {
            self.fallback_count.fetch_add(1, Ordering::Relaxed);
            if self.verbose {
                let mut logged = self.logged.lock().unwrap();
                if logged.insert((m, k, n)) {
                    eprintln!(
                        "dispatch: no tuned entry for {m}x{k} n={n}; falling back to {} \
                         (re-run `bitnet tune` to cover this shape)",
                        q.name()
                    );
                }
            }
        }
        q
    }

    /// How many selections fell back to the profile default so far.
    pub fn fallbacks(&self) -> u64 {
        self.fallback_count.load(Ordering::Relaxed)
    }

    /// Record that a routed call could not run its resolved kernel
    /// (`want`) and degraded to `ran` — alternate budget exhausted, K
    /// alignment mismatch, or a non-reconstructable primary. Counted so
    /// "tuned winner is live" is never silently untrue, logged once per
    /// (m, k, n) in verbose mode.
    pub fn note_degraded(
        &self,
        m: usize,
        k: usize,
        n: usize,
        want: QuantType,
        ran: QuantType,
    ) {
        self.degraded_count.fetch_add(1, Ordering::Relaxed);
        if self.verbose {
            let mut logged = self.logged_degraded.lock().unwrap();
            if logged.insert((m, k, n)) {
                eprintln!(
                    "dispatch: {m}x{k} n={n} resolved to {} but ran {} \
                     (alternate budget or K alignment)",
                    want.name(),
                    ran.name()
                );
            }
        }
    }

    /// How many routed calls degraded from their resolved kernel so far.
    pub fn degraded(&self) -> u64 {
        self.degraded_count.load(Ordering::Relaxed)
    }
}

/// What [`tune`] measures.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// (m, k) matmul shapes to tune (see [`shapes_for_model`]).
    pub shapes: Vec<(usize, usize)>,
    /// Activation batch sizes to tune each shape at.
    pub batches: Vec<usize>,
    /// Traffic weight per entry of `batches`, parallel to it (empty =
    /// every batch weighs 1.0, the fixed-sweep behavior). Trace-driven
    /// sweeps ([`TuneConfig::set_weighted_batches`]) fill this with each
    /// width's observed frequency, which `tune` records into the
    /// profile's entries.
    pub batch_weights: Vec<f64>,
    /// Thread-pool size to measure with (match the serving `--threads`).
    pub threads: usize,
    /// Candidate kernels; non-applicable ones (k % k_multiple != 0) are
    /// skipped per shape.
    pub candidates: Vec<QuantType>,
    /// Fallback kernel recorded in the profile.
    pub default: QuantType,
    /// Minimum timed iterations per (kernel, shape).
    pub min_iters: usize,
    /// Minimum measurement wall time per (kernel, shape), seconds.
    pub min_seconds: f64,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            shapes: Vec::new(),
            batches: vec![1, 4],
            batch_weights: Vec::new(),
            threads: 1,
            candidates: default_candidates(),
            default: QuantType::I2S,
            min_iters: 3,
            min_seconds: 0.06,
        }
    }
}

impl TuneConfig {
    /// Replace the batch sweep with observed `(width, weight)` pairs —
    /// the trace-driven mode (`tune --trace`): the sweep runs at exactly
    /// the GEMM batch widths a recorded serving trace exhibits (see
    /// `coordinator::trace::ServingTrace::weighted_batches`), no fixed
    /// `--batches` fallback.
    pub fn set_weighted_batches(&mut self, batches: &[(usize, f64)]) {
        self.batches = batches.iter().map(|&(n, _)| n).collect();
        self.batch_weights = batches.iter().map(|&(_, w)| w).collect();
    }

    /// The weight for `batches[i]` (1.0 when no weights were supplied).
    fn batch_weight(&self, i: usize) -> f64 {
        self.batch_weights.get(i).copied().unwrap_or(1.0)
    }
}

/// The default candidate set: compact ternary-native serving kernels
/// (storage ≤ 4 bpw). The dense baselines (F32/F16) and the general
/// llama.cpp formats (Q4_0/Q2_K) are excluded on purpose — a dense MAD
/// path can win a small cache-resident micro-benchmark, and silently
/// packing a "ternary" model at 16–32 bpw would defeat the 1-bit
/// serving premise. Measure them anyway with `--kernels`.
pub fn default_candidates() -> Vec<QuantType> {
    QuantType::ALL
        .iter()
        .copied()
        .filter(|&q| {
            let info = kernel_for(q).info();
            info.ternary_native && info.bpw <= 4.0
        })
        .collect()
}

/// Micro-benchmark every applicable candidate on every (shape × batch)
/// and return the winners as a [`TuningProfile`]. `progress` (when given)
/// receives one line per measurement — the CLI wires it to stderr under
/// `--verbose`.
pub fn tune(cfg: &TuneConfig, mut progress: Option<&mut dyn FnMut(&str)>) -> TuningProfile {
    // The process-wide pool, not a private one: tuning in a serving
    // process used to layer a second worker set on top of the engine's,
    // and the resulting oversubscription skewed the measurements the
    // profile is built from.
    let pool = pallas_core::threadpool::shared_pool(cfg.threads.max(1));
    let mut entries = Vec::new();
    for &(m, k) in &cfg.shapes {
        for (bi, &n) in cfg.batches.iter().enumerate() {
            let weight = cfg.batch_weight(bi);
            if n == 0 {
                // A zero-row matmul measures nothing; an n=0 entry would
                // also shadow every real batch in `select` (e.n <= n).
                if let Some(p) = progress.as_mut() {
                    p(&format!("tune {m}x{k}: skipping batch 0 (no work to measure)"));
                }
                continue;
            }
            let mut measurements: Vec<Measurement> = Vec::new();
            for &qt in &cfg.candidates {
                let kern = kernel_for(qt);
                if k % kern.info().k_multiple != 0 {
                    continue;
                }
                // Measure each kernel once per SIMD tier it implements
                // and this host can run — the per-shape winner is a
                // (kernel, level) pair, not just a kernel, and the
                // scalar row is what profile degradation falls back to
                // on hosts that lack the winning vector tier.
                let kernel_levels = kern.simd_levels();
                // A kernel with a block-skip layout is additionally
                // measured on a ~60%-zero-block synthetic tensor with
                // sparse packing forced on — the sparse-vs-dense choice
                // is a measured dispatch dimension, not a guess. Skipped
                // entirely when sparse packing is disabled on this host
                // (the measurement could never be served).
                let sparse_variants: &[bool] = if kern.sparse_capable() && sparse::enabled() {
                    &[false, true]
                } else {
                    &[false]
                };
                for level in simd::available_levels() {
                    if !kernel_levels.contains(&level) {
                        continue;
                    }
                    for &sp in sparse_variants {
                        // Lock ordering: sparse mode outside, SIMD level
                        // inside (matches the kernel test suite).
                        let rate: KernelRate = if sp {
                            sparse::with_mode(SparseMode::On, || {
                                simd::with_level(level, || {
                                    calibrate_kernel_shape_sparse(
                                        qt,
                                        m,
                                        k,
                                        n,
                                        &pool,
                                        cfg.min_iters,
                                        cfg.min_seconds,
                                    )
                                })
                            })
                        } else {
                            // Forced dense so a process-wide `on` mode
                            // can't silently turn this row sparse.
                            sparse::with_mode(SparseMode::Off, || {
                                simd::with_level(level, || {
                                    calibrate_kernel_shape(
                                        qt,
                                        m,
                                        k,
                                        n,
                                        &pool,
                                        cfg.min_iters,
                                        cfg.min_seconds,
                                    )
                                })
                            })
                        };
                        let meas = Measurement {
                            qtype: qt,
                            simd: level,
                            sparse: sp,
                            us_per_matmul: rate.secs_per_matmul(m, k) * 1e6,
                            gweights_per_s: rate.weights_per_s / 1e9,
                        };
                        if let Some(p) = progress.as_mut() {
                            p(&format!(
                                "tune {m}x{k} n={n} {:<9} [{:<6}]{} {:>10.1} µs/matmul ({:.2} Gw/s)",
                                qt.name(),
                                level.name(),
                                if sp { " sparse" } else { "       " },
                                meas.us_per_matmul,
                                meas.gweights_per_s
                            ));
                        }
                        measurements.push(meas);
                    }
                }
            }
            if measurements.is_empty() {
                continue;
            }
            measurements
                .sort_by(|a, b| a.us_per_matmul.partial_cmp(&b.us_per_matmul).expect("finite"));
            let best = measurements[0].qtype;
            let best_simd = measurements[0].simd;
            let best_sparse = measurements[0].sparse;
            if let Some(p) = progress.as_mut() {
                // Weighted (trace-driven) sweeps annotate each winner
                // with its traffic share — even a single-width trace
                // whose share is exactly 100%.
                let sparse_tag = if best_sparse { " sparse" } else { "" };
                if cfg.batch_weights.is_empty() {
                    p(&format!(
                        "tune {m}x{k} n={n} -> best {} [{}]{sparse_tag}",
                        best.name(),
                        best_simd.name()
                    ));
                } else {
                    p(&format!(
                        "tune {m}x{k} n={n} -> best {} [{}]{sparse_tag} ({:.1}% of traced traffic)",
                        best.name(),
                        best_simd.name(),
                        weight * 100.0
                    ));
                }
            }
            entries.push(TuningEntry { m, k, n, weight, best, best_simd, best_sparse, measurements });
        }
    }
    TuningProfile {
        threads: cfg.threads.max(1),
        default: cfg.default,
        entries,
        overrides: Vec::new(),
        e2e: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(m: usize, k: usize, n: usize, best: QuantType) -> TuningEntry {
        TuningEntry {
            m,
            k,
            n,
            weight: 1.0,
            best,
            best_simd: SimdLevel::Scalar,
            best_sparse: false,
            measurements: Vec::new(),
        }
    }

    #[test]
    fn select_prefers_largest_tuned_batch_not_above_n() {
        let p = TuningProfile {
            entries: vec![
                entry(256, 256, 1, QuantType::Tl20),
                entry(256, 256, 4, QuantType::Tq20),
                entry(256, 256, 16, QuantType::F16),
            ],
            ..TuningProfile::empty(QuantType::I2S, 2)
        };
        assert_eq!(p.select(256, 256, 1), QuantType::Tl20);
        assert_eq!(p.select(256, 256, 3), QuantType::Tl20);
        assert_eq!(p.select(256, 256, 4), QuantType::Tq20);
        assert_eq!(p.select(256, 256, 9), QuantType::Tq20);
        assert_eq!(p.select(256, 256, 100), QuantType::F16);
    }

    #[test]
    fn select_falls_back_to_smallest_batch_then_default() {
        let p = TuningProfile {
            entries: vec![entry(64, 512, 8, QuantType::Tl10)],
            ..TuningProfile::empty(QuantType::I2S, 1)
        };
        // Tuned batches all exceed n → smallest tuned batch.
        assert_eq!(p.select(64, 512, 1), QuantType::Tl10);
        // Unknown shape → default.
        assert_eq!(p.select(65, 512, 1), QuantType::I2S);
        assert_eq!(p.select(64, 513, 4), QuantType::I2S);
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let p = TuningProfile {
            threads: 4,
            default: QuantType::Tl20,
            entries: vec![TuningEntry {
                m: 768,
                k: 256,
                n: 1,
                weight: 0.625,
                best: QuantType::Tl21,
                best_simd: SimdLevel::Avx2,
                best_sparse: true,
                measurements: vec![
                    Measurement {
                        qtype: QuantType::Tl21,
                        simd: SimdLevel::Avx2,
                        sparse: true,
                        us_per_matmul: 12.5,
                        gweights_per_s: 15.7,
                    },
                    Measurement {
                        qtype: QuantType::I2S,
                        simd: SimdLevel::Scalar,
                        sparse: false,
                        us_per_matmul: 14.0,
                        gweights_per_s: 14.0,
                    },
                ],
            }],
            overrides: vec![LayerOverride {
                layer: 3,
                role: Role::Down,
                n: 4,
                qtype: QuantType::Tl20,
            }],
            e2e: vec![E2eEntry {
                label: "auto".into(),
                prefill_tok_s: 123.5,
                decode_tok_s: 45.25,
            }],
        };
        let back = TuningProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        // And through the text form too.
        let text = p.to_json().to_string_pretty();
        let back2 = TuningProfile::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back2, p);
    }

    #[test]
    fn from_json_rejects_bad_profiles() {
        assert!(TuningProfile::from_json(&Json::parse("{}").unwrap()).is_err());
        let wrong_version =
            r#"{"version": 99, "threads": 1, "default": "I2_S", "entries": []}"#;
        let err = TuningProfile::from_json(&Json::parse(wrong_version).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("supported"), "{err:#}");
        let bad_kernel =
            r#"{"version": 1, "threads": 1, "default": "NOPE", "entries": []}"#;
        assert!(TuningProfile::from_json(&Json::parse(bad_kernel).unwrap()).is_err());
        let bad_role = r#"{"version": 2, "threads": 1, "default": "I2_S", "entries": [],
            "overrides": [{"layer": 0, "role": "sideways", "n": 1, "kernel": "I2_S"}]}"#;
        assert!(TuningProfile::from_json(&Json::parse(bad_role).unwrap()).is_err());
    }

    #[test]
    fn v1_profiles_still_load() {
        // A verbatim PR-1 (version 1) profile: no overrides/e2e sections.
        let v1 = r#"{
            "version": 1, "threads": 2, "default": "I2_S",
            "entries": [{"m": 256, "k": 256, "n": 1, "best": "TL2_0", "measurements": []}]
        }"#;
        let p = TuningProfile::from_json(&Json::parse(v1).unwrap()).unwrap();
        assert_eq!(p.select(256, 256, 1), QuantType::Tl20);
        assert!(p.overrides.is_empty() && p.e2e.is_empty());
        // Re-saving migrates to the current version.
        let resaved = p.to_json();
        assert_eq!(resaved.get("version").and_then(Json::as_usize), Some(PROFILE_VERSION as usize));
    }

    #[test]
    fn layer_overrides_take_precedence_with_batch_resolution() {
        let mut p = TuningProfile::empty(QuantType::I2S, 1);
        p.entries.push(entry(256, 256, 1, QuantType::Tl20));
        p.overrides.push(LayerOverride { layer: 1, role: Role::Qkv, n: 1, qtype: QuantType::Tl11 });
        p.overrides.push(LayerOverride { layer: 1, role: Role::Qkv, n: 8, qtype: QuantType::Tl21 });
        // Overridden layer/role: batch rule applies over the overrides.
        assert_eq!(p.select_for(1, Role::Qkv, 256, 256, 1), (QuantType::Tl11, false));
        assert_eq!(p.select_for(1, Role::Qkv, 256, 256, 6), (QuantType::Tl11, false));
        assert_eq!(p.select_for(1, Role::Qkv, 256, 256, 8), (QuantType::Tl21, false));
        // Other layers / roles fall through to the shape entries…
        assert_eq!(p.select_for(0, Role::Qkv, 256, 256, 1), (QuantType::Tl20, false));
        assert_eq!(p.select_for(1, Role::O, 256, 256, 1), (QuantType::Tl20, false));
        // …and untuned shapes to the default, flagged as a fallback.
        assert_eq!(p.select_for(0, Role::Down, 512, 512, 1), (QuantType::I2S, true));
    }

    #[test]
    fn dispatch_plan_counts_fallbacks() {
        let mut p = TuningProfile::empty(QuantType::I2S, 1);
        p.entries.push(entry(256, 256, 1, QuantType::Tl20));
        let plan = DispatchPlan::new(Dispatch::Auto(p));
        assert_eq!(plan.select(0, Role::Qkv, 256, 256, 1), QuantType::Tl20);
        assert_eq!(plan.fallbacks(), 0);
        assert_eq!(plan.select(0, Role::Qkv, 512, 512, 1), QuantType::I2S);
        assert_eq!(plan.select(0, Role::Qkv, 512, 512, 1), QuantType::I2S);
        assert_eq!(plan.fallbacks(), 2);
        // Fixed never falls back.
        let fixed = DispatchPlan::new(Dispatch::Fixed(QuantType::Tl21));
        assert_eq!(fixed.select(9, Role::Up, 1, 1, 1), QuantType::Tl21);
        assert_eq!(fixed.fallbacks(), 0);
        // Degradations (resolved winner couldn't run) count separately.
        assert_eq!(fixed.degraded(), 0);
        fixed.note_degraded(256, 256, 8, QuantType::Tl21, QuantType::I2S);
        assert_eq!(fixed.degraded(), 1);
        assert_eq!(fixed.fallbacks(), 0);
    }

    #[test]
    fn vector_winner_degrades_to_usable_measurement() {
        let mut e = entry(256, 256, 1, QuantType::Tl11);
        e.best_simd = SimdLevel::Avx2;
        e.measurements = vec![
            Measurement {
                qtype: QuantType::Tl11,
                simd: SimdLevel::Avx2,
                sparse: false,
                us_per_matmul: 10.0,
                gweights_per_s: 20.0,
            },
            Measurement {
                qtype: QuantType::Tq20,
                simd: SimdLevel::Scalar,
                sparse: false,
                us_per_matmul: 15.0,
                gweights_per_s: 13.0,
            },
            Measurement {
                qtype: QuantType::Tl11,
                simd: SimdLevel::Scalar,
                sparse: false,
                us_per_matmul: 18.0,
                gweights_per_s: 11.0,
            },
        ];
        let p = TuningProfile {
            entries: vec![e],
            ..TuningProfile::empty(QuantType::I2S, 1)
        };
        // Forced scalar: the AVX2 winner is unusable, so resolution
        // re-ranks to the fastest scalar measurement and reports the
        // degrade as a fallback.
        simd::with_level(SimdLevel::Scalar, || {
            assert_eq!(p.select_traced(256, 256, 1), (QuantType::Tq20, true));
        });

        // No usable measurement recorded (hand-edited profile): keep the
        // winner's kernel — it still runs, on its scalar path.
        let mut bare = entry(64, 128, 1, QuantType::Tl10);
        bare.best_simd = SimdLevel::Neon;
        let p2 = TuningProfile {
            entries: vec![bare],
            ..TuningProfile::empty(QuantType::I2S, 1)
        };
        simd::with_level(SimdLevel::Scalar, || {
            assert_eq!(p2.select_traced(64, 128, 1), (QuantType::Tl10, true));
        });
    }

    #[test]
    fn sparse_winner_degrades_when_sparse_packing_is_off() {
        let mut e = entry(256, 256, 1, QuantType::Tl10);
        e.best_sparse = true;
        e.measurements = vec![
            Measurement {
                qtype: QuantType::Tl10,
                simd: SimdLevel::Scalar,
                sparse: true,
                us_per_matmul: 8.0,
                gweights_per_s: 25.0,
            },
            Measurement {
                qtype: QuantType::I2S,
                simd: SimdLevel::Scalar,
                sparse: false,
                us_per_matmul: 12.0,
                gweights_per_s: 16.0,
            },
            Measurement {
                qtype: QuantType::Tl10,
                simd: SimdLevel::Scalar,
                sparse: false,
                us_per_matmul: 14.0,
                gweights_per_s: 14.0,
            },
        ];
        let p = TuningProfile { entries: vec![e], ..TuningProfile::empty(QuantType::Tl20, 1) };
        // Sparse packing enabled: the sparse-tuned winner is served.
        sparse::with_mode(SparseMode::On, || {
            assert_eq!(p.select_traced(256, 256, 1), (QuantType::Tl10, false));
        });
        // Sparse packing disabled: no tensor carries the block-skip
        // index the winner was tuned with, so resolution re-ranks to the
        // fastest dense measurement and reports the degrade.
        sparse::with_mode(SparseMode::Off, || {
            assert_eq!(p.select_traced(256, 256, 1), (QuantType::I2S, true));
        });
    }

    #[test]
    fn dispatch_plan_counts_simd_degrades_as_fallbacks() {
        let mut e = entry(256, 256, 1, QuantType::Tl11);
        e.best_simd = SimdLevel::Avx2;
        e.measurements = vec![Measurement {
            qtype: QuantType::I2S,
            simd: SimdLevel::Scalar,
            sparse: false,
            us_per_matmul: 15.0,
            gweights_per_s: 13.0,
        }];
        let p = TuningProfile {
            entries: vec![e],
            ..TuningProfile::empty(QuantType::Tl20, 1)
        };
        let plan = DispatchPlan::new(Dispatch::Auto(p));
        simd::with_level(SimdLevel::Scalar, || {
            assert_eq!(plan.select(0, Role::Qkv, 256, 256, 1), QuantType::I2S);
        });
        assert_eq!(plan.fallbacks(), 1);
    }

    #[test]
    fn tune_measures_every_usable_simd_level() {
        let cfg = TuneConfig {
            shapes: vec![(16, 128)],
            batches: vec![1],
            candidates: vec![QuantType::I2S],
            min_iters: 1,
            min_seconds: 0.001,
            ..TuneConfig::default()
        };
        let profile = tune(&cfg, None);
        assert_eq!(profile.entries.len(), 1);
        let e = &profile.entries[0];
        // Every measurement ran at a level the kernel implements, at
        // most once per (level, sparse) variant, and the recorded winner
        // is the fastest.
        assert!(!e.measurements.is_empty());
        let kern_levels = kernel_for(QuantType::I2S).simd_levels();
        let mut seen: Vec<(SimdLevel, bool)> = Vec::new();
        for m in &e.measurements {
            assert!(kern_levels.contains(&m.simd));
            assert!(
                !seen.contains(&(m.simd, m.sparse)),
                "duplicate variant {:?} sparse={}",
                m.simd,
                m.sparse
            );
            seen.push((m.simd, m.sparse));
        }
        // A dense row always exists, and every sparse row is paired with
        // a dense row at the same level. (Whether sparse rows exist at
        // all depends on the process-wide sparse mode, which concurrent
        // `with_mode` tests may be forcing — don't re-read it here.)
        assert!(e.measurements.iter().any(|m| !m.sparse));
        for m in e.measurements.iter().filter(|m| m.sparse) {
            assert!(
                e.measurements.iter().any(|d| d.simd == m.simd && !d.sparse),
                "sparse measurement at {:?} lacks its dense counterpart",
                m.simd
            );
        }
        assert_eq!(
            (e.best, e.best_simd, e.best_sparse),
            (e.measurements[0].qtype, e.measurements[0].simd, e.measurements[0].sparse)
        );
        // The profile round-trips with the level fields intact.
        let back = TuningProfile::from_json(&profile.to_json()).unwrap();
        assert_eq!(back, profile);
    }

    #[test]
    fn role_names_round_trip() {
        for r in Role::ALL {
            assert_eq!(Role::parse(r.name()), Some(r));
        }
        assert_eq!(Role::parse("QKV"), Some(Role::Qkv));
        assert_eq!(Role::parse("nope"), None);
    }

    #[test]
    fn default_candidates_exclude_dense_and_general_formats() {
        let c = default_candidates();
        for q in [QuantType::I2S, QuantType::Tl20, QuantType::Tl11, QuantType::Tq10] {
            assert!(c.contains(&q), "{q:?} should be a default candidate");
        }
        for q in [QuantType::F32, QuantType::F16, QuantType::Q40, QuantType::Q2K] {
            assert!(!c.contains(&q), "{q:?} must not be packed by default auto-tuning");
        }
    }

    #[test]
    fn tune_skips_zero_batch() {
        let cfg = TuneConfig {
            shapes: vec![(16, 128)],
            batches: vec![0, 1],
            candidates: vec![QuantType::I2S],
            min_iters: 1,
            min_seconds: 0.001,
            ..TuneConfig::default()
        };
        let profile = tune(&cfg, None);
        assert_eq!(profile.entries.len(), 1);
        assert_eq!(profile.entries[0].n, 1);
    }

    #[test]
    fn weighted_batches_are_recorded_into_entries() {
        let mut cfg = TuneConfig {
            shapes: vec![(16, 128)],
            candidates: vec![QuantType::I2S],
            min_iters: 1,
            min_seconds: 0.001,
            ..TuneConfig::default()
        };
        cfg.set_weighted_batches(&[(1, 0.75), (2, 0.25)]);
        assert_eq!(cfg.batches, vec![1, 2]);
        let profile = tune(&cfg, None);
        assert_eq!(profile.entries.len(), 2);
        assert_eq!((profile.entries[0].n, profile.entries[0].weight), (1, 0.75));
        assert_eq!((profile.entries[1].n, profile.entries[1].weight), (2, 0.25));
        // Weights survive the JSON round trip.
        let back = TuningProfile::from_json(&profile.to_json()).unwrap();
        assert_eq!(back, profile);
        // Fixed sweeps (no weights supplied) record the neutral 1.0.
        let fixed = tune(
            &TuneConfig {
                shapes: vec![(16, 128)],
                batches: vec![1],
                candidates: vec![QuantType::I2S],
                min_iters: 1,
                min_seconds: 0.001,
                ..TuneConfig::default()
            },
            None,
        );
        assert_eq!(fixed.entries[0].weight, 1.0);
    }

    #[test]
    fn tune_produces_entries_with_winners() {
        let cfg = TuneConfig {
            shapes: vec![(64, 256)],
            batches: vec![1],
            candidates: vec![QuantType::I2S, QuantType::Tl10],
            min_iters: 2,
            min_seconds: 0.005,
            ..TuneConfig::default()
        };
        let mut lines = Vec::new();
        let mut sink = |s: &str| lines.push(s.to_string());
        let profile = tune(&cfg, Some(&mut sink));
        assert_eq!(profile.entries.len(), 1);
        let e = &profile.entries[0];
        assert_eq!((e.m, e.k, e.n), (64, 256, 1));
        assert!(cfg.candidates.contains(&e.best));
        // At least one measurement per candidate (more when the host runs
        // a vector tier: one row per usable SIMD level).
        assert!(e.measurements.len() >= 2, "{:?}", e.measurements);
        assert!(e.measurements[0].us_per_matmul <= e.measurements[1].us_per_matmul);
        assert!(!lines.is_empty());
        // Selection from a freshly tuned profile resolves to the winner.
        assert_eq!(profile.select(64, 256, 1), e.best);
    }

    #[test]
    fn dispatch_policies_select_as_documented() {
        let fixed = Dispatch::Fixed(QuantType::Tl21);
        assert_eq!(fixed.select(10, 20, 1), QuantType::Tl21);
        assert!(fixed.describe().contains("TL2_1"));

        let mut p = TuningProfile::empty(QuantType::I2S, 1);
        p.entries.push(entry(256, 768, 1, QuantType::Tl11));
        let auto = Dispatch::Auto(p);
        assert_eq!(auto.select(256, 768, 1), QuantType::Tl11);
        assert_eq!(auto.select(512, 512, 1), QuantType::I2S, "missing shape → default");
        assert!(auto.describe().contains("auto"));
    }
}
