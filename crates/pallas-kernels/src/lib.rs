//! # pallas-kernels — ternary mpGEMM kernel library
//!
//! The paper's TL1/TL2/I2_S kernels and every baseline they are
//! compared against ([`kernels`]), plus the perf harnesses that
//! calibrate and roofline them ([`perf`]). Sits directly above
//! [`pallas_core`] (thread pool, utilities); knows nothing about the
//! transformer or the serving stack.
//!
//! `unsafe` is confined to the explicit SIMD implementations under
//! `kernels/simd/` (intrinsics + documented `# Safety` contracts), the
//! bounds-free LUT reads in the scalar kernel hot loops, and the
//! disjoint-write pointer fan-out of the threaded matmul. Every block
//! carries a `// SAFETY:` comment; the `undocumented_unsafe_blocks`
//! clippy lint keeps it that way.

#![warn(clippy::undocumented_unsafe_blocks)]

pub mod kernels;
#[deny(unsafe_code)]
pub mod perf;

pub use kernels::{Dispatch, DispatchPlan, QuantType, Role, TuningProfile};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
