//! Appendix A complexity claims, checked against both the analytic
//! counters and the real kernels' storage/traffic numbers.

use bitnet::kernels::counters::{elut_counts, mad_counts};
use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::{kernel_for, QuantType};
use bitnet::util::Rng;

fn packed_bytes(qt: QuantType, m: usize, k: usize) -> usize {
    let mut rng = Rng::new(1);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let t = TernaryWeights::from_ternary(q, m, k, 0.1);
    kernel_for(qt).quantize(&t).weight_bytes()
}

/// ELUT compute scales as 1/g of MAD compute (Appendix A.2) across sizes.
#[test]
fn compute_ratio_scales_with_g() {
    for (m, k) in [(1024u64, 3072u64), (4096, 6144), (8192, 12288)] {
        let mad = mad_counts(m, 1, k);
        let e3 = elut_counts(m, 1, k, 3, 3, true);
        let acc_ratio = e3.lookup as f64 / (m * k) as f64;
        assert!((acc_ratio - 1.0 / 3.0).abs() < 1e-3, "{acc_ratio}");
        assert!(e3.compute_ops() < mad.compute_ops());
    }
}

/// Appendix A.3 Table 3 cross-check against *real packed tensors*:
/// element-wise storage ≤ bit-wise storage, with the exact ratios.
#[test]
fn real_storage_matches_table3() {
    let (m, k) = (64, 3072);
    let tl2 = packed_bytes(QuantType::Tl20, m, k) as f64;
    let tmac = packed_bytes(QuantType::Tmac, m, k) as f64;
    let tl1 = packed_bytes(QuantType::Tl10, m, k) as f64;
    // TL2 (1.67 bpw) vs bit-wise 2 bpw: ratio 5/6.
    assert!((tl2 / tmac - 5.0 / 6.0).abs() < 0.01, "{}", tl2 / tmac);
    // TL1 and T-MAC both 2 bpw.
    assert!((tl1 / tmac - 1.0).abs() < 1e-9);
}

/// Eq. in Appendix A.3: memory complexity of g=3 mirrored equals g=2
/// unmirrored: O(MNK·3²/2) == O(MNK·(3³/2)/3).
#[test]
fn mirror_memory_equivalence() {
    let (m, n, k) = (2048u64, 1u64, 6144u64);
    let per_group_g2: f64 = 9.0 / 2.0; // C^g/g
    let per_group_g3 = (27.0 / 2.0) / 3.0;
    assert!((per_group_g2 - per_group_g3).abs() < 1e-9);
    // And the counter model agrees to first order on act traffic per weight.
    let e2 = elut_counts(m, n, k, 3, 2, false);
    let e3 = elut_counts(m, n, k, 3, 3, true);
    let t2 = e2.act_bytes as f64 / (m * n * k) as f64;
    let t3 = e3.act_bytes as f64 / (m * n * k) as f64;
    // Both scale as 16 bytes per group per row: 16/g each.
    assert!((t2 / t3 - 1.5).abs() < 0.01, "{}", t2 / t3);
}

/// Preprocessing is O(NK·C^g/g) and independent of M (Algorithm 2).
#[test]
fn preprocessing_independent_of_m() {
    let k = 6144;
    let a = elut_counts(128, 1, k, 3, 3, true);
    let b = elut_counts(8192, 1, k, 3, 3, true);
    let build_a = a.add - a.lookup * 2; // subtract accumulation + sign adds
    let build_b = b.add - b.lookup * 2;
    assert_eq!(build_a, build_b);
}

/// Per-token weight traffic ordering drives the Table 7 speed ordering:
/// TL2 < TQ1_0 < TL1 = I2_S = TMAC < TQ2_0 < Q2_K < Q4_0 < F16.
#[test]
fn weight_traffic_ordering() {
    let (m, k) = (64, 3072);
    let b = |qt| packed_bytes(qt, m, k);
    assert!(b(QuantType::Tl20) < b(QuantType::Tq10));
    assert!(b(QuantType::Tq10) < b(QuantType::Tl10));
    assert_eq!(b(QuantType::Tl10), b(QuantType::I2S));
    assert_eq!(b(QuantType::I2S), b(QuantType::Tmac));
    assert!(b(QuantType::Tmac) < b(QuantType::Tq20));
    assert!(b(QuantType::Tq20) < b(QuantType::Q2K));
    assert!(b(QuantType::Q2K) < b(QuantType::Q40));
    assert!(b(QuantType::Q40) < b(QuantType::F16));
}
