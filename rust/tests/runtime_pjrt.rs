//! Three-layer composition: the Pallas kernel / JAX block lowered by
//! `make artifacts` executes under the Rust PJRT runtime and agrees with
//! the native Rust kernel library on the same inputs.
//!
//! These tests skip (pass vacuously, with a note) when artifacts/ has not
//! been built, so `cargo test` works pre-`make artifacts`; CI runs
//! `make test` which builds artifacts first.

use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::{kernel_for, QuantType};
use bitnet::runtime::{manifest_for, Runtime};
use bitnet::util::Rng;
use std::path::Path;

fn artifact(name: &str) -> Option<std::path::PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
    if p.exists() {
        Some(p)
    } else {
        eprintln!("SKIP: {} not built (run `make artifacts`)", p.display());
        None
    }
}

#[test]
fn pallas_kernel_matches_rust_i2s() {
    let Some(path) = artifact("ternary_matmul.hlo.txt") else { return };
    let rt = Runtime::new().unwrap();
    let exe = rt.load_hlo_text(&path).unwrap();

    // Geometry fixed by aot.py: x f32[768], w f32[256, 768], scale 0.05.
    let (m, k) = (256usize, 768usize);
    let mut rng = Rng::new(2024);
    let wq: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let w_f32: Vec<f32> = wq.iter().map(|&v| v as f32).collect();
    let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();

    let outputs = exe
        .execute_f32(&[(&x, &[k]), (&w_f32, &[m, k])])
        .expect("execute ternary_matmul artifact");
    assert_eq!(outputs.len(), 1);
    let pjrt_out = &outputs[0];
    assert_eq!(pjrt_out.len(), m);

    // Rust-native result through the lossless I2_S path, same scale 0.05.
    let t = TernaryWeights::from_ternary(wq, m, k, 0.05);
    let kern = kernel_for(QuantType::I2S);
    let packed = kern.quantize(&t);
    let p = kern.prepare(&x, k);
    let mut rust_out = vec![0f32; m];
    kern.gemv(&packed, &p, &mut rust_out);

    let mut max_rel = 0f64;
    for (a, b) in pjrt_out.iter().zip(rust_out.iter()) {
        let rel = ((a - b).abs() as f64) / (b.abs() as f64).max(1e-3);
        max_rel = max_rel.max(rel);
    }
    // Both paths compute the identical integer sum; only the final f32
    // rescale ordering can differ by an ulp.
    assert!(max_rel < 1e-5, "PJRT vs Rust I2_S max rel {max_rel}");
}

#[test]
fn ffn_artifact_executes_with_real_shapes() {
    let Some(path) = artifact("bitnet_ffn.hlo.txt") else { return };
    let rt = Runtime::new().unwrap();
    let exe = rt.load_hlo_text(&path).unwrap();
    let entry = manifest_for(&path).expect("manifest entry");
    let out = exe.execute_random(&entry).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), 256); // H of the tiny config
    assert!(out[0].iter().all(|v| v.is_finite()));
}

#[test]
fn block_artifact_decode_step_shapes() {
    let Some(path) = artifact("bitnet_block.hlo.txt") else { return };
    let rt = Runtime::new().unwrap();
    let exe = rt.load_hlo_text(&path).unwrap();
    let entry = manifest_for(&path).expect("manifest entry");
    assert_eq!(entry.input_shapes.len(), 13);
    let out = exe.execute_random(&entry).unwrap();
    // (x', k_new, v_new)
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].len(), 256);
    assert_eq!(out[1].len(), 128);
    assert_eq!(out[2].len(), 128);
}

#[test]
fn manifest_shapes_parse() {
    let Some(path) = artifact("manifest.toml") else { return };
    let cfg = bitnet::config::Config::load(&path).unwrap();
    assert!(cfg.get("ternary_matmul.inputs").is_some());
}
