//! End-to-end prefix-sharing and chunked-prefill tests against the
//! serving engine: the acceptance criteria of the COW + radix-index PR.
//!
//! * A shared system prompt must let the same KV budget co-run at least
//!   2x the sequences of the no-sharing engine, with the shared prefix
//!   prefilled exactly once (the prefill-token counter proves it).
//! * Every sequence's tokens must be bit-identical to its unshared run —
//!   including after copy-on-write splits and preemption round-trips.
//! * Chunked prefill must be bit-identical to whole-prompt prefill for
//!   every chunk size and KV dtype, including re-prefill after a
//!   preemption.
//!
//! Greedy outputs are batch-composition-invariant (pinned by
//! `serving::batched_output_matches_sequential_output`), so the output
//! assertions here are robust to submit-timing races; the concurrency
//! and accounting assertions are made deterministic by waiting out the
//! seed request before the sharers are submitted (prompts are indexed at
//! prefill completion, so a prefix can only be mapped after its donor
//! finished prefilling).

use bitnet::coordinator::{Engine, EngineConfig, KvDtype, Request};
use bitnet::kernels::QuantType;
use bitnet::model::{ModelConfig, Transformer};
use std::sync::atomic::Ordering;

fn tiny_model() -> Transformer {
    Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 5)
}

/// 8-page arena, 66-token prompts sharing a 64-token (4 full pages)
/// system prefix, 6 new tokens each: unshared, every sequence needs 5
/// pages (67-token watermark) so the arena serializes them; shared, the
/// 4 index pages plus one private tail page each co-run all four
/// followers.
#[test]
fn shared_system_prompt_doubles_admitted_concurrency() {
    let system: Vec<u32> = (0u32..64).map(|i| (i * 7 + 3) % 512).collect();
    let prompts: Vec<Vec<u32>> = (0u32..5)
        .map(|i| {
            let mut p = system.clone();
            p.extend_from_slice(&[400 + i, 300 + i]);
            p
        })
        .collect();
    let run = |prefix_cache: bool, prefill_chunk: usize| {
        let engine = Engine::start(
            tiny_model(),
            EngineConfig {
                max_batch: 4,
                kv_budget_tokens: 128,
                seed: 7,
                prefix_cache,
                prefill_chunk,
                ..Default::default()
            },
        );
        // The seed request runs alone: its prompt pages enter the radix
        // index when its prefill completes, before any sharer submits.
        let mut outs = vec![engine.submit(Request::greedy(prompts[0].clone(), 6)).wait().0];
        let handles: Vec<_> =
            prompts[1..].iter().map(|p| engine.submit(Request::greedy(p.clone(), 6))).collect();
        outs.extend(handles.into_iter().map(|h| h.wait().0));
        let m = &engine.metrics;
        (
            outs,
            m.peak_batch.load(Ordering::Relaxed),
            m.prefill_tokens_computed.load(Ordering::Relaxed),
            m.prefix_hit_tokens.load(Ordering::Relaxed),
        )
    };

    let (outs_off, peak_off, computed_off, hit_off) = run(false, 0);
    assert_eq!(hit_off, 0);
    assert_eq!(computed_off, 5 * 66, "no sharing: every prompt prefills in full");
    assert_eq!(peak_off, 1, "5 pages per sequence serialize an 8-page arena");

    let (outs_on, peak_on, computed_on, hit_on) = run(true, 0);
    assert_eq!(outs_on, outs_off, "sharing must not change any sequence's tokens");
    assert_eq!(hit_on, 4 * 64, "each follower maps the 4 indexed system pages");
    assert_eq!(computed_on, 66 + 4 * 2, "the shared prefix prefilled exactly once");
    assert!(
        peak_on >= 2 * peak_off,
        "same budget must co-run >= 2x the sequences (got {peak_on} vs {peak_off})"
    );

    // Chunked streaming composes with sharing: same tokens, and still a
    // single prefill of the shared prefix.
    let (outs_chunked, _, computed_chunked, hit_chunked) = run(true, 16);
    assert_eq!(outs_chunked, outs_off);
    assert_eq!((computed_chunked, hit_chunked), (66 + 4 * 2, 4 * 64));
}

/// Identical resubmission maps 31 of 32 tokens (the cap keeps the last
/// token prefillable), so its first write lands in a shared page and
/// must COW-split it; a prompt diverging mid-page shares only the fully
/// matching page. Both must decode bit-identically to fresh engines.
#[test]
fn cow_splits_and_divergence_keep_outputs_bit_identical() {
    let prompt: Vec<u32> = (0u32..32).map(|i| (i * 11 + 2) % 512).collect();
    let mut diverging = prompt.clone();
    for t in diverging[16..].iter_mut() {
        *t += 100; // second page differs, first page matches
    }
    let fresh = |p: &[u32]| {
        let engine = Engine::start(
            tiny_model(),
            EngineConfig { max_batch: 2, seed: 7, prefix_cache: true, ..Default::default() },
        );
        engine.submit(Request::greedy(p.to_vec(), 6)).wait().0
    };
    let (a_ref, c_ref) = (fresh(&prompt), fresh(&diverging));

    let engine = Engine::start(
        tiny_model(),
        EngineConfig { max_batch: 2, seed: 7, prefix_cache: true, ..Default::default() },
    );
    let a = engine.submit(Request::greedy(prompt.clone(), 6)).wait().0;
    let b = engine.submit(Request::greedy(prompt.clone(), 6)).wait().0;
    let c = engine.submit(Request::greedy(diverging.clone(), 6)).wait().0;
    assert_eq!(a, a_ref);
    assert_eq!(b, a_ref, "resubmission decodes bit-identically off shared pages");
    assert_eq!(c, c_ref, "mid-prompt divergence maps only the matching page");

    let m = &engine.metrics;
    assert!(
        m.kv_cow_splits.load(Ordering::Relaxed) >= 1,
        "writing the last prompt token into a shared page must split it"
    );
    assert_eq!(m.prefix_hit_tokens.load(Ordering::Relaxed), 31 + 16);
    assert_eq!(m.prefill_tokens_computed.load(Ordering::Relaxed), 32 + 1 + 16);
}

/// Two sharers of a one-page system prompt in a 4-page arena: their
/// decode growth exhausts the arena, the newest is preempted (losing its
/// mapping) and re-prefills from scratch on re-admission — and every
/// token stream still matches a roomy unshared engine.
#[test]
fn preempted_sharer_reprefills_and_matches_unshared_outputs() {
    let system: Vec<u32> = (0u32..16).map(|i| (i * 5 + 1) % 512).collect();
    let prompts: Vec<Vec<u32>> = [[200u32, 201], [210, 211], [220, 221]]
        .iter()
        .map(|tail| {
            let mut p = system.clone();
            p.extend_from_slice(tail);
            p
        })
        .collect();
    let reference: Vec<Vec<u32>> = {
        let engine = Engine::start(
            tiny_model(),
            EngineConfig { max_batch: 2, seed: 7, ..Default::default() },
        );
        prompts.iter().map(|p| engine.submit(Request::greedy(p.clone(), 20)).wait().0).collect()
    };

    let engine = Engine::start(
        tiny_model(),
        EngineConfig {
            max_batch: 2,
            kv_budget_tokens: 64, // 4 pages
            seed: 7,
            prefix_cache: true,
            ..Default::default()
        },
    );
    let first = engine.submit(Request::greedy(prompts[0].clone(), 20)).wait().0;
    let handles: Vec<_> =
        prompts[1..].iter().map(|p| engine.submit(Request::greedy(p.clone(), 20))).collect();
    let rest: Vec<Vec<u32>> = handles.into_iter().map(|h| h.wait().0).collect();
    assert_eq!(first, reference[0]);
    assert_eq!(rest, reference[1..], "preempted sharer must reproduce its unshared tokens");

    let m = &engine.metrics;
    assert!(
        m.kv_preemptions.load(Ordering::Relaxed) >= 1,
        "growth past the 4-page arena must preempt one sharer"
    );
    assert_eq!(
        m.prefix_hit_tokens.load(Ordering::Relaxed),
        2 * 16,
        "both sharers mapped the system page at submit; re-admission re-prefills instead"
    );
}

/// One run of the preemption-pressure workload: two 16-token prompts,
/// 33 new tokens each, under `budget` KV tokens with the given prefill
/// chunk and page dtype.
fn run_pressure(budget: usize, chunk: usize, dtype: KvDtype) -> (Vec<Vec<u32>>, u64) {
    let engine = Engine::start(
        tiny_model(),
        EngineConfig {
            max_batch: 4,
            kv_budget_tokens: budget,
            seed: 7,
            kv_dtype: dtype,
            prefill_chunk: chunk,
            ..Default::default()
        },
    );
    let prompts: Vec<Vec<u32>> = vec![(3..19).collect(), (103..119).collect()];
    let handles: Vec<_> =
        prompts.iter().map(|p| engine.submit(Request::greedy(p.clone(), 33))).collect();
    let outs = handles.into_iter().map(|h| h.wait().0).collect();
    (outs, engine.metrics.kv_preemptions.load(Ordering::Relaxed))
}

/// Chunked prefill must be bit-identical to whole-prompt prefill for
/// every chunk size (one page, three pages, unbounded) and both KV
/// dtypes — under an arena tight enough that preemption forces chunked
/// *re*-prefill too.
#[test]
fn chunked_prefill_bit_identical_across_chunk_sizes_and_dtypes() {
    for dtype in [KvDtype::F32, KvDtype::F16] {
        let (reference, _) = run_pressure(4096, 0, dtype);
        for chunk in [0usize, 16, 48] {
            let (outs, preemptions) = run_pressure(64, chunk, dtype);
            assert_eq!(
                outs,
                reference,
                "chunk={chunk} dtype={} diverged from whole-prompt prefill",
                dtype.name()
            );
            assert!(
                preemptions >= 1,
                "the 4-page arena must exercise re-prefill after preemption (chunk={chunk})"
            );
        }
    }
}
