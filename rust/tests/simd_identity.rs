//! Scalar≡SIMD differential harness: every kernel, at every SIMD tier
//! this host can run, must produce *bit-identical* output to the
//! forced-scalar path — through both the standalone `gemv` path and the
//! prepare-once `matmul_prepared` path. The shapes are adversarial on
//! purpose: K at the kernel's minimum (shorter than one vector
//! register's worth of work), K an odd multiple of the alignment (so
//! every remainder loop runs), M not a multiple of the 16-row SIMD tile,
//! and degenerate all-zero / all-(±1) weight matrices. The block-skip
//! sparse layout is held to the same bar: sparse ≡ dense ≡ scalar,
//! bit for bit, at every tier.
//!
//! Every computation in this binary runs inside `simd::with_level`,
//! which serializes on the kernel layer's force lock — so concurrent
//! tests never observe each other's forced tier.

use bitnet::coordinator::kv_pool::{AttnWorkspace, KvArena, KvDtype};
use bitnet::kernels::quant::{quantize_act_int8, training_scheme_ref_row, TernaryWeights};
use bitnet::kernels::sparse::{self, SparseMode, SPARSE_THRESHOLD};
use bitnet::kernels::{
    kernel_for, matmul_prepared, simd, Kernel, PreparedActivations, QTensor, QuantType, SimdLevel,
};
use bitnet::threadpool::ThreadPool;
use bitnet::util::Rng;

fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
    let mut rng = Rng::new(seed);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    TernaryWeights::from_ternary(q, m, k, 0.05)
}

/// Ternary weights with whole 384-column stripes zeroed — the *same*
/// columns in every row, so multi-row vector tiles can elide too. 384
/// is a common multiple of every sparse kernel's block span (64 for
/// TL1/ELUT, 128 for I2_S, 96 for TL2's trio region), so each zeroed
/// stripe is a run of entirely-zero blocks for every kernel. Stripes
/// `s` with `s * 3 % 5 < 3` are zeroed: 3 of every 5 ⇒ 60% zero blocks
/// when `k` is a multiple of 1920, enough to clear the pack threshold.
fn block_sparse_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
    assert_eq!(k % 384, 0, "stripes must tile k");
    let mut rng = Rng::new(seed);
    let q: Vec<i8> = (0..m * k)
        .map(|i| {
            let s = (i % k) / 384;
            if s * 3 % 5 < 3 {
                0
            } else {
                rng.next_ternary() as i8
            }
        })
        .collect();
    TernaryWeights::from_ternary(q, m, k, 0.05)
}

/// Standalone prepare + gemv under a forced SIMD tier.
fn gemv_at(
    kern: &'static dyn Kernel,
    packed: &QTensor,
    x: &[f32],
    m: usize,
    k: usize,
    level: SimdLevel,
) -> Vec<f32> {
    simd::with_level(level, || {
        let p = kern.prepare(x, k);
        let mut out = vec![0f32; m];
        kern.gemv(packed, &p, &mut out);
        out
    })
}

/// Prepare-once path (`PreparedBatch::build` → `prepare_row_into` →
/// `matmul_prepared`) under a forced SIMD tier.
fn matmul_prepared_at(
    kern: &'static dyn Kernel,
    packed: &QTensor,
    x: &[f32],
    (m, k, n): (usize, usize, usize),
    pool: &ThreadPool,
    level: SimdLevel,
) -> Vec<f32> {
    simd::with_level(level, || {
        let mut acts = PreparedActivations::new();
        acts.begin_input();
        let mut out = vec![0f32; n * m];
        let batch = acts.get_or_prepare(kern, x, k, n, pool);
        matmul_prepared(kern, packed, batch, x, n, &mut out, pool);
        out
    })
}

/// The SIMD tiers to exercise. Scalar is included so the harness is
/// self-checking (scalar ≡ scalar) even on hosts with no vector unit.
fn levels() -> Vec<SimdLevel> {
    simd::available_levels()
}

/// Every kernel × every tier × adversarial (m, k): single row, M=17
/// (not a multiple of the 16-row tile), K at the kernel's minimum
/// alignment (shorter than one register of work for the vector paths),
/// and K an odd multiple (×13) so remainder loops run.
#[test]
fn gemv_bit_identical_across_simd_levels() {
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let kmul = kern.info().k_multiple;
        for (m, k) in [(1usize, kmul.max(4)), (17, kmul * 13), (48, 768)] {
            assert_eq!(k % kmul, 0, "{qt:?}: test shape must fit the kernel");
            let t = random_ternary(m, k, 7 + m as u64);
            let packed = kern.quantize(&t);
            let mut rng = Rng::new(900 + k as u64);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let reference = gemv_at(kern, &packed, &x, m, k, SimdLevel::Scalar);
            assert!(reference.iter().all(|v| v.is_finite()), "{qt:?} ({m},{k}): finite");
            for level in levels() {
                let out = gemv_at(kern, &packed, &x, m, k, level);
                assert_eq!(
                    out,
                    reference,
                    "{qt:?} ({m},{k}) at {}: gemv must be bit-identical to scalar",
                    level.name()
                );
            }
        }
    }
}

/// The batched prepare-once path at n ∈ {1, 8, 33} — the same contract,
/// through `PreparedBatch` and the tiled parallel accumulator.
#[test]
fn matmul_prepared_bit_identical_across_simd_levels() {
    let (m, k) = (48, 768);
    let pool = ThreadPool::new(4);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let t = random_ternary(m, k, 19);
        let packed = kern.quantize(&t);
        for n in [1usize, 8, 33] {
            let mut rng = Rng::new(50 + n as u64);
            let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
            let reference =
                matmul_prepared_at(kern, &packed, &x, (m, k, n), &pool, SimdLevel::Scalar);
            for level in levels() {
                let out = matmul_prepared_at(kern, &packed, &x, (m, k, n), &pool, level);
                assert_eq!(
                    out,
                    reference,
                    "{qt:?} n={n} at {}: matmul_prepared must be bit-identical to scalar",
                    level.name()
                );
            }
        }
    }
}

/// Degenerate weight matrices: all-zero and all-(+1)/all-(−1). These hit
/// the LUT paths with constant indices and the I2_S path with codes at
/// both extremes of the 2-bit range.
#[test]
fn degenerate_weights_bit_identical_across_levels() {
    let (m, k) = (8, 768);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        for (label, w) in [("zero", 0i8), ("plus", 1), ("minus", -1)] {
            let t = TernaryWeights::from_ternary(vec![w; m * k], m, k, 0.05);
            let packed = kern.quantize(&t);
            let mut rng = Rng::new(77);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let reference = gemv_at(kern, &packed, &x, m, k, SimdLevel::Scalar);
            for level in levels() {
                let out = gemv_at(kern, &packed, &x, m, k, level);
                assert_eq!(out, reference, "{qt:?} all-{label} at {}", level.name());
            }
        }
    }
}

/// Fixed-seed tail regression: K chosen as k_multiple × 37 — odd, not a
/// multiple of any 8/16/32-group blocking — so every kernel's final
/// scale block is short and every vector path runs its remainder loop.
/// n = 3 routes through `prepare_row_into` with that short final block.
#[test]
fn tail_blocks_pinned_by_fixed_seed_cases() {
    let pool = ThreadPool::new(2);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let kmul = kern.info().k_multiple;
        let (m, k, n) = (17usize, kmul.max(4) * 37, 3usize);
        let t = random_ternary(m, k, 123);
        let packed = kern.quantize(&t);
        let mut rng = Rng::new(321);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let reference = matmul_prepared_at(kern, &packed, &x, (m, k, n), &pool, SimdLevel::Scalar);
        // Cross-check the scalar shared path against per-row standalone
        // prepare before comparing tiers, so a tail bug shared by every
        // tier cannot hide.
        simd::with_level(SimdLevel::Scalar, || {
            for i in 0..n {
                let p = kern.prepare(&x[i * k..(i + 1) * k], k);
                let mut per_row = vec![0f32; m];
                kern.gemv(&packed, &p, &mut per_row);
                assert_eq!(
                    &reference[i * m..(i + 1) * m],
                    &per_row[..],
                    "{qt:?} k={k} row {i}: shared vs per-row prepare (scalar)"
                );
            }
        });
        for level in levels() {
            let out = matmul_prepared_at(kern, &packed, &x, (m, k, n), &pool, level);
            assert_eq!(out, reference, "{qt:?} k={k} tail at {}", level.name());
        }
    }
}

/// The prepare-phase LUT table build is vectorized too, so it gets its
/// own lockdown: compare the prepared activation buffers themselves
/// (int16 tables for the lossless kernels, int8 tables + block scales
/// for the requantized ones) between forced-scalar and every tier, so a
/// compensating accumulation bug cannot mask a table-builder bug. K
/// shapes hit the kernel minimum, an odd ×13 multiple, and a large
/// multi-block row (1920 also exercises TL2's trio/tail split).
#[test]
fn lut_table_build_bit_identical_across_simd_levels() {
    use bitnet::kernels::Prepared;
    let luts = [
        QuantType::Tl10,
        QuantType::Tl11,
        QuantType::Tl20,
        QuantType::Tl21,
        QuantType::Elut4,
        QuantType::Elut5,
    ];
    for qt in luts {
        let kern = kernel_for(qt);
        let kmul = kern.info().k_multiple;
        for k in [kmul.max(4), kmul * 13, 1920] {
            let mut rng = Rng::new(1000 + k as u64);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let reference = simd::with_level(SimdLevel::Scalar, || kern.prepare(&x, k));
            for level in levels() {
                let p = simd::with_level(level, || kern.prepare(&x, k));
                match (&reference, &p) {
                    (
                        Prepared::LutI16 { tables: ta, scale: sa },
                        Prepared::LutI16 { tables: tb, scale: sb },
                    ) => {
                        assert_eq!(sa, sb, "{qt:?} k={k} at {}: act scale", level.name());
                        assert_eq!(ta, tb, "{qt:?} k={k} at {}: int16 tables", level.name());
                    }
                    (
                        Prepared::LutI8 { tables: ta, block_scales: ba, scale: sa, .. },
                        Prepared::LutI8 { tables: tb, block_scales: bb, scale: sb, .. },
                    ) => {
                        assert_eq!(sa, sb, "{qt:?} k={k} at {}: act scale", level.name());
                        assert_eq!(ba, bb, "{qt:?} k={k} at {}: block scales", level.name());
                        assert_eq!(ta, tb, "{qt:?} k={k} at {}: int8 tables", level.name());
                    }
                    _ => panic!("{qt:?}: prepared kinds must match across tiers"),
                }
            }
        }
    }
}

/// The lossless kernels must stay bit-exact against the integer
/// training-scheme reference *through every vector path*, not just
/// match scalar: LUT gathers and maddubs-style accumulation must
/// reproduce the exact per-block integer sums.
#[test]
fn lossless_kernels_training_scheme_exact_at_every_level() {
    let (m, k) = (16, 768);
    for qt in [QuantType::I2S, QuantType::Tl11, QuantType::Tl21] {
        let kern = kernel_for(qt);
        let t = random_ternary(m, k, 41);
        let packed = kern.quantize(&t);
        let mut rng = Rng::new(42);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let act = quantize_act_int8(&x);
        for level in levels() {
            let out = gemv_at(kern, &packed, &x, m, k, level);
            for r in 0..m {
                assert_eq!(
                    out[r],
                    training_scheme_ref_row(t.row(r), t.scale, &act),
                    "{qt:?} row {r} at {}: training-scheme exactness",
                    level.name()
                );
            }
        }
    }
}

/// The tentpole contract: for every sparse-capable kernel, the
/// block-skip layout is bit-identical to the dense layout at every SIMD
/// tier — same packed bytes, same outputs, only the zero blocks'
/// gather/accumulate/scale-fold elided. Shapes cover a single row, a
/// 17-row matrix (one short vector tile), and a 48-row matrix over a
/// 60%-zero-block stripe pattern.
#[test]
fn sparse_layout_bit_identical_to_dense_across_levels() {
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        if !kern.sparse_capable() {
            continue;
        }
        for (m, k) in [(1usize, 384usize), (17, 768), (48, 1920)] {
            assert_eq!(k % kern.info().k_multiple, 0, "{qt:?}: test shape must fit the kernel");
            let t = block_sparse_ternary(m, k, 11 + m as u64);
            let dense = sparse::with_mode(SparseMode::Off, || kern.quantize(&t));
            let sp = sparse::with_mode(SparseMode::On, || kern.quantize(&t));
            assert!(dense.sparse.is_none(), "{qt:?}: forced-off packing must stay dense");
            let idx = sp.sparse.as_ref().expect("forced-on packing must attach the index");
            assert!(
                idx.nonzero_blocks() < idx.total_blocks(),
                "{qt:?} ({m},{k}): stripes must form whole zero blocks"
            );
            assert_eq!(
                dense.data, sp.data,
                "{qt:?} ({m},{k}): the index is purely additive — packed bytes unchanged"
            );
            let mut rng = Rng::new(400 + k as u64);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let reference = gemv_at(kern, &dense, &x, m, k, SimdLevel::Scalar);
            for level in levels() {
                let out_dense = gemv_at(kern, &dense, &x, m, k, level);
                let out_sparse = gemv_at(kern, &sp, &x, m, k, level);
                assert_eq!(
                    out_dense,
                    reference,
                    "{qt:?} ({m},{k}) dense at {}",
                    level.name()
                );
                assert_eq!(
                    out_sparse,
                    reference,
                    "{qt:?} ({m},{k}) at {}: block-skip must be bit-identical to dense scalar",
                    level.name()
                );
            }
        }
    }
}

/// The same contract through the batched prepare-once path: row-range
/// partitioning across pool threads, 16-row vector tiles with their
/// tile-OR skip test, and remainder rows — sparse ≡ dense scalar at
/// every tier and batch width.
#[test]
fn matmul_prepared_sparse_identical_to_dense() {
    let (m, k) = (48, 1920);
    let pool = ThreadPool::new(4);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        if !kern.sparse_capable() {
            continue;
        }
        let t = block_sparse_ternary(m, k, 21);
        let dense = sparse::with_mode(SparseMode::Off, || kern.quantize(&t));
        let sp = sparse::with_mode(SparseMode::On, || kern.quantize(&t));
        assert!(sp.sparse.is_some());
        for n in [1usize, 8, 33] {
            let mut rng = Rng::new(60 + n as u64);
            let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
            let reference =
                matmul_prepared_at(kern, &dense, &x, (m, k, n), &pool, SimdLevel::Scalar);
            for level in levels() {
                let out = matmul_prepared_at(kern, &sp, &x, (m, k, n), &pool, level);
                assert_eq!(
                    out,
                    reference,
                    "{qt:?} n={n} at {}: sparse matmul_prepared must match dense scalar",
                    level.name()
                );
            }
        }
    }
}

/// Pack-time gating: iid ternary (~1/3 zero *weights* but essentially
/// zero whole zero *blocks*) must stay dense under `Auto`, while the
/// 60%-zero-block stripe tensor must clear [`SPARSE_THRESHOLD`] and get
/// the layout automatically — the below-threshold fallback the issue
/// requires, asserted per kernel.
#[test]
fn pack_time_threshold_gates_the_layout() {
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        if !kern.sparse_capable() {
            continue;
        }
        let iid = random_ternary(8, 1920, 33);
        let packed = sparse::with_mode(SparseMode::Auto, || kern.quantize(&iid));
        assert!(
            packed.sparse.is_none(),
            "{qt:?}: iid ternary has no whole zero blocks — auto must keep it dense"
        );
        let blocked = block_sparse_ternary(8, 1920, 34);
        let packed = sparse::with_mode(SparseMode::Auto, || kern.quantize(&blocked));
        let idx = packed
            .sparse
            .as_ref()
            .expect("60% zero blocks must clear the auto threshold");
        assert!(
            idx.zero_block_fraction() >= SPARSE_THRESHOLD,
            "{qt:?}: measured fraction {} below threshold yet the layout attached",
            idx.zero_block_fraction()
        );
    }
}

/// A one-layer arena holding `ctx` random K/V rows for sequence 7.
fn filled_arena(
    kv_dim: usize,
    ctx: usize,
    dtype: KvDtype,
    page_tokens: usize,
    seed: u64,
) -> KvArena {
    let mut arena = KvArena::with_page_tokens(1, kv_dim, 8192, dtype, page_tokens);
    assert!(arena.reserve(7, ctx));
    let mut rng = Rng::new(seed);
    for pos in 0..ctx {
        let k: Vec<f32> = (0..kv_dim).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f32> = (0..kv_dim).map(|_| rng.next_gaussian()).collect();
        arena.append(7, 0, pos, &k, &v);
    }
    arena
}

/// The paged fused attend must be bit-identical to the forced-scalar
/// reference at every SIMD tier, across KV dtypes (f16 decodes *inside*
/// the vector loops), GQA group sizes (incl. MQA), page sizes from
/// maximal straddling (1) to the contiguous degenerate (4096), and
/// ragged context lengths hitting page boundaries and remainder loops.
#[test]
fn attend_bit_identical_across_simd_levels() {
    for dtype in [KvDtype::F32, KvDtype::F16] {
        for (n_heads, n_kv_heads) in [(4usize, 4usize), (8, 2), (5, 1)] {
            for head_dim in [8usize, 12] {
                let kv_dim = n_kv_heads * head_dim;
                for page_tokens in [1usize, 3, 16, 4096] {
                    for ctx in [1usize, 16, 17, 33] {
                        let arena =
                            filled_arena(kv_dim, ctx, dtype, page_tokens, 70 + ctx as u64);
                        let mut rng = Rng::new(71);
                        let q: Vec<f32> =
                            (0..n_heads * head_dim).map(|_| rng.next_gaussian()).collect();
                        let scale = 1.0 / (head_dim as f32).sqrt();
                        let attend_at = |level: SimdLevel| {
                            simd::with_level(level, || {
                                let mut out = vec![0f32; n_heads * head_dim];
                                arena.attend(
                                    7, 0, &q, ctx, n_heads, n_kv_heads, head_dim, scale,
                                    &mut out,
                                );
                                out
                            })
                        };
                        let reference = attend_at(SimdLevel::Scalar);
                        assert!(reference.iter().all(|v| v.is_finite()));
                        for level in levels() {
                            assert_eq!(
                                attend_at(level),
                                reference,
                                "{dtype:?} {n_heads}h/{n_kv_heads}kv hd={head_dim} \
                                 page={page_tokens} ctx={ctx} at {}",
                                level.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Head-parallel attend through a persistent workspace must be
/// bit-identical to the serial no-pool path (head order and pool size
/// cannot change a single bit), and the workspace must allocate once
/// then reuse.
#[test]
fn attend_pooled_workspace_bit_identical_to_serial() {
    let (n_heads, n_kv_heads, head_dim) = (8usize, 4usize, 16usize);
    let kv_dim = n_kv_heads * head_dim;
    // n_heads * ctx = 1040 ≥ 512 crosses the head-parallel threshold.
    let ctx = 130usize;
    let pool = ThreadPool::new(4);
    for dtype in [KvDtype::F32, KvDtype::F16] {
        let arena = filled_arena(kv_dim, ctx, dtype, 16, 90);
        let mut rng = Rng::new(91);
        let q: Vec<f32> = (0..n_heads * head_dim).map(|_| rng.next_gaussian()).collect();
        let scale = 1.0 / (head_dim as f32).sqrt();
        let mut reference = vec![0f32; n_heads * head_dim];
        arena.attend(7, 0, &q, ctx, n_heads, n_kv_heads, head_dim, scale, &mut reference);
        let mut ws = AttnWorkspace::new();
        for level in levels() {
            for round in 0..2 {
                let out = simd::with_level(level, || {
                    let mut out = vec![0f32; n_heads * head_dim];
                    arena.attend_with(
                        &mut ws,
                        7,
                        0,
                        &q,
                        ctx,
                        n_heads,
                        n_kv_heads,
                        head_dim,
                        scale,
                        &mut out,
                        Some(&pool),
                    );
                    out
                });
                assert_eq!(
                    out,
                    reference,
                    "{dtype:?} round {round} at {}: pooled attend must match serial",
                    level.name()
                );
            }
        }
        assert_eq!(ws.allocs(), 1, "{dtype:?}: one sizing allocation");
        assert!(ws.reuses() >= 1, "{dtype:?}: later rounds reuse the score buffer");
    }
}

/// The vectorized non-matmul ops (rmsnorm, rope, swiglu, softmax) are
/// held to the same bar: bit-identical to forced scalar at every tier,
/// at lengths covering sub-register slices, exact register multiples,
/// and remainder tails.
#[test]
fn model_ops_bit_identical_across_simd_levels() {
    use bitnet::model::ops::{rmsnorm, rope, swiglu};
    for n in [1usize, 7, 64, 65, 256] {
        let mut rng = Rng::new(500 + n as u64);
        let x: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let gain: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let up: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let eval = |level: SimdLevel| {
            simd::with_level(level, || {
                let mut normed = vec![0f32; n];
                rmsnorm(&x, &gain, 1e-5, &mut normed);
                let mut act = vec![0f32; n];
                swiglu(&x, &up, &mut act);
                let mut sm = x.clone();
                bitnet::util::softmax(&mut sm);
                (normed, act, sm)
            })
        };
        let reference = eval(SimdLevel::Scalar);
        for level in levels() {
            assert_eq!(eval(level), reference, "ops n={n} at {}", level.name());
        }
    }
    // RoPE separately: head_dim spans sub-block, unaligned, and
    // multi-block (the sin/cos table block is 64 pairs).
    for head_dim in [8usize, 20, 160] {
        let n_heads = 3usize;
        let mut rng = Rng::new(600 + head_dim as u64);
        let x0: Vec<f32> = (0..n_heads * head_dim).map(|_| rng.next_gaussian()).collect();
        let eval = |level: SimdLevel| {
            simd::with_level(level, || {
                let mut x = x0.clone();
                rope(&mut x, n_heads, head_dim, 17, 10000.0);
                x
            })
        };
        let reference = eval(SimdLevel::Scalar);
        for level in levels() {
            assert_eq!(eval(level), reference, "rope hd={head_dim} at {}", level.name());
        }
    }
}

/// The scalar sparse path must actually *count* what it skips: one
/// full-matrix gemv over the striped tensor elides at least the
/// tensor's total zero blocks (the counter is global and monotonic, so
/// concurrent tests can only push it higher).
#[test]
fn scalar_sparse_gemv_reports_elided_blocks() {
    let (m, k) = (4, 1920);
    let kern = kernel_for(QuantType::I2S);
    let t = block_sparse_ternary(m, k, 5);
    let sp = sparse::with_mode(SparseMode::On, || kern.quantize(&t));
    let idx = sp.sparse.as_ref().expect("forced-on packing must attach the index");
    let zero_blocks = (idx.total_blocks() - idx.nonzero_blocks()) as u64;
    assert!(zero_blocks > 0, "striped tensor must have zero blocks");
    let mut rng = Rng::new(88);
    let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
    let before = sparse::elided_counts()[SimdLevel::Scalar as usize];
    let _ = gemv_at(kern, &sp, &x, m, k, SimdLevel::Scalar);
    let after = sparse::elided_counts()[SimdLevel::Scalar as usize];
    assert!(
        after - before >= zero_blocks,
        "scalar sparse gemv must report its elided blocks: +{} < {zero_blocks}",
        after - before
    );
}
