//! Scalar≡SIMD differential harness: every kernel, at every SIMD tier
//! this host can run, must produce *bit-identical* output to the
//! forced-scalar path — through both the standalone `gemv` path and the
//! prepare-once `matmul_prepared` path. The shapes are adversarial on
//! purpose: K at the kernel's minimum (shorter than one vector
//! register's worth of work), K an odd multiple of the alignment (so
//! every remainder loop runs), M not a multiple of the 16-row SIMD tile,
//! and degenerate all-zero / all-(±1) weight matrices.
//!
//! Every computation in this binary runs inside `simd::with_level`,
//! which serializes on the kernel layer's force lock — so concurrent
//! tests never observe each other's forced tier.

use bitnet::kernels::quant::{quantize_act_int8, training_scheme_ref_row, TernaryWeights};
use bitnet::kernels::{
    kernel_for, matmul_prepared, simd, Kernel, PreparedActivations, QTensor, QuantType, SimdLevel,
};
use bitnet::threadpool::ThreadPool;
use bitnet::util::Rng;

fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
    let mut rng = Rng::new(seed);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    TernaryWeights::from_ternary(q, m, k, 0.05)
}

/// Standalone prepare + gemv under a forced SIMD tier.
fn gemv_at(
    kern: &'static dyn Kernel,
    packed: &QTensor,
    x: &[f32],
    m: usize,
    k: usize,
    level: SimdLevel,
) -> Vec<f32> {
    simd::with_level(level, || {
        let p = kern.prepare(x, k);
        let mut out = vec![0f32; m];
        kern.gemv(packed, &p, &mut out);
        out
    })
}

/// Prepare-once path (`PreparedBatch::build` → `prepare_row_into` →
/// `matmul_prepared`) under a forced SIMD tier.
fn matmul_prepared_at(
    kern: &'static dyn Kernel,
    packed: &QTensor,
    x: &[f32],
    (m, k, n): (usize, usize, usize),
    pool: &ThreadPool,
    level: SimdLevel,
) -> Vec<f32> {
    simd::with_level(level, || {
        let mut acts = PreparedActivations::new();
        acts.begin_input();
        let mut out = vec![0f32; n * m];
        let batch = acts.get_or_prepare(kern, x, k, n, pool);
        matmul_prepared(kern, packed, batch, x, n, &mut out, pool);
        out
    })
}

/// The SIMD tiers to exercise. Scalar is included so the harness is
/// self-checking (scalar ≡ scalar) even on hosts with no vector unit.
fn levels() -> Vec<SimdLevel> {
    simd::available_levels()
}

/// Every kernel × every tier × adversarial (m, k): single row, M=17
/// (not a multiple of the 16-row tile), K at the kernel's minimum
/// alignment (shorter than one register of work for the vector paths),
/// and K an odd multiple (×13) so remainder loops run.
#[test]
fn gemv_bit_identical_across_simd_levels() {
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let kmul = kern.info().k_multiple;
        for (m, k) in [(1usize, kmul.max(4)), (17, kmul * 13), (48, 768)] {
            assert_eq!(k % kmul, 0, "{qt:?}: test shape must fit the kernel");
            let t = random_ternary(m, k, 7 + m as u64);
            let packed = kern.quantize(&t);
            let mut rng = Rng::new(900 + k as u64);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let reference = gemv_at(kern, &packed, &x, m, k, SimdLevel::Scalar);
            assert!(reference.iter().all(|v| v.is_finite()), "{qt:?} ({m},{k}): finite");
            for level in levels() {
                let out = gemv_at(kern, &packed, &x, m, k, level);
                assert_eq!(
                    out,
                    reference,
                    "{qt:?} ({m},{k}) at {}: gemv must be bit-identical to scalar",
                    level.name()
                );
            }
        }
    }
}

/// The batched prepare-once path at n ∈ {1, 8, 33} — the same contract,
/// through `PreparedBatch` and the tiled parallel accumulator.
#[test]
fn matmul_prepared_bit_identical_across_simd_levels() {
    let (m, k) = (48, 768);
    let pool = ThreadPool::new(4);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let t = random_ternary(m, k, 19);
        let packed = kern.quantize(&t);
        for n in [1usize, 8, 33] {
            let mut rng = Rng::new(50 + n as u64);
            let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
            let reference =
                matmul_prepared_at(kern, &packed, &x, (m, k, n), &pool, SimdLevel::Scalar);
            for level in levels() {
                let out = matmul_prepared_at(kern, &packed, &x, (m, k, n), &pool, level);
                assert_eq!(
                    out,
                    reference,
                    "{qt:?} n={n} at {}: matmul_prepared must be bit-identical to scalar",
                    level.name()
                );
            }
        }
    }
}

/// Degenerate weight matrices: all-zero and all-(+1)/all-(−1). These hit
/// the LUT paths with constant indices and the I2_S path with codes at
/// both extremes of the 2-bit range.
#[test]
fn degenerate_weights_bit_identical_across_levels() {
    let (m, k) = (8, 768);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        for (label, w) in [("zero", 0i8), ("plus", 1), ("minus", -1)] {
            let t = TernaryWeights::from_ternary(vec![w; m * k], m, k, 0.05);
            let packed = kern.quantize(&t);
            let mut rng = Rng::new(77);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let reference = gemv_at(kern, &packed, &x, m, k, SimdLevel::Scalar);
            for level in levels() {
                let out = gemv_at(kern, &packed, &x, m, k, level);
                assert_eq!(out, reference, "{qt:?} all-{label} at {}", level.name());
            }
        }
    }
}

/// Fixed-seed tail regression: K chosen as k_multiple × 37 — odd, not a
/// multiple of any 8/16/32-group blocking — so every kernel's final
/// scale block is short and every vector path runs its remainder loop.
/// n = 3 routes through `prepare_row_into` with that short final block.
#[test]
fn tail_blocks_pinned_by_fixed_seed_cases() {
    let pool = ThreadPool::new(2);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let kmul = kern.info().k_multiple;
        let (m, k, n) = (17usize, kmul.max(4) * 37, 3usize);
        let t = random_ternary(m, k, 123);
        let packed = kern.quantize(&t);
        let mut rng = Rng::new(321);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let reference = matmul_prepared_at(kern, &packed, &x, (m, k, n), &pool, SimdLevel::Scalar);
        // Cross-check the scalar shared path against per-row standalone
        // prepare before comparing tiers, so a tail bug shared by every
        // tier cannot hide.
        simd::with_level(SimdLevel::Scalar, || {
            for i in 0..n {
                let p = kern.prepare(&x[i * k..(i + 1) * k], k);
                let mut per_row = vec![0f32; m];
                kern.gemv(&packed, &p, &mut per_row);
                assert_eq!(
                    &reference[i * m..(i + 1) * m],
                    &per_row[..],
                    "{qt:?} k={k} row {i}: shared vs per-row prepare (scalar)"
                );
            }
        });
        for level in levels() {
            let out = matmul_prepared_at(kern, &packed, &x, (m, k, n), &pool, level);
            assert_eq!(out, reference, "{qt:?} k={k} tail at {}", level.name());
        }
    }
}

/// The lossless kernels must stay bit-exact against the integer
/// training-scheme reference *through every vector path*, not just
/// match scalar: LUT gathers and maddubs-style accumulation must
/// reproduce the exact per-block integer sums.
#[test]
fn lossless_kernels_training_scheme_exact_at_every_level() {
    let (m, k) = (16, 768);
    for qt in [QuantType::I2S, QuantType::Tl11, QuantType::Tl21] {
        let kern = kernel_for(qt);
        let t = random_ternary(m, k, 41);
        let packed = kern.quantize(&t);
        let mut rng = Rng::new(42);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let act = quantize_act_int8(&x);
        for level in levels() {
            let out = gemv_at(kern, &packed, &x, m, k, level);
            for r in 0..m {
                assert_eq!(
                    out[r],
                    training_scheme_ref_row(t.row(r), t.scale, &act),
                    "{qt:?} row {r} at {}: training-scheme exactness",
                    level.name()
                );
            }
        }
    }
}
