//! NUMA placement integration: under a mocked multi-node topology the
//! whole model pipeline — per-node weight localization at load, placed
//! matmul routing, node-grouped workers — must produce logits
//! bit-identical to a plain single-node pool, while the per-node
//! dispatch counters show every node executed its own row partition.
//!
//! Mock topologies (`Topology::mock`) place work but never pin threads,
//! so these tests are host-independent and run on single-core CI.

use bitnet::model::weights::Checkpoint;
use bitnet::model::{ModelConfig, Transformer};
use bitnet::threadpool::ThreadPool;
use bitnet::topology::Topology;
use bitnet::{Dispatch, DispatchPlan, QuantType};
use std::sync::Arc;

fn model_with_pool(ck: &Checkpoint, pool: Arc<ThreadPool>) -> Transformer {
    let plan = DispatchPlan::new(Dispatch::Fixed(QuantType::I2S));
    Transformer::from_checkpoint_plan_pool(ck, plan, pool)
}

fn argmax(v: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as u32
}

/// Prefill `prompt`, then decode `steps` greedy tokens; return every
/// logits vector produced along the way.
fn run_pipeline(model: &Transformer, prompt: &[u32], steps: usize) -> Vec<Vec<f32>> {
    let mut session = model.new_session(prompt.len() + steps + 1);
    let mut out = vec![model.prefill(&mut session, prompt)];
    for _ in 0..steps {
        let tok = argmax(out.last().unwrap());
        out.push(model.decode_step(&mut session, tok));
    }
    out
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn mock_two_node_logits_are_bit_identical() {
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 42);
    let prompt: Vec<u32> = (0..33).map(|i| (i * 7 + 3) % cfg.vocab_size as u32).collect();

    let single = model_with_pool(&ck, Arc::new(ThreadPool::new(4)));
    let numa_pool = Arc::new(ThreadPool::with_topology(4, Topology::mock(2)));
    let numa = model_with_pool(&ck, Arc::clone(&numa_pool));

    let a = run_pipeline(&single, &prompt, 6);
    let b = run_pipeline(&numa, &prompt, 6);
    assert_eq!(a.len(), b.len());
    for (step, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(bits(x), bits(y), "logits diverged at step {step}");
    }

    // Every node ran its own partition of the placed GEMM rows.
    let stats = numa_pool.numa_stats();
    assert_eq!(stats.nodes, 2);
    assert!(stats.mocked);
    assert_eq!(stats.chunks.len(), 2);
    for (node, &chunks) in stats.chunks.iter().enumerate() {
        assert!(chunks > 0, "node {node} executed no chunks: {stats:?}");
    }
}

#[test]
fn uneven_three_node_split_stays_bit_identical() {
    // Three nodes over four threads: row ranges are uneven and one node
    // holds two workers — the routing math must still cover every row
    // exactly once.
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 7);
    let prompt: Vec<u32> = (0..17).map(|i| (i * 11 + 5) % cfg.vocab_size as u32).collect();

    let single = model_with_pool(&ck, Arc::new(ThreadPool::new(4)));
    let numa_pool = Arc::new(ThreadPool::with_topology(4, Topology::mock(3)));
    let numa = model_with_pool(&ck, Arc::clone(&numa_pool));

    let a = run_pipeline(&single, &prompt, 4);
    let b = run_pipeline(&numa, &prompt, 4);
    for (step, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(bits(x), bits(y), "logits diverged at step {step}");
    }
    let stats = numa_pool.numa_stats();
    assert_eq!(stats.nodes, 3);
    assert!(stats.chunks.iter().sum::<u64>() > 0);
}
