//! Paged-KV correctness: the arena-backed cache must be invisible to the
//! math. F32 paging is bit-identical to the contiguous layout across
//! every serving shape (single sequence, chunked prefill, batched decode,
//! sequences straddling page boundaries); f16 pages stay within a tight
//! perplexity bound; and the watermark scheduler's preemption round-trip
//! (preempt → re-admit → re-prefill) reproduces the exact greedy tokens
//! an unconstrained budget produces.

use bitnet::coordinator::{Engine, EngineConfig, FinishReason, KvArena, KvDtype, Request};
use bitnet::eval::{eval_token_stream, log_softmax_at, perplexity};
use bitnet::kernels::QuantType;
use bitnet::model::{ModelConfig, Session, Transformer};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

fn tiny_model() -> Transformer {
    Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 42)
}

/// Every logits vector a fixed workload produces when the shared arena
/// uses `page_tokens`-sized pages: three sequences prefilled as chunks
/// (lengths 17/16/5 — straddling, exactly filling, and inside the
/// default page), 20 batched decode steps, plus a single-sequence (n=1)
/// prefill + decode tail. All sessions share one arena, so their page
/// tables interleave.
fn logits_suite(model: &Transformer, page_tokens: usize) -> Vec<Vec<f32>> {
    let arena = Arc::new(Mutex::new(KvArena::with_page_tokens(
        model.cfg.n_layers,
        model.cfg.kv_dim(),
        16384,
        KvDtype::F32,
        page_tokens,
    )));
    let prompts: [&[u32]; 3] = [
        &[3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9, 7, 9, 3, 2],
        &[2, 7, 1, 8, 2, 8, 1, 8, 2, 8, 4, 5, 9, 0, 4, 5],
        &[1, 6, 1, 8, 0],
    ];
    let mut sessions: Vec<Session> =
        (0..prompts.len()).map(|i| model.new_session_shared(&arena, i as u64, 64)).collect();
    let mut out = Vec::new();
    for (s, p) in sessions.iter_mut().zip(prompts.iter()) {
        out.push(model.prefill(s, p));
    }
    let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
    for step in 0..20u32 {
        let tokens = [5 + step % 400, 7 + step % 300, 11 + step % 200];
        out.extend(model.decode_batch(&mut refs, &tokens));
    }
    drop(refs);
    // n=1 regime in the same arena (its pages land after the batch's).
    let mut solo = model.new_session_shared(&arena, 99, 64);
    out.push(model.prefill(&mut solo, &[42, 43, 44]));
    for step in 0..18u32 {
        out.push(model.decode_step(&mut solo, 50 + step));
    }
    out
}

#[test]
fn paged_f32_is_bit_identical_to_contiguous_layout() {
    let model = tiny_model();
    // page_tokens larger than any sequence degenerates to one page per
    // sequence — exactly the pre-paged contiguous layout. Page sizes 1
    // and 3 force maximal straddling; 16 is the production default.
    let reference = logits_suite(&model, 4096);
    for page_tokens in [1usize, 3, 16] {
        let paged = logits_suite(&model, page_tokens);
        assert_eq!(paged.len(), reference.len());
        for (i, (a, b)) in paged.iter().zip(reference.iter()).enumerate() {
            assert_eq!(a, b, "logits {i} diverge at page_tokens={page_tokens}");
        }
    }
}

/// Page size must be invisible to attention at *both* KV dtypes: the
/// same appended rows read through 1-, 3-, and 16-token pages produce
/// bit-identical attend output to the one-page-per-sequence contiguous
/// layout. The model-level suite above covers f32 end to end; this
/// pins the f16 decode-in-the-loop path (where a scratch-materializing
/// or differently-tiled gather would show up) at the arena level.
#[test]
fn paged_attend_matches_contiguous_at_both_dtypes() {
    for dtype in [KvDtype::F32, KvDtype::F16] {
        for (n_heads, n_kv_heads, head_dim, ctx) in
            [(4usize, 4usize, 8usize, 17usize), (8, 2, 16, 33), (5, 1, 12, 16)]
        {
            let kv_dim = n_kv_heads * head_dim;
            let mut rng = bitnet::util::Rng::new(77);
            let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..ctx)
                .map(|_| {
                    (
                        (0..kv_dim).map(|_| rng.next_gaussian()).collect(),
                        (0..kv_dim).map(|_| rng.next_gaussian()).collect(),
                    )
                })
                .collect();
            let q: Vec<f32> = (0..n_heads * head_dim).map(|_| rng.next_gaussian()).collect();
            let scale = 1.0 / (head_dim as f32).sqrt();
            let attend_paged = |page_tokens: usize| {
                let mut arena = KvArena::with_page_tokens(1, kv_dim, 8192, dtype, page_tokens);
                assert!(arena.reserve(1, ctx));
                for (pos, (k, v)) in rows.iter().enumerate() {
                    arena.append(1, 0, pos, k, v);
                }
                let mut out = vec![0f32; n_heads * head_dim];
                arena.attend(1, 0, &q, ctx, n_heads, n_kv_heads, head_dim, scale, &mut out);
                out
            };
            let contiguous = attend_paged(4096);
            for page_tokens in [1usize, 3, 16] {
                assert_eq!(
                    attend_paged(page_tokens),
                    contiguous,
                    "{dtype:?} {n_heads}h/{n_kv_heads}kv hd={head_dim} ctx={ctx}: \
                     page_tokens={page_tokens} diverges from contiguous"
                );
            }
        }
    }
}

/// Teacher-forced perplexity with a session of the given KV dtype
/// (mirrors `eval::perplexity`, which always uses the f32 default).
fn ppl_with_dtype(model: &Transformer, tokens: &[u32], dtype: KvDtype) -> f64 {
    let mut session = model.new_session_dtype(tokens.len(), dtype);
    let mut nll = 0f64;
    let mut count = 0usize;
    let mut logits = model.prefill(&mut session, &tokens[..1]);
    for w in tokens.windows(2) {
        nll += -log_softmax_at(&logits, w[1] as usize);
        count += 1;
        logits = model.decode_step(&mut session, w[1]);
    }
    (nll / count as f64).exp()
}

#[test]
fn f16_kv_perplexity_stays_close() {
    let model = tiny_model();
    let tokens = eval_token_stream(512, 40, 11);
    let p32 = ppl_with_dtype(&model, &tokens, KvDtype::F32);
    // The f32 dtype path is the same arena code: must match the eval
    // harness bit for bit.
    assert_eq!(p32, perplexity(&model, &tokens));
    let p16 = ppl_with_dtype(&model, &tokens, KvDtype::F16);
    let rel = (p16 - p32).abs() / p32;
    assert!(rel < 0.05, "f16 KV perplexity {p16} vs f32 {p32} (rel {rel})");
}

/// Serve `prompts` greedily under a KV budget; returns every output
/// token stream plus the preemption count and peak decode width.
fn run_budget(budget_tokens: usize, prompts: &[Vec<u32>], max_new: usize) -> (Vec<Vec<u32>>, u64, u64) {
    let model = tiny_model();
    let engine = Engine::start(
        model,
        EngineConfig {
            max_batch: 4,
            kv_budget_tokens: budget_tokens,
            eos_token: 1,
            seed: 5,
            ..Default::default()
        },
    );
    let handles: Vec<_> =
        prompts.iter().map(|p| engine.submit(Request::greedy(p.clone(), max_new))).collect();
    let outs: Vec<Vec<u32>> = handles
        .into_iter()
        .map(|h| {
            let (tokens, reason, _) = h.wait();
            assert_eq!(reason, FinishReason::Length);
            tokens
        })
        .collect();
    let preemptions = engine.metrics.kv_preemptions.load(Ordering::Relaxed);
    let peak_batch = engine.metrics.peak_batch.load(Ordering::Relaxed);
    (outs, preemptions, peak_batch)
}

fn pressure_prompts() -> Vec<Vec<u32>> {
    vec![(3..19).collect(), (103..119).collect()]
}

#[test]
fn watermark_admission_runs_concurrency_worst_case_cannot() {
    // Two 16-token prompts generating 33 tokens each under a 64-token
    // (4-page) budget: worst-case admission (prompt + max_new = 49
    // tokens = 4 pages per sequence) could only ever run them one at a
    // time. Watermark admission holds both in flight.
    let arena = KvArena::accounting(64);
    assert!(
        2 * arena.pages_for(16 + 33) > arena.total_pages(),
        "workload must not fit under worst-case reservation"
    );
    let (outs, preemptions, peak_batch) = run_budget(64, &pressure_prompts(), 33);
    assert!(outs.iter().all(|t| t.len() == 33), "every sequence completes");
    assert!(peak_batch >= 2, "watermark admission must co-run both (peak {peak_batch})");
    // Combined demand peaks at 6 pages > 4: the scheduler must have
    // preempted (and recovered) at least once.
    assert!(preemptions >= 1, "pressure workload must exercise preemption");
}

#[test]
fn preemption_round_trip_reproduces_unconstrained_tokens() {
    // Same workload with a roomy budget: no preemption, the reference
    // output. The tight run preempts, re-admits, re-prefills — and must
    // emit exactly the same greedy tokens.
    let prompts = pressure_prompts();
    let (reference, p0, _) = run_budget(4096, &prompts, 33);
    assert_eq!(p0, 0, "roomy budget must not preempt");
    let (tight, p1, _) = run_budget(64, &prompts, 33);
    assert!(p1 >= 1, "tight budget must preempt");
    assert_eq!(tight, reference, "preemption round-trip must not change outputs");
}
