//! Prepare-once pipeline tests: the shared-preparation path
//! (`PreparedActivations` + `matmul_prepared`) must be bit-identical to
//! per-call preparation for every kernel and batch shape, lossless
//! kernels must stay training-scheme exact through it, preprocessing
//! must run once per consuming role-group (not once per projection), and
//! steady-state decode must not allocate in the prepare path.

use bitnet::kernels::quant::{quantize_act_int8, training_scheme_ref_row, TernaryWeights};
use bitnet::kernels::{kernel_for, matmul, matmul_prepared, PreparedActivations, QuantType};
use bitnet::model::{ModelConfig, Transformer};
use bitnet::threadpool::ThreadPool;
use bitnet::util::Rng;

fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
    let mut rng = Rng::new(seed);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    TernaryWeights::from_ternary(q, m, k, 0.05)
}

/// Property: for all 14 kernels × {n=1, 8, 33}, one shared preparation
/// consumed by multiple matmuls equals per-call preparation bit-for-bit.
#[test]
fn shared_prepare_is_bit_identical_to_per_call_prepare() {
    // K = 768 satisfies every kernel's K-multiple (128 | 768, 256 | 768,
    // 32/16/8/4 | 768), so all 14 kernels run on the same shape.
    let (m, k) = (48, 768);
    let pool = ThreadPool::new(4);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        assert_eq!(k % kern.info().k_multiple, 0, "{qt:?}: test shape must fit every kernel");
        let t = random_ternary(m, k, 7);
        let packed = kern.quantize(&t);
        for n in [1usize, 8, 33] {
            let mut rng = Rng::new(100 + n as u64);
            let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
            // Shared path: prepare once, consume twice (the wq/wk pattern).
            let mut acts = PreparedActivations::new();
            acts.begin_input();
            let mut out_a = vec![0f32; n * m];
            {
                let batch = acts.get_or_prepare(kern, &x, k, n, &pool);
                matmul_prepared(kern, &packed, batch, &x, n, &mut out_a, &pool);
            }
            let mut out_b = vec![0f32; n * m];
            {
                let batch = acts.get_or_prepare(kern, &x, k, n, &pool);
                matmul_prepared(kern, &packed, batch, &x, n, &mut out_b, &pool);
            }
            let s = acts.stats();
            assert_eq!(s.misses, 1, "{qt:?} n={n}: prepare must run exactly once");
            assert_eq!(s.hits, 1, "{qt:?} n={n}: second consumer must hit the cache");
            assert_eq!(out_a, out_b, "{qt:?} n={n}: shared batch must be deterministic");
            // Reference: per-row standalone prepare + serial gemv.
            for i in 0..n {
                let p = kern.prepare(&x[i * k..(i + 1) * k], k);
                let mut out_ref = vec![0f32; m];
                kern.gemv(&packed, &p, &mut out_ref);
                assert_eq!(
                    &out_a[i * m..(i + 1) * m],
                    &out_ref[..],
                    "{qt:?} n={n} row {i}: shared vs per-call prepare"
                );
            }
        }
    }
}

/// Rebuilding a warm cache for new inputs of the same shape must reuse
/// every buffer (the allocation-free steady state) and stay correct.
#[test]
fn warm_cache_rebuilds_without_allocation_for_all_kernels() {
    let (m, k, n) = (16, 768, 4);
    let pool = ThreadPool::new(2);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let t = random_ternary(m, k, 11);
        let packed = kern.quantize(&t);
        let mut acts = PreparedActivations::new();
        let mut rng = Rng::new(12);
        let mut out = vec![0f32; n * m];
        let mut reference = vec![0f32; n * m];
        let mut allocs_after_first = 0u64;
        for step in 0..3 {
            let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
            acts.begin_input();
            {
                let batch = acts.get_or_prepare(kern, &x, k, n, &pool);
                matmul_prepared(kern, &packed, batch, &x, n, &mut out, &pool);
            }
            if step == 0 {
                allocs_after_first = acts.stats().buffer_allocs;
            }
            matmul(kern, &packed, &x, n, &mut reference, &pool);
            assert_eq!(out, reference, "{qt:?} step {step}");
        }
        let s = acts.stats();
        assert_eq!(s.misses, 3, "{qt:?}: one prepare per input");
        assert_eq!(
            s.buffer_allocs, allocs_after_first,
            "{qt:?}: every rebuild after the first must reuse buffers"
        );
        assert!(s.buffer_reuses >= 2, "{qt:?}: warm rebuilds count as reuses");
    }
}

/// The lossless kernels (I2_S, TL1_1, TL2_1) must stay bit-identical to
/// the integer training-scheme reference (the dequantized-f32-equivalent
/// computation) through the shared-prepare path.
#[test]
fn lossless_kernels_stay_bit_exact_through_shared_path() {
    let (m, k) = (32, 768);
    let pool = ThreadPool::new(3);
    for qt in [QuantType::I2S, QuantType::Tl11, QuantType::Tl21] {
        let kern = kernel_for(qt);
        let t = random_ternary(m, k, 21);
        let packed = kern.quantize(&t);
        for n in [1usize, 5] {
            let mut rng = Rng::new(33 + n as u64);
            let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
            let mut acts = PreparedActivations::new();
            acts.begin_input();
            let mut out = vec![0f32; n * m];
            let batch = acts.get_or_prepare(kern, &x, k, n, &pool);
            matmul_prepared(kern, &packed, batch, &x, n, &mut out, &pool);
            for i in 0..n {
                let act = quantize_act_int8(&x[i * k..(i + 1) * k]);
                for r in 0..m {
                    assert_eq!(
                        out[i * m + r],
                        training_scheme_ref_row(t.row(r), t.scale, &act),
                        "{qt:?} n={n} row ({i},{r})"
                    );
                }
            }
        }
    }
}

/// For a given layer input, preparation runs exactly once per consuming
/// role-group: qkv = 1 prepare (wk/wv hit), gate+up = 1 prepare (up
/// hits), o and down 1 each — 4 misses and 3 hits per layer per step,
/// not 7 prepares.
#[test]
fn prepare_runs_once_per_role_group() {
    let model = Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 5);
    let layers = model.cfg.n_layers as u64;
    let mut s = model.new_session(64);
    let _ = model.prefill(&mut s, &[1, 2, 3, 4]);
    let ps = model.prepare_stats();
    assert_eq!(ps.misses, 4 * layers, "one prepare per role-group per layer");
    assert_eq!(ps.hits, 3 * layers, "wk/wv and up share their inputs' preparation");
    let logits = model.decode_step(&mut s, 7);
    assert_eq!(logits.len(), model.cfg.vocab_size);
    let ps = model.prepare_stats();
    assert_eq!(ps.misses, 8 * layers);
    assert_eq!(ps.hits, 6 * layers);
}

/// Steady-state decode must not allocate in the prepare path: once the
/// decode shapes are warm, the buffer-allocation counter flatlines.
#[test]
fn decode_steady_state_is_allocation_free_in_prepare_path() {
    for qt in [QuantType::I2S, QuantType::Tl20, QuantType::Tl21] {
        let model = Transformer::synthetic(&ModelConfig::tiny(), qt, 6);
        let mut s = model.new_session(64);
        let _ = model.prefill(&mut s, &[3, 1, 4]);
        // Warm the decode shapes (n=1 inputs at hidden and ffn widths).
        let _ = model.decode_step(&mut s, 1);
        let _ = model.decode_step(&mut s, 2);
        let warm = model.prepare_stats();
        for t in 3..10u32 {
            let _ = model.decode_step(&mut s, t);
        }
        let ps = model.prepare_stats();
        assert_eq!(
            ps.buffer_allocs, warm.buffer_allocs,
            "{qt:?}: steady-state decode must not allocate in the prepare path"
        );
        assert!(ps.buffer_reuses > warm.buffer_reuses, "{qt:?}: builds keep reusing buffers");
    }
}

/// Steady-state decode must not allocate in the attention path either:
/// the per-session `AttnWorkspace` grows its score buffer in
/// power-of-two steps, so once prefill has sized it past the decode
/// window the allocation counter flatlines while the reuse counter
/// keeps climbing — every decode step reads the cache through a warm
/// workspace.
#[test]
fn decode_steady_state_is_allocation_free_in_attention_path() {
    let model = Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 9);
    let mut s = model.new_session(64);
    // 23-token prompt: with tiny's 4 heads the prefill peak is
    // n_heads * ctx = 92 scores, so the workspace lands on a 128-slot
    // power-of-two capacity — enough for decode out to ctx = 32.
    let prompt: Vec<u32> = (0..23).map(|i| 5 + i % 40).collect();
    let _ = model.prefill(&mut s, &prompt);
    let _ = model.decode_step(&mut s, 1);
    let _ = model.decode_step(&mut s, 2);
    let (warm_allocs, warm_reuses) = s.attn_workspace_stats();
    assert!(warm_allocs >= 1, "prefill must have sized the workspace");
    for t in 3..10u32 {
        let _ = model.decode_step(&mut s, t);
    }
    let (allocs, reuses) = s.attn_workspace_stats();
    assert_eq!(allocs, warm_allocs, "steady-state decode attention must not allocate");
    assert!(reuses > warm_reuses, "decode steps keep reusing the warm score buffer");
}
