//! Serving-path integration: the engine under load, end to end, plus
//! failure injection (rejections, cancellations on shutdown).

use bitnet::coordinator::{Engine, EngineConfig, FinishReason, Request};
use bitnet::kernels::QuantType;
use bitnet::model::{ModelConfig, SamplingParams, Transformer};
use bitnet::util::Rng;
use std::sync::atomic::Ordering;

fn engine(qt: QuantType, max_batch: usize, kv_tokens: usize) -> Engine {
    let model = Transformer::synthetic(&ModelConfig::tiny(), qt, 42);
    Engine::start(
        model,
        EngineConfig { max_batch, kv_budget_tokens: kv_tokens, eos_token: 1, seed: 5 },
    )
}

#[test]
fn sustained_load_all_requests_complete() {
    let eng = engine(QuantType::Tl20, 4, 4096);
    let mut rng = Rng::new(9);
    let handles: Vec<_> = (0..24)
        .map(|_| {
            let plen = 1 + rng.next_below(10);
            let prompt: Vec<u32> = (0..plen).map(|_| 3 + rng.next_below(500) as u32).collect();
            eng.submit(Request {
                prompt,
                max_new_tokens: 1 + rng.next_below(12),
                sampling: SamplingParams::with_temperature(0.8),
                stop_on_eos: false,
            })
        })
        .collect();
    for h in handles {
        let (tokens, reason, stats) = h.wait();
        assert_eq!(reason, FinishReason::Length);
        assert_eq!(tokens.len(), stats.new_tokens);
        assert!(!tokens.is_empty());
    }
    let m = &eng.metrics;
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 24);
    assert_eq!(m.requests_rejected.load(Ordering::Relaxed), 0);
    assert!(m.mean_batch() > 1.0, "mean batch {}", m.mean_batch());
}

#[test]
fn kv_pressure_serializes_but_completes() {
    // Budget fits ~1 request at a time; everything must still finish.
    let eng = engine(QuantType::I2S, 8, 64);
    let handles: Vec<_> = (0..5)
        .map(|i| eng.submit(Request::greedy(vec![i + 3, 4, 5], 8)))
        .collect();
    for h in handles {
        let (tokens, reason, _) = h.wait();
        assert_eq!(reason, FinishReason::Length);
        assert_eq!(tokens.len(), 8);
    }
}

#[test]
fn shutdown_cancels_in_flight() {
    let handles = {
        let eng = engine(QuantType::I2S, 2, 4096);
        // max_new must fit the KV budget (else the request is *rejected*,
        // not cancelled) while being far too long to finish before drop.
        let handles: Vec<_> =
            (0..4).map(|i| eng.submit(Request::greedy(vec![i + 3], 200))).collect();
        // Engine dropped here while requests are long-running.
        handles
    };
    let mut cancelled = 0;
    for h in handles {
        let (_, reason, _) = h.wait();
        if reason == FinishReason::Cancelled {
            cancelled += 1;
        }
    }
    assert!(cancelled > 0, "long requests should be cancelled at shutdown");
}

#[test]
fn eos_stops_generation() {
    // With eos_token likely to appear under temperature sampling over a
    // tiny vocab... deterministic alternative: eos = the greedy token.
    let eng = engine(QuantType::I2S, 1, 4096);
    // First discover the greedy continuation token.
    let (toks, _, _) = eng.submit(Request::greedy(vec![10, 11], 1)).wait();
    let greedy_tok = toks[0];
    let model = Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 42);
    let eng2 = Engine::start(
        model,
        EngineConfig { max_batch: 1, kv_budget_tokens: 4096, eos_token: greedy_tok, seed: 5 },
    );
    let (tokens, reason, _) = eng2
        .submit(Request { prompt: vec![10, 11], max_new_tokens: 50, sampling: SamplingParams::greedy(), stop_on_eos: true })
        .wait();
    assert_eq!(reason, FinishReason::Eos);
    assert!(tokens.len() < 50);
}

#[test]
fn throughput_improves_with_batching() {
    // Batching reuses each weight pass across the batch. On a multi-core
    // memory-bound host this is a large win; on a 1-core box with a
    // cache-resident tiny model the win shrinks toward zero, so the hard
    // guarantee tested here is (a) batching engages (mean batch > 1) and
    // (b) it never *loses* aggregate throughput beyond noise.
    let run = |max_batch: usize| {
        let eng = engine(QuantType::Tl20, max_batch, 8192);
        let t0 = std::time::Instant::now();
        let handles: Vec<_> =
            (0..8).map(|i| eng.submit(Request::greedy(vec![i + 3, 2], 24))).collect();
        let total: usize = handles.into_iter().map(|h| h.wait().0.len()).sum();
        let tps = total as f64 / t0.elapsed().as_secs_f64();
        (tps, eng.metrics.mean_batch())
    };
    let (tps1, _) = run(1);
    let (tps4, mean_batch) = run(4);
    assert!(mean_batch > 1.5, "batching should engage: mean batch {mean_batch}");
    assert!(
        tps4 > tps1 * 0.7,
        "batching must not collapse aggregate throughput: {tps1:.1} vs {tps4:.1} tok/s"
    );
}
