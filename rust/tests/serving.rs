//! Serving-path integration: the engine under load, end to end, plus
//! failure injection (rejections, cancellations on shutdown) and
//! phase-aware dispatch through the coordinator (prefill chunks and
//! batched decode routing to different tuned kernels mid-serve).

use bitnet::coordinator::{Engine, EngineConfig, FinishReason, Request};
use bitnet::kernels::tuner::{shapes_for_model, TuningEntry};
use bitnet::kernels::{Dispatch, QuantType, SimdLevel, TuningProfile};
use bitnet::model::weights::Checkpoint;
use bitnet::model::{ModelConfig, SamplingParams, Transformer};
use bitnet::util::Rng;
use std::sync::atomic::Ordering;

fn engine(qt: QuantType, max_batch: usize, kv_tokens: usize) -> Engine {
    let model = Transformer::synthetic(&ModelConfig::tiny(), qt, 42);
    Engine::start(
        model,
        EngineConfig { max_batch, kv_budget_tokens: kv_tokens, eos_token: 1, seed: 5, ..Default::default() },
    )
}

#[test]
fn sustained_load_all_requests_complete() {
    let eng = engine(QuantType::Tl20, 4, 4096);
    let mut rng = Rng::new(9);
    let handles: Vec<_> = (0..24)
        .map(|_| {
            let plen = 1 + rng.next_below(10);
            let prompt: Vec<u32> = (0..plen).map(|_| 3 + rng.next_below(500) as u32).collect();
            eng.submit(Request {
                prompt,
                max_new_tokens: 1 + rng.next_below(12),
                sampling: SamplingParams::with_temperature(0.8),
                stop_on_eos: false,
            })
        })
        .collect();
    for h in handles {
        let (tokens, reason, stats) = h.wait();
        assert_eq!(reason, FinishReason::Length);
        assert_eq!(tokens.len(), stats.new_tokens);
        assert!(!tokens.is_empty());
    }
    let m = &eng.metrics;
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 24);
    assert_eq!(m.requests_rejected.load(Ordering::Relaxed), 0);
    assert!(m.mean_batch() > 1.0, "mean batch {}", m.mean_batch());
}

#[test]
fn kv_pressure_serializes_but_completes() {
    // Tight budget; everything must still finish, and the engine must
    // report page-level KV occupancy that stays inside the budget.
    let eng = engine(QuantType::I2S, 8, 64);
    let handles: Vec<_> = (0..5)
        .map(|i| eng.submit(Request::greedy(vec![i + 3, 4, 5], 8)))
        .collect();
    for h in handles {
        let (tokens, reason, _) = h.wait();
        assert_eq!(reason, FinishReason::Length);
        assert_eq!(tokens.len(), 8);
    }
    let m = &eng.metrics;
    let total = m.kv_pages_total.load(Ordering::Relaxed);
    let peak = m.kv_pages_peak.load(Ordering::Relaxed);
    assert_eq!(total, 4, "64-token budget is 4 pages");
    assert!(peak >= 1 && peak <= total, "peak pages {peak} within budget {total}");
    assert_eq!(m.kv_pages_used.load(Ordering::Relaxed), 0, "all pages released at the end");
    assert!(
        m.kv_resident_bytes.load(Ordering::Relaxed)
            <= m.kv_capacity_bytes.load(Ordering::Relaxed),
        "lazy minting never exceeds the budget"
    );
    assert!(m.summary().contains("kv "), "summary reports the arena");
}

#[test]
fn shutdown_cancels_in_flight() {
    let handles = {
        let eng = engine(QuantType::I2S, 2, 4096);
        // max_new must fit the KV budget (else the request is *rejected*,
        // not cancelled) while being far too long to finish before drop.
        let handles: Vec<_> =
            (0..4).map(|i| eng.submit(Request::greedy(vec![i + 3], 200))).collect();
        // Engine dropped here while requests are long-running.
        handles
    };
    let mut cancelled = 0;
    for h in handles {
        let (_, reason, _) = h.wait();
        if reason == FinishReason::Cancelled {
            cancelled += 1;
        }
    }
    assert!(cancelled > 0, "long requests should be cancelled at shutdown");
}

#[test]
fn eos_stops_generation() {
    // With eos_token likely to appear under temperature sampling over a
    // tiny vocab... deterministic alternative: eos = the greedy token.
    let eng = engine(QuantType::I2S, 1, 4096);
    // First discover the greedy continuation token.
    let (toks, _, _) = eng.submit(Request::greedy(vec![10, 11], 1)).wait();
    let greedy_tok = toks[0];
    let model = Transformer::synthetic(&ModelConfig::tiny(), QuantType::I2S, 42);
    let eng2 = Engine::start(
        model,
        EngineConfig { max_batch: 1, kv_budget_tokens: 4096, eos_token: greedy_tok, seed: 5, ..Default::default() },
    );
    let (tokens, reason, _) = eng2
        .submit(Request { prompt: vec![10, 11], max_new_tokens: 50, sampling: SamplingParams::greedy(), stop_on_eos: true })
        .wait();
    assert_eq!(reason, FinishReason::Eos);
    assert!(tokens.len() < 50);
}

#[test]
fn phase_aware_auto_engine_matches_fixed_engine_outputs() {
    // A profile with distinct decode (n=1 → I2_S) and batched (n=4 →
    // TL2_1) winners, served through the full coordinator: prefill
    // chunks and multi-sequence decode steps route to the batched
    // winner, single-sequence decode to the primary — and because both
    // kernels are lossless, greedy outputs must equal the fixed I2_S
    // engine exactly, whatever batch compositions the scheduler forms.
    let cfg = ModelConfig::tiny();
    let mut profile = TuningProfile::empty(QuantType::I2S, 1);
    for (m, k) in shapes_for_model(&cfg) {
        for (n, qt) in [(1usize, QuantType::I2S), (4, QuantType::Tl21)] {
            profile.entries.push(TuningEntry {
                m,
                k,
                n,
                weight: 1.0,
                best: qt,
                best_simd: SimdLevel::Scalar,
                best_sparse: false,
                measurements: Vec::new(),
            });
        }
    }
    let auto_model = Transformer::from_checkpoint_dispatch(
        &Checkpoint::synthetic(&cfg, 42),
        Dispatch::Auto(profile),
        1,
    );
    let eng_auto = Engine::start(
        auto_model,
        EngineConfig { max_batch: 4, kv_budget_tokens: 4096, eos_token: 1, seed: 5, ..Default::default() },
    );
    let eng_fixed = engine(QuantType::I2S, 4, 4096);
    let prompts: Vec<Vec<u32>> = vec![vec![4, 5, 6], vec![7, 8], vec![9, 10, 11, 12], vec![200]];
    let ha: Vec<_> =
        prompts.iter().map(|p| eng_auto.submit(Request::greedy(p.clone(), 8))).collect();
    let hf: Vec<_> =
        prompts.iter().map(|p| eng_fixed.submit(Request::greedy(p.clone(), 8))).collect();
    let out_auto: Vec<Vec<u32>> = ha.into_iter().map(|h| h.wait().0).collect();
    let out_fixed: Vec<Vec<u32>> = hf.into_iter().map(|h| h.wait().0).collect();
    assert_eq!(out_auto, out_fixed, "lossless phase-aware dispatch must not change outputs");
    assert_eq!(
        eng_auto.metrics.dispatch_fallbacks.load(Ordering::Relaxed),
        0,
        "profile covers every serving shape"
    );
    assert_eq!(
        eng_auto.metrics.dispatch_degraded.load(Ordering::Relaxed),
        0,
        "every resolved winner must actually run (one alternate fits the budget)"
    );
    assert!(eng_auto.metrics.peak_batch.load(Ordering::Relaxed) >= 1);
    // The longest prompt was 4 tokens — the prefill-phase dispatch key.
    assert_eq!(eng_auto.metrics.peak_prefill_chunk.load(Ordering::Relaxed), 4);
}

#[test]
fn uncovered_profile_surfaces_dispatch_fallbacks_in_metrics() {
    // An empty Auto profile silently served everything on the default
    // kernel before PR 2; now every such selection is counted.
    let cfg = ModelConfig::tiny();
    let profile = TuningProfile::empty(QuantType::I2S, 1);
    let model = Transformer::from_checkpoint_dispatch(
        &Checkpoint::synthetic(&cfg, 42),
        Dispatch::Auto(profile),
        1,
    );
    let eng = Engine::start(
        model,
        EngineConfig { max_batch: 2, kv_budget_tokens: 2048, eos_token: 1, seed: 5, ..Default::default() },
    );
    let (tokens, reason, _) = eng.submit(Request::greedy(vec![5, 6, 7], 4)).wait();
    assert_eq!(reason, FinishReason::Length);
    assert_eq!(tokens.len(), 4);
    assert!(
        eng.metrics.dispatch_fallbacks.load(Ordering::Relaxed) > 0,
        "empty profile must surface fallbacks in metrics"
    );
    assert!(eng.metrics.summary().contains("dispatch fallbacks"));
}

#[test]
fn engine_records_serving_trace() {
    // The engine's step loop records the shape histogram `tune --trace`
    // consumes: every prompt length shows up as a prefill chunk, decode
    // widths stay within the batch cap, and the counters mirror into
    // the lock-free metrics.
    let eng = engine(QuantType::I2S, 4, 4096);
    let prompts: Vec<Vec<u32>> = vec![vec![4, 5, 6], vec![7, 8], vec![9, 10, 11, 12]];
    let handles: Vec<_> =
        prompts.iter().map(|p| eng.submit(Request::greedy(p.clone(), 6))).collect();
    for h in handles {
        let (_, reason, _) = h.wait();
        assert_eq!(reason, FinishReason::Length);
    }
    let trace = eng.trace_snapshot();
    assert!(trace.steps > 0, "steps with GEMM work must be recorded");
    for p in &prompts {
        assert!(
            trace.prefill_chunks.contains_key(&p.len()),
            "prefill chunk {} missing from {trace:?}",
            p.len()
        );
    }
    assert_eq!(
        trace.prefill_chunks.values().sum::<u64>(),
        prompts.len() as u64,
        "one prefill event per admitted request"
    );
    assert!(!trace.decode_widths.is_empty());
    assert!(trace.decode_widths.keys().all(|&w| (1..=4).contains(&w)));
    // The tuner-facing view is a proper distribution over observed widths.
    let wb = trace.weighted_batches();
    assert!(!wb.is_empty());
    let total: f64 = wb.iter().map(|(_, w)| w).sum();
    assert!((total - 1.0).abs() < 1e-9, "weights must sum to 1, got {total}");
    // Mirrored into the engine metrics and visible in the summary line.
    assert_eq!(eng.metrics.trace_steps.load(Ordering::Relaxed), trace.steps);
    assert_eq!(
        eng.metrics.trace_shapes.load(Ordering::Relaxed),
        trace.distinct_shapes() as u64
    );
    assert!(eng.metrics.summary().contains("trace"));
}

#[test]
fn throughput_improves_with_batching() {
    // Batching reuses each weight pass across the batch. On a multi-core
    // memory-bound host this is a large win; on a 1-core box with a
    // cache-resident tiny model the win shrinks toward zero, so the hard
    // guarantee tested here is (a) batching engages (mean batch > 1) and
    // (b) it never *loses* aggregate throughput beyond noise.
    let run = |max_batch: usize| {
        let eng = engine(QuantType::Tl20, max_batch, 8192);
        let t0 = std::time::Instant::now();
        let handles: Vec<_> =
            (0..8).map(|i| eng.submit(Request::greedy(vec![i + 3, 2], 24))).collect();
        let total: usize = handles.into_iter().map(|h| h.wait().0.len()).sum();
        let tps = total as f64 / t0.elapsed().as_secs_f64();
        (tps, eng.metrics.mean_batch())
    };
    let (tps1, _) = run(1);
    let (tps4, mean_batch) = run(4);
    assert!(mean_batch > 1.5, "batching should engage: mean batch {mean_batch}");
    assert!(
        tps4 > tps1 * 0.7,
        "batching must not collapse aggregate throughput: {tps1:.1} vs {tps4:.1} tok/s"
    );
}
